from .pipeline import DataPipeline, synth_batch  # noqa: F401
