"""Synthetic-token data pipeline with task-based prefetch.

The paper's observation (§5.3) that long compute tasks "hide I/O overhead"
is made systematic here: batch generation runs as RCOMPSs tasks submitted
``prefetch_depth`` steps ahead of the consumer, so the runtime overlaps
data preparation with the training step — the same DAG mechanics as the
paper's fill_fragment tasks.

Batches are deterministic in (seed, step): restart-safe (a restored run
re-generates exactly the batches it would have seen), and each data shard
derives its slice from its shard index — the multi-host layout.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core import api
from ..models.lm import LMConfig


def synth_batch(cfg: LMConfig, batch: int, seq: int, step: int,
                seed: int = 0, shard: int = 0, n_shards: int = 1) -> Dict:
    """Deterministic synthetic LM batch for (seed, step, shard)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard, n_shards]))
    b = batch // n_shards
    out: Dict[str, np.ndarray] = {}
    # a token stream with local structure (markov-ish) so loss can improve
    base = rng.integers(0, cfg.vocab_size, size=(b, 1))
    steps = rng.integers(-3, 4, size=(b, seq))
    tokens = np.abs(base + np.cumsum(steps, axis=1)) % cfg.vocab_size
    tokens = tokens.astype(np.int32)
    if cfg.input_mode == "tokens":
        out["tokens"] = tokens
    elif cfg.input_mode == "embeds":
        out["embeds"] = rng.standard_normal((b, seq, cfg.d_model)).astype(np.float32)
    else:  # prefix_embeds (VLM)
        p = min(cfg.prefix_len, seq // 2)
        out["prefix_embeds"] = rng.standard_normal((b, p, cfg.d_model)).astype(np.float32)
        out["tokens"] = tokens[:, : seq - p]
    targets = np.roll(tokens, -1, axis=1)
    targets[:, -1] = 0
    out["targets"] = targets.astype(np.int32)
    mask = np.ones((b, seq), np.float32)
    mask[:, -1] = 0.0
    if cfg.input_mode == "prefix_embeds":
        p = min(cfg.prefix_len, seq // 2)
        mask[:, :p] = 0.0  # no loss on image-patch positions
    out["loss_mask"] = mask
    return out


class DataPipeline:
    """Prefetching batch source backed by RCOMPSs tasks."""

    def __init__(self, cfg: LMConfig, batch: int, seq: int, *, seed: int = 0,
                 prefetch_depth: int = 2, use_runtime: bool = True):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.depth = prefetch_depth
        self.use_runtime = use_runtime
        self._task = (api.task(synth_batch, name="data_prefetch")
                      if use_runtime else None)
        self._pending: Dict[int, object] = {}
        self._next = 0

    def _submit(self, step: int) -> None:
        if step not in self._pending:
            self._pending[step] = self._task(self.cfg, self.batch, self.seq,
                                             step, self.seed)

    def get(self, step: Optional[int] = None) -> Dict:
        step = self._next if step is None else step
        self._next = step + 1
        if not self.use_runtime:
            return synth_batch(self.cfg, self.batch, self.seq, step, self.seed)
        self._submit(step)
        for ahead in range(1, self.depth + 1):
            self._submit(step + ahead)
        fut = self._pending.pop(step)
        return api.wait_on(fut)
