"""Futures and versioned data registry.

RCOMPSs tracks every task parameter/result as a *datum* with an id and a
version (rendered ``dXvY`` in the paper's DAG figures).  A ``Future`` is a
lightweight handle to one ``(data_id, version)`` pair plus the task that
produces it.  The object store keeps the concrete values; versions exist so
that INOUT parameters get COMPSs-style renaming semantics (a task that
mutates datum ``d3`` produces ``d3v2`` while previously-submitted readers
still see ``d3v1``).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple


class TaskFailedError(RuntimeError):
    """Raised by ``wait_on`` when the producing task exhausted its retries."""

    def __init__(self, task_name: str, task_id: int, cause: BaseException):
        super().__init__(f"task {task_name}#{task_id} failed: {cause!r}")
        self.task_name = task_name
        self.task_id = task_id
        self.cause = cause


class Future:
    """Handle to the (eventual) value of ``data_id`` at ``version``."""

    __slots__ = ("data_id", "version", "producer_task", "_store")

    def __init__(self, data_id: int, version: int, producer_task: int, store: "ObjectStore"):
        self.data_id = data_id
        self.version = version
        self.producer_task = producer_task
        self._store = store

    @property
    def key(self) -> Tuple[int, int]:
        return (self.data_id, self.version)

    def done(self) -> bool:
        return self._store.is_ready(self.key)

    def result(self, timeout: Optional[float] = None) -> Any:
        return self._store.get(self.key, timeout=timeout)

    def __repr__(self) -> str:  # matches the paper's DAG edge labels
        return f"<Future d{self.data_id}v{self.version} by task#{self.producer_task}>"


class ObjectStore:
    """Thread-safe versioned value store.

    Values are indexed by ``(data_id, version)``.  ``put`` publishes a value
    (or an exception) and wakes waiters.  Location metadata (which *node* the
    bytes live on) feeds the locality-aware scheduler and the discrete-event
    simulator's transport model.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._values: Dict[Tuple[int, int], Any] = {}
        self._errors: Dict[Tuple[int, int], BaseException] = {}
        self._locations: Dict[Tuple[int, int], set] = {}
        self._nbytes: Dict[Tuple[int, int], int] = {}
        self._transfers = 0          # cross-domain reads observed
        self._transfer_bytes = 0
        self._next_data_id = 1

    # -- identity allocation -------------------------------------------------
    def new_data_id(self) -> int:
        with self._lock:
            did = self._next_data_id
            self._next_data_id += 1
            return did

    # -- publication ----------------------------------------------------------
    def put(self, key: Tuple[int, int], value: Any, node: Optional[int] = None) -> None:
        nbytes = getattr(value, "nbytes", 0)
        try:
            nbytes = int(nbytes)
        except Exception:
            nbytes = 0
        with self._cond:
            self._values[key] = value
            self._nbytes[key] = nbytes
            if node is not None:
                self._locations.setdefault(key, set()).add(node)
            self._cond.notify_all()

    def put_error(self, key: Tuple[int, int], err: BaseException) -> None:
        with self._cond:
            self._errors[key] = err
            self._cond.notify_all()

    # -- retrieval -------------------------------------------------------------
    def is_ready(self, key: Tuple[int, int]) -> bool:
        with self._lock:
            return key in self._values or key in self._errors

    def get(self, key: Tuple[int, int], timeout: Optional[float] = None) -> Any:
        with self._cond:
            if not self._cond.wait_for(
                lambda: key in self._values or key in self._errors, timeout=timeout
            ):
                raise TimeoutError(f"timed out waiting for d{key[0]}v{key[1]}")
            if key in self._errors:
                raise self._errors[key]
            return self._values[key]

    def get_nowait(self, key: Tuple[int, int]) -> Any:
        with self._lock:
            if key in self._errors:
                raise self._errors[key]
            return self._values[key]

    # -- locality / transfer metadata ------------------------------------------
    # Every datum records which address-space *domains* hold a copy (node ids
    # for the thread backend, worker-process ids for the process backend) and
    # its byte size, so scheduling policies can score ready tasks by resident
    # input *bytes* — across threads and across processes alike.
    def note_location(self, key: Tuple[int, int], node: int) -> None:
        with self._lock:
            held = self._locations.setdefault(key, set())
            if node not in held:
                if held:  # a new domain pulled a copy: that's a transfer
                    self._transfers += 1
                    self._transfer_bytes += self._nbytes.get(key, 0)
                held.add(node)

    def forget_node(self, node: int) -> None:
        """Drop a domain from every datum's residency set — the address
        space died (e.g. a node agent crashed).  Locality scoring stops
        steering reads there, and re-ships to its replacement count as
        fresh transfers in the ledger."""
        with self._lock:
            for held in self._locations.values():
                held.discard(node)

    def locations(self, key: Tuple[int, int]) -> set:
        with self._lock:
            return set(self._locations.get(key, ()))

    def nbytes(self, key: Tuple[int, int]) -> int:
        with self._lock:
            return self._nbytes.get(key, 0)

    def transfer_stats(self) -> Tuple[int, int]:
        """(cross-domain reads, bytes moved) — the transfer ledger."""
        with self._lock:
            return self._transfers, self._transfer_bytes

    # -- housekeeping ------------------------------------------------------------
    def evict(self, key: Tuple[int, int]) -> None:
        """Drop a value (garbage collection once all consumers ran)."""
        with self._lock:
            self._values.pop(key, None)
            self._locations.pop(key, None)
            self._nbytes.pop(key, None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._values)
