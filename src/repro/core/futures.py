"""Futures and versioned data registry.

RCOMPSs tracks every task parameter/result as a *datum* with an id and a
version (rendered ``dXvY`` in the paper's DAG figures).  A ``Future`` is a
lightweight handle to one ``(data_id, version)`` pair plus the task that
produces it.  The object store keeps the concrete values; versions exist so
that INOUT parameters get COMPSs-style renaming semantics (a task that
mutates datum ``d3`` produces ``d3v2`` while previously-submitted readers
still see ``d3v1``).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from .memory import (
    MemoryBudget,
    MemoryGovernor,
    SpilledValue,
    spill_to_file,
    spillable,
)


class TaskFailedError(RuntimeError):
    """Raised by ``wait_on`` when the producing task exhausted its retries."""

    def __init__(self, task_name: str, task_id: int, cause: BaseException):
        super().__init__(f"task {task_name}#{task_id} failed: {cause!r}")
        self.task_name = task_name
        self.task_id = task_id
        self.cause = cause


class Future:
    """Handle to the (eventual) value of ``data_id`` at ``version``."""

    __slots__ = ("data_id", "version", "producer_task", "_store")

    def __init__(self, data_id: int, version: int, producer_task: int, store: "ObjectStore"):
        self.data_id = data_id
        self.version = version
        self.producer_task = producer_task
        self._store = store

    @property
    def key(self) -> Tuple[int, int]:
        return (self.data_id, self.version)

    def done(self) -> bool:
        return self._store.is_ready(self.key)

    def result(self, timeout: Optional[float] = None) -> Any:
        return self._store.get(self.key, timeout=timeout)

    def __repr__(self) -> str:  # matches the paper's DAG edge labels
        return f"<Future d{self.data_id}v{self.version} by task#{self.producer_task}>"


class RemoteValue:
    """Placeholder for a datum whose bytes are resident on a cluster
    node, not on the scheduler (DESIGN.md §15).

    The producing agent kept the result in its node plane and the
    ``done`` reply carried only this descriptor: result ``token``, home
    ``node``, the node's data-plane ``addr`` (``host:port``) and the
    datum's ndarray byte count.  ``key`` is bound when the runtime
    publishes the output.  The scheduler only materializes the bytes on
    ``wait_on``/gather (through the store's installed fetcher); tasks
    consuming the datum on another node pull it peer-to-peer via a
    ``Fetch`` directive instead.
    """

    __slots__ = ("key", "token", "node", "addr", "nbytes")

    def __init__(self, token: int, node: int, addr: Optional[str],
                 nbytes: int, key: Optional[Tuple[int, int]] = None):
        self.key = key
        self.token = token
        self.node = node
        self.addr = addr
        self.nbytes = int(nbytes)

    def __repr__(self) -> str:
        k = f"d{self.key[0]}v{self.key[1]}" if self.key else "unbound"
        return (f"<RemoteValue {k} {self.nbytes}B on node {self.node} "
                f"({self.addr})>")


class ObjectStore:
    """Thread-safe versioned value store.

    Values are indexed by ``(data_id, version)``.  ``put`` publishes a value
    (or an exception) and wakes waiters.  Location metadata (which *node* the
    bytes live on) feeds the locality-aware scheduler and the discrete-event
    simulator's transport model.
    """

    def __init__(self):
        # reentrant: a put/get may trigger governed spill/fault paths that
        # re-enter store accounting from the same thread
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._values: Dict[Tuple[int, int], Any] = {}
        self._errors: Dict[Tuple[int, int], BaseException] = {}
        self._locations: Dict[Tuple[int, int], set] = {}
        self._nbytes: Dict[Tuple[int, int], int] = {}
        self._node_bytes: Dict[int, int] = {}   # resident bytes per domain
        self._transfers = 0          # cross-domain reads observed
        self._transfer_bytes = 0
        # source-attributed movement (DESIGN.md §15): bytes relayed
        # through the scheduler's own link vs moved peer-to-peer between
        # node data planes (booked against the actual source node)
        self._relay_bytes = 0
        self._p2p_bytes = 0
        self._p2p_by_source: Dict[int, int] = {}
        # node×node movement for the dashboard's transfer matrix
        # (DESIGN.md §17): (src, dst) -> bytes, src == -1 meaning the
        # scheduler's own link (relay).  Invariant: summing src >= 0
        # entries gives _p2p_bytes; summing src == -1 gives _relay_bytes.
        self._transfer_matrix: Dict[Tuple[int, int], int] = {}
        self._gathers = 0            # RemoteValues materialized scheduler-side
        self._gather_bytes = 0
        # installed by the cluster executor: fetcher(key, rv) -> value
        self._fetcher: Optional[Callable[[Tuple[int, int], RemoteValue], Any]] = None
        self._fetching: set = set()   # keys with a gather pull in flight
        self._next_data_id = 1
        self.governor: Optional[MemoryGovernor] = None
        self._spill_dir: Optional[str] = None
        self._spill_min: Optional[int] = None
        # bumped on every residency/budget-relevant change (a key gaining a
        # domain, a spill, an evict, a node reset); the locality scheduler
        # keys its per-node placement caches off this (DESIGN.md §14)
        self.residency_epoch = 0

    # -- memory governance (DESIGN.md §13) ------------------------------------
    def configure_memory(self, budget, spill_dir: Optional[str] = None,
                         high_frac: float = 0.9, low_frac: float = 0.7,
                         min_bytes: Optional[int] = None) -> None:
        """Bound this store: values past the high watermark spill to
        mmap-codec files (coldest first) and fault back as zero-copy
        ``np.memmap`` views on the next read.  ``budget`` of ``None``/0
        disables governance (the pre-§13 behaviour)."""
        from .memory import parse_bytes
        cap = parse_bytes(budget)
        if cap is None:
            self.governor = None
            return
        self._spill_dir = spill_dir
        self._spill_min = min_bytes
        self.governor = MemoryGovernor(
            MemoryBudget(cap, high_frac, low_frac), self._spill_key,
            name="store")

    def _spill_key(self, key: Tuple[int, int]) -> int:
        """Governor callback: replace a resident array with its on-disk
        form.  Returns bytes freed (0 = not spillable right now)."""
        value = self._values.get(key)
        if not spillable(value, self._spill_min):
            return 0
        try:
            spilled = spill_to_file(value, prefix=f"rjax_store_d{key[0]}v{key[1]}_",
                                    dir=self._spill_dir)
        except Exception:
            return 0
        self._values[key] = spilled
        self.residency_epoch += 1
        return value.nbytes

    def _maybe_fault(self, key: Tuple[int, int], value: Any) -> Any:
        """Transparent fault path: a spilled entry is read back as a
        read-only memmap view and stays resident in that (file-backed,
        kernel-reclaimable) form."""
        if isinstance(value, SpilledValue):
            view = value.load()
            self._values[key] = view
            if self.governor is not None:
                self.governor.fault(key, value.nbytes)
            return view
        if self.governor is not None:
            self.governor.touch(key)
        return value

    # -- identity allocation -------------------------------------------------
    def new_data_id(self) -> int:
        with self._lock:
            did = self._next_data_id
            self._next_data_id += 1
            return did

    def new_data_ids(self, n: int) -> range:
        """Allocate ``n`` consecutive data ids under one lock acquisition
        (fan-out submission)."""
        with self._lock:
            first = self._next_data_id
            self._next_data_id += n
            return range(first, first + n)

    # -- publication ----------------------------------------------------------
    def put(self, key: Tuple[int, int], value: Any, node: Optional[int] = None) -> None:
        nbytes = getattr(value, "nbytes", 0)
        try:
            nbytes = int(nbytes)
        except Exception:
            nbytes = 0
        with self._cond:
            self._values[key] = value
            self._nbytes[key] = nbytes
            if node is not None:
                held = self._locations.setdefault(key, set())
                if node not in held:
                    held.add(node)
                    self._node_bytes[node] = self._node_bytes.get(node, 0) + nbytes
                    self.residency_epoch += 1
            if self.governor is not None and spillable(value, self._spill_min):
                self.governor.admit(key, nbytes)
            self._cond.notify_all()

    def put_error(self, key: Tuple[int, int], err: BaseException) -> None:
        with self._cond:
            self._errors[key] = err
            self._cond.notify_all()

    # -- retrieval -------------------------------------------------------------
    def is_ready(self, key: Tuple[int, int]) -> bool:
        with self._lock:
            return key in self._values or key in self._errors

    def set_fetcher(self, fetcher: Optional[Callable]) -> None:
        """Install the scheduler-side materializer for
        :class:`RemoteValue` placeholders:
        ``fetcher(key, rv, timeout) -> value`` pulls the bytes from the
        producing node's data plane (``timeout`` of None = the fetcher's
        own default)."""
        self._fetcher = fetcher

    def _materialize(self, key: Tuple[int, int], rv: RemoteValue,
                     timeout: Optional[float] = None) -> Any:
        """Pull a node-resident datum to the scheduler (gather path).
        Runs OUTSIDE the store lock — a peer fetch must never stall
        completions publishing other keys.  Always clears the key's
        single-flight mark and wakes waiters on the way out."""
        try:
            if self._fetcher is None:
                raise RuntimeError(
                    f"cannot materialize {rv!r}: no remote fetcher installed")
            value = self._fetcher(key, rv, timeout)
            with self._cond:
                if self._values.get(key) is rv:
                    self._values[key] = value
                    self._gathers += 1
                    self._gather_bytes += rv.nbytes
                    if self.governor is not None \
                            and spillable(value, self._spill_min):
                        self.governor.admit(key, getattr(value, "nbytes", 0))
            return value
        finally:
            with self._cond:
                self._fetching.discard(key)
                self._cond.notify_all()

    def get(self, key: Tuple[int, int], timeout: Optional[float] = None,
            materialize: bool = True) -> Any:
        """Blocking read.  ``materialize=False`` returns node-resident
        datums as their :class:`RemoteValue` placeholder (the cluster
        dispatch path, which moves metadata only); the default pulls the
        bytes to the scheduler.  A placeholder whose home node died is
        invalidated by the recovery path — waiters simply keep waiting
        until the resurrected producer re-publishes."""
        deadline = None if timeout is None else time.monotonic() + timeout
        attempts = 0
        while True:
            with self._cond:
                remaining = None if deadline is None else \
                    max(0.0, deadline - time.monotonic())
                if not self._cond.wait_for(
                    lambda: key in self._values or key in self._errors,
                    timeout=remaining,
                ):
                    raise TimeoutError(
                        f"timed out waiting for d{key[0]}v{key[1]}")
                if key in self._errors:
                    raise self._errors[key]
                value = self._values[key]
                if not (materialize and isinstance(value, RemoteValue)):
                    return self._maybe_fault(key, value)
                if key in self._fetching:
                    # single-flight: another thread is already pulling
                    # this datum — wait for its swap instead of paying a
                    # duplicate network transfer (still honoring OUR
                    # deadline: the in-flight fetch may be slower)
                    if deadline is not None:
                        left = deadline - time.monotonic()
                        if left <= 0:
                            raise TimeoutError(
                                f"timed out waiting for d{key[0]}v{key[1]}")
                        self._cond.wait(timeout=min(0.5, left))
                    else:
                        self._cond.wait(timeout=0.5)
                    continue
                self._fetching.add(key)
                rv = value
            remaining = None if deadline is None else \
                max(0.1, deadline - time.monotonic())
            try:
                return self._materialize(key, rv, remaining)
            except Exception:
                # the home node may have died mid-fetch: if recovery
                # already invalidated the placeholder, loop back into the
                # wait for the re-executed producer; otherwise retry a
                # couple of times before surfacing — but never past the
                # caller's deadline
                if deadline is not None and time.monotonic() >= deadline:
                    raise
                with self._lock:
                    still_same = self._values.get(key) is rv
                if still_same:
                    attempts += 1
                    if attempts >= 3:
                        raise
                    time.sleep(0.05 * attempts)

    def get_nowait(self, key: Tuple[int, int], materialize: bool = True) -> Any:
        """Non-blocking read — except that a present-but-node-resident
        datum with ``materialize=True`` inherently needs a network pull;
        that pull routes through :meth:`get` so concurrent callers share
        one single-flight transfer."""
        with self._lock:
            if key in self._errors:
                raise self._errors[key]
            value = self._values[key]   # KeyError when absent: the contract
            if not (materialize and isinstance(value, RemoteValue)):
                return self._maybe_fault(key, value)
        return self.get(key, materialize=True)

    # -- locality / transfer metadata ------------------------------------------
    # Every datum records which address-space *domains* hold a copy (node ids
    # for the thread backend, worker-process ids for the process backend) and
    # its byte size, so scheduling policies can score ready tasks by resident
    # input *bytes* — across threads and across processes alike.
    def note_location(self, key: Tuple[int, int], node: int,
                      source: Optional[int] = None) -> None:
        """Record that ``node`` now holds a copy of ``key``.  ``source``
        names the node the copy actually came from when the caller knows
        the transport (a broadcast/peer leg, DESIGN.md §16) — otherwise
        attribution falls back to inspecting the stored value."""
        with self._lock:
            held = self._locations.setdefault(key, set())
            if node not in held:
                nb = self._nbytes.get(key, 0)
                if held:  # a new domain pulled a copy: that's a transfer
                    self._transfers += 1
                    self._transfer_bytes += nb
                    # attribute the movement to its actual source: a
                    # node-resident datum moves peer-to-peer from its home
                    # node; anything else is relayed over the scheduler's
                    # own link (DESIGN.md §15) — unless the caller told us
                    # which peer served the bytes
                    v = self._values.get(key)
                    if source is not None and source != node:
                        self._p2p_bytes += nb
                        self._p2p_by_source[source] = (
                            self._p2p_by_source.get(source, 0) + nb)
                        self._matrix_add(source, node, nb)
                    elif isinstance(v, RemoteValue) and v.node != node:
                        self._p2p_bytes += nb
                        self._p2p_by_source[v.node] = (
                            self._p2p_by_source.get(v.node, 0) + nb)
                        self._matrix_add(v.node, node, nb)
                    else:
                        self._relay_bytes += nb
                        self._matrix_add(-1, node, nb)
                held.add(node)
                self._node_bytes[node] = (
                    self._node_bytes.get(node, 0) + nb)
                self.residency_epoch += 1

    def _matrix_add(self, src: int, dst: int, nb: int) -> None:
        if nb:
            self._transfer_matrix[(src, dst)] = (
                self._transfer_matrix.get((src, dst), 0) + nb)

    def reattribute_to_p2p(self, key: Tuple[int, int], source: int,
                           dest: Optional[int] = None) -> None:
        """Move one copy of ``key`` from the relay ledger to the p2p
        ledger.  Input residency is booked during task resolution, before
        the dispatcher knows the transport; when packing later turns the
        input into a by-key peer ``Fetch`` (DESIGN.md §16) the bytes never
        cross the scheduler link after all.  ``dest`` (the consuming
        node, when the caller knows it) keeps the node×node matrix in
        step with the aggregate split."""
        with self._lock:
            nb = self._nbytes.get(key, 0)
            moved = min(nb, self._relay_bytes)
            self._relay_bytes -= moved
            self._p2p_bytes += nb
            self._p2p_by_source[source] = (
                self._p2p_by_source.get(source, 0) + nb)
            if dest is not None:
                cell = self._transfer_matrix.get((-1, dest), 0)
                take = min(moved, cell)
                if take:
                    if cell - take:
                        self._transfer_matrix[(-1, dest)] = cell - take
                    else:
                        self._transfer_matrix.pop((-1, dest), None)
                self._matrix_add(source, dest, nb)

    def transfer_matrix(self) -> List[dict]:
        """JSON-friendly node×node movement matrix: one
        ``{"src", "dst", "bytes"}`` row per nonzero cell, ``src == -1``
        meaning the scheduler relayed the bytes (DESIGN.md §17)."""
        with self._lock:
            return [{"src": s, "dst": d, "bytes": b}
                    for (s, d), b in sorted(self._transfer_matrix.items())]

    def forget_node(self, node: int) -> None:
        """Drop a domain from every datum's residency set — the address
        space died (e.g. a node agent crashed).  Locality scoring stops
        steering reads there, re-ships to its replacement count as fresh
        transfers in the ledger, and the per-node *budget* ledger resets
        too (a replacement agent starts with empty memory: leaving the old
        byte count in place would starve the node of placements)."""
        with self._lock:
            for held in self._locations.values():
                held.discard(node)
            self._node_bytes[node] = 0
            self.residency_epoch += 1

    def node_bytes(self, node: int) -> int:
        """Resident governed bytes attributed to one locality domain —
        the scheduler's memory-aware placement reads this."""
        with self._lock:
            return self._node_bytes.get(node, 0)

    def locations(self, key: Tuple[int, int]) -> set:
        with self._lock:
            return set(self._locations.get(key, ()))

    def nbytes(self, key: Tuple[int, int]) -> int:
        with self._lock:
            return self._nbytes.get(key, 0)

    def transfer_stats(self) -> Tuple[int, int]:
        """(cross-domain reads, bytes moved) — the transfer ledger."""
        with self._lock:
            return self._transfers, self._transfer_bytes

    def transfer_detail(self) -> dict:
        """Source-attributed movement ledger (DESIGN.md §15):
        ``scheduler_relay_bytes`` crossed the scheduler's link,
        ``p2p_bytes`` moved directly between node data planes (broken
        down per source node), ``gather_bytes`` were materialized
        scheduler-side for ``wait_on``/gather."""
        with self._lock:
            return {
                "transfers": self._transfers,
                "transfer_bytes": self._transfer_bytes,
                "scheduler_relay_bytes": self._relay_bytes,
                "p2p_bytes": self._p2p_bytes,
                "p2p_by_source": dict(self._p2p_by_source),
                "matrix": [{"src": s, "dst": d, "bytes": b}
                           for (s, d), b in
                           sorted(self._transfer_matrix.items())],
                "gathers": self._gathers,
                "gather_bytes": self._gather_bytes,
            }

    # -- loss recovery (DESIGN.md §15) ----------------------------------------
    def homed_keys(self, node: int) -> List[Tuple[int, int]]:
        """Keys whose unmaterialized :class:`RemoteValue` is homed on
        ``node`` — what :meth:`invalidate_lost` would delete."""
        with self._lock:
            return [key for key, v in self._values.items()
                    if isinstance(v, RemoteValue) and v.node == node]

    def redirect_node(self, node: int,
                      replacements: Dict[Tuple[int, int], Tuple[int, str]]
                      ) -> List[Tuple[int, int]]:
        """Replica-hit recovery (DESIGN.md §20): node ``node`` is dead,
        but some of its placeholders have surviving copies — rehome each
        key in ``replacements`` (``key -> (replica_node, replica_addr)``)
        onto its replica, with a by-key token (``None``) so fetches
        resolve through the replica plane's key table.  Keys NOT in
        ``replacements`` are left for ``invalidate_lost`` + lineage.
        Returns the rehomed keys.  Pure dict work under the store lock —
        callers must pre-snapshot replica locations (no executor locks
        are taken here)."""
        out: List[Tuple[int, int]] = []
        with self._lock:
            for key, v in list(self._values.items()):
                if not (isinstance(v, RemoteValue) and v.node == node):
                    continue
                rep = replacements.get(key)
                if rep is None:
                    continue
                b, addr = rep
                self._values[key] = RemoteValue(None, b, addr, v.nbytes,
                                                key=key)
                out.append(key)
            if out:
                self.residency_epoch += 1
        return out

    def invalidate_lost(self, node: int) -> List[Tuple[int, int]]:
        """A node died: every unmaterialized :class:`RemoteValue` homed
        there is gone.  Drop those entries (readers block until the
        resurrected producers re-publish) and wipe their residency
        everywhere — consumers that already pulled a copy keep serving
        their own tasks from their planes, but placement and the
        transfer ledger must stop trusting stale locations.  Returns the
        lost keys for lineage re-execution."""
        with self._cond:
            keys = [key for key, v in self._values.items()
                    if isinstance(v, RemoteValue) and v.node == node]
            return self._invalidate_keys_locked(keys)

    def invalidate_keys(self, keys) -> List[Tuple[int, int]]:
        """Targeted form of :meth:`invalidate_lost` for placeholders that
        slipped into the store after their home node's sweep (a ``done``
        reply racing the crash)."""
        with self._cond:
            return self._invalidate_keys_locked(keys)

    def _invalidate_keys_locked(self, keys) -> List[Tuple[int, int]]:
        lost: List[Tuple[int, int]] = []
        for key in keys:
            if isinstance(self._values.get(key), RemoteValue):
                del self._values[key]
                lost.append(key)
                nb = self._nbytes.get(key, 0)
                for holder in self._locations.pop(key, ()):
                    self._node_bytes[holder] = max(
                        0, self._node_bytes.get(holder, 0) - nb)
        if lost:
            self.residency_epoch += 1
        return lost

    def memory_stats(self) -> dict:
        """The spill/fault side of the ledger (zeros when ungoverned)."""
        if self.governor is not None:
            return self.governor.stats()
        return {"budget_bytes": None, "bytes_used": 0, "peak_bytes": 0,
                "spills": 0, "faults": 0, "spill_bytes": 0,
                "fault_bytes": 0, "governed_entries": 0}

    def dispose_spills(self) -> None:
        """Unlink every still-spilled entry's file (runtime shutdown).
        Faulted views clean up after themselves — their files unlink at
        view GC — but a value that was spilled and never read again
        would otherwise leave its temp file behind."""
        with self._lock:
            for key, value in list(self._values.items()):
                if isinstance(value, SpilledValue):
                    value.dispose()
                    del self._values[key]

    # -- housekeeping ------------------------------------------------------------
    def evict(self, key: Tuple[int, int]) -> None:
        """Drop a value (garbage collection once all consumers ran)."""
        with self._lock:
            value = self._values.pop(key, None)
            if isinstance(value, SpilledValue):
                value.dispose()
            if self.governor is not None:
                self.governor.release(key)
            nbytes = self._nbytes.pop(key, 0)
            for node in self._locations.pop(key, ()):
                self._node_bytes[node] = max(
                    0, self._node_bytes.get(node, 0) - nbytes)
            self.residency_epoch += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._values)
