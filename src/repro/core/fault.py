"""Fault-tolerance policies (paper §3.1/§6: task resubmission, exception
management) plus beyond-paper straggler speculation and liveness failure
detection (DESIGN.md §19).

*Resubmission*: a task raising an exception is re-queued up to
``max_retries`` times; only after exhausting retries does the failure become
permanent, at which point the error is published on the task's outputs and
propagates to all transitive dependents (which fail fast without retrying —
their inputs are poisoned, re-running them cannot help).  Re-queueing waits
:meth:`RetryPolicy.delay_for` first: exponential backoff with bounded
jitter, folded with the §15 lost-input recovery pacing so a task whose
inputs died with a node never storms the rebuilding store.

*Speculation* (straggler mitigation, DESIGN.md §3): a monitor re-launches a
duplicate of any *pure* task whose running time exceeds
``factor ×`` the median duration of completed tasks of the same name, when
idle capacity exists.  First completion wins; the loser is discarded.  This
is the classic LATE/Dryad mitigation adapted to the COMPSs task model.

*Liveness* (DESIGN.md §19): every crash-recovery path in the cluster
backend is triggered by a TCP disconnect (``AgentChannel.on_close``).  An
agent that wedges without dying — SIGSTOP, pathological swap/GC stall, a
half-open connection after a partition — never disconnects, so before this
layer the job hung forever.  :class:`FailureDetector` is the scheduler-side
timeout detector over the PR 7 heartbeat plane: per node it tracks the last
beat (install time counts as a synthetic first beat so a node stopped at
birth is still caught) and classifies ``alive → suspect → dead`` by beat
age against :class:`LivenessConfig`.  The detector never repairs anything
itself: the executor closes a dead node's channel, which fires the
*existing* ``on_close`` → respawn → §15 lineage path, so recovery semantics
stay single-sourced no matter how the failure was noticed.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"


@dataclass(frozen=True)
class RetryPolicy:
    max_retries: int = 0          # default per-task; task() can override
    retry_on: tuple = (Exception,)
    backoff_seconds: float = 0.0  # base delay before re-queueing attempt 2
    backoff_factor: float = 2.0   # exponential growth per further attempt
    backoff_max: float = 30.0     # cap on the exponential term
    jitter: float = 0.25          # uniform extra, as a fraction of the delay

    def should_retry(self, attempts: int, max_retries: int, err: BaseException) -> bool:
        if attempts > max_retries:
            return False
        return isinstance(err, self.retry_on)

    def delay_for(self, attempts: int, *, lost_input: bool = False,
                  lost_input_pace: float = 0.25,
                  rng: Callable[[], float] = random.random) -> float:
        """Seconds to wait before re-queueing after failed attempt
        ``attempts`` (1-based).  The exponential term is
        ``backoff_seconds * backoff_factor**(attempts-1)`` capped at
        ``backoff_max``; lost-input failures are additionally paced by at
        least ``min(1.0, lost_input_pace * attempts)`` so retries don't
        race §15 lineage rebuilds even with ``backoff_seconds=0``.  Jitter
        adds up to ``jitter`` fraction on top (never subtracts), so the
        result is always >= the deterministic floor — the property the
        backoff regression test pins.
        """
        base = 0.0
        if self.backoff_seconds > 0.0 and attempts >= 1:
            base = min(self.backoff_max,
                       self.backoff_seconds *
                       self.backoff_factor ** (attempts - 1))
        if lost_input:
            base = max(base, min(1.0, lost_input_pace * max(1, attempts)))
        if base > 0.0 and self.jitter > 0.0:
            base += base * self.jitter * rng()
        return base


@dataclass(frozen=True)
class SpeculationConfig:
    enabled: bool = False
    factor: float = 3.0          # running > factor * median(same-name) => straggler
    min_samples: int = 3         # need this many completions to trust the median
    min_seconds: float = 0.05    # never speculate below this absolute runtime
    poll_interval: float = 0.02  # monitor period


@dataclass(frozen=True)
class LivenessConfig:
    """Scheduler-side failure-detector knobs (``runtime_start(liveness=,
    suspicion_s=)`` / ``RJAX_LIVENESS`` / ``RJAX_SUSPICION_S``)."""

    enabled: bool = True
    suspicion_s: float = 5.0     # beat age after which a node is suspect
    dead_factor: float = 2.0     # dead at suspicion_s * dead_factor
    min_grace_beats: float = 3.0 # never suspect before this many beat periods

    @property
    def dead_s(self) -> float:
        return self.suspicion_s * self.dead_factor


@dataclass
class _NodeView:
    last_beat: float             # monotonic time of last heartbeat (or install)
    beats: int = 0
    state: str = ALIVE
    deadline_at: Optional[float] = None   # oldest in-flight request's deadline


class FailureDetector:
    """Timeout-style liveness detector over heartbeat ages and in-flight
    request deadlines.  Pure bookkeeping + classification: thread-safe,
    no timers of its own — the executor's monitor loop calls
    :meth:`assess` and acts on ``dead`` verdicts.
    """

    def __init__(self, cfg: LivenessConfig, heartbeat_s: float,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.heartbeat_s = float(heartbeat_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._nodes: Dict[int, _NodeView] = {}
        # a node must miss at least suspicion_s AND min_grace_beats beat
        # periods before suspicion — guards against false kills when the
        # configured suspicion window is tighter than the beat cadence
        self._suspect_age = max(cfg.suspicion_s,
                                cfg.min_grace_beats * self.heartbeat_s)
        self._dead_age = max(cfg.dead_s,
                             cfg.min_grace_beats * self.heartbeat_s)

    @property
    def active(self) -> bool:
        """Heartbeats off means beat age carries no information."""
        return self.cfg.enabled and self.heartbeat_s > 0.0

    # ------------------------------------------------------------- feeding
    def note_install(self, node: int) -> None:
        """A (re)spawned node's channel went live: install time counts as
        a synthetic beat so a node wedged at birth still ages out."""
        with self._lock:
            self._nodes[node] = _NodeView(last_beat=self._clock())

    def note_beat(self, node: int) -> None:
        with self._lock:
            view = self._nodes.get(node)
            if view is None:
                view = self._nodes[node] = _NodeView(last_beat=0.0)
            view.last_beat = self._clock()
            view.beats += 1

    def note_deadline(self, node: int, deadline_at: Optional[float]) -> None:
        """Earliest in-flight request deadline on ``node`` (monotonic
        timestamp), or ``None`` when nothing in flight carries one."""
        with self._lock:
            view = self._nodes.get(node)
            if view is not None:
                view.deadline_at = deadline_at

    def note_removed(self, node: int) -> None:
        """Channel went down (crash or verdict acted upon): forget the
        node until its replacement is installed."""
        with self._lock:
            self._nodes.pop(node, None)

    # ----------------------------------------------------------- verdicts
    def assess(self, node: int) -> str:
        """Classify one node right now; updates its recorded state."""
        now = self._clock()
        with self._lock:
            view = self._nodes.get(node)
            if view is None:
                return DEAD
            state = ALIVE
            if self.active:
                age = now - view.last_beat
                if age > self._dead_age:
                    state = DEAD
                elif age > self._suspect_age:
                    state = SUSPECT
            if (state != DEAD and view.deadline_at is not None
                    and now > view.deadline_at):
                # an in-flight request sailed past its deadline (plus the
                # executor's slack): the node is wedged even if it beats
                state = DEAD
            view.state = state
            return state

    def snapshot(self) -> Dict[int, dict]:
        """Per-node liveness view for telemetry (`/api/status`)."""
        now = self._clock()
        with self._lock:
            return {
                node: {
                    "state": view.state,
                    "beat_age_s": round(now - view.last_beat, 3),
                    "beats": view.beats,
                }
                for node, view in self._nodes.items()
            }


class PoisonedInputError(RuntimeError):
    """A dependency failed permanently; this task cannot run."""

    def __init__(self, dep_task: int, cause: BaseException):
        super().__init__(f"input produced by failed task#{dep_task}: {cause!r}")
        self.dep_task = dep_task
        self.cause = cause
