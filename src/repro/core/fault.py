"""Fault-tolerance policies (paper §3.1/§6: task resubmission, exception
management) plus beyond-paper straggler speculation.

*Resubmission*: a task raising an exception is re-queued up to
``max_retries`` times; only after exhausting retries does the failure become
permanent, at which point the error is published on the task's outputs and
propagates to all transitive dependents (which fail fast without retrying —
their inputs are poisoned, re-running them cannot help).

*Speculation* (straggler mitigation, DESIGN.md §3): a monitor re-launches a
duplicate of any *pure* task whose running time exceeds
``factor ×`` the median duration of completed tasks of the same name, when
idle capacity exists.  First completion wins; the loser is discarded.  This
is the classic LATE/Dryad mitigation adapted to the COMPSs task model.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RetryPolicy:
    max_retries: int = 0          # default per-task; task() can override
    retry_on: tuple = (Exception,)
    backoff_seconds: float = 0.0  # optional delay between attempts

    def should_retry(self, attempts: int, max_retries: int, err: BaseException) -> bool:
        if attempts > max_retries:
            return False
        return isinstance(err, self.retry_on)


@dataclass(frozen=True)
class SpeculationConfig:
    enabled: bool = False
    factor: float = 3.0          # running > factor * median(same-name) => straggler
    min_samples: int = 3         # need this many completions to trust the median
    min_seconds: float = 0.05    # never speculate below this absolute runtime
    poll_interval: float = 0.02  # monitor period


class PoisonedInputError(RuntimeError):
    """A dependency failed permanently; this task cannot run."""

    def __init__(self, dep_task: int, cause: BaseException):
        super().__init__(f"input produced by failed task#{dep_task}: {cause!r}")
        self.dep_task = dep_task
        self.cause = cause
