"""Pluggable scheduling policies (paper §3.1: FIFO, LIFO, locality-aware).

The scheduler owns the ready set.  Worker threads call ``take(worker)``,
which blocks until a task is available (or the runtime drains).  Policies
differ only in *which* ready task a worker receives:

* ``fifo``      — submission order (COMPSs default).
* ``lifo``      — most recently readied first (depth-first; smaller memory
                  footprint for wide fan-outs).
* ``locality``  — prefer the ready task with the most input bytes already
                  resident on the worker's node (COMPSs data-locality-aware
                  policy).  Domains follow the executor backend: one per
                  node under ``thread``, per worker process under
                  ``process``, per TCP node agent under ``cluster`` —
                  where a miss costs a real wire transfer (DESIGN.md §12).
                  Under the peer data plane (DESIGN.md §15) the store's
                  location sets reflect TRUE node residency of unfetched
                  results (``RemoteValue`` placeholders carry their home
                  node and every peer pull adds the puller's domain), so
                  the same score now steers consumers at the node that
                  physically holds the bytes — a hit costs zero wire
                  crossings, a miss one peer hop instead of a scheduler
                  relay.
                  With a per-node memory budget configured (DESIGN.md §13)
                  the policy is additionally *memory-aware*: the placement
                  score subtracts the projected input+output bytes that
                  would exceed the node's remaining budget, so tasks flow
                  to nodes with both the data and the headroom.
* ``worksteal`` — per-worker deques; owner pops LIFO, thieves steal FIFO.
                  Beyond-paper addition used for straggler mitigation.

Hot-path accounting (DESIGN.md §14): ``queue_len`` reads an incrementally
maintained counter (no per-poll deque sweep), ``push_many`` wakes exactly
as many waiters as it enqueued tasks, and the ``locality`` policy keeps a
per-node cache of placement scores that is invalidated by the store's
residency epoch (``note_location``/spill/evict) instead of rescoring the
whole window on every pop — O(1) amortized per take while residency is
stable.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, List, Optional, Tuple

from .dag import TaskGraph
from .futures import ObjectStore

# weight of the memory-overflow penalty relative to the locality score
# (which lives in [0, 1]).  > 1 so a fully-local task on a node with NO
# headroom scores below a fully-remote task on a node with room: paying
# the transfer beats spilling the node's working set.
MEMORY_PENALTY = 1.5

# locality scan window over the head of the ready queue
LOCALITY_WINDOW = 64

# score bonus for a task's hinted node (collectives pin merges where the
# larger child is resident, DESIGN.md §16).  The hint augments the
# locality fraction rather than overriding it: a hinted node that also
# holds the inputs is unbeatable, a hinted node with nothing resident
# still loses to a fully-local unhinted one only when the bonus is < 1.
HINT_BONUS = 0.75

# a per-node score cache larger than this is reset wholesale (entries for
# tasks popped by *other* nodes linger until the next residency epoch)
_SCORE_CACHE_MAX = 4096


class Scheduler:
    def __init__(
        self,
        graph: TaskGraph,
        store: ObjectStore,
        policy: str = "fifo",
        workers_per_node: int = 1,
        node_budget: Optional[int] = None,
    ):
        if policy not in ("fifo", "lifo", "locality", "worksteal"):
            raise ValueError(f"unknown scheduling policy: {policy}")
        self.policy = policy
        self.graph = graph
        self.store = store
        self.workers_per_node = max(1, workers_per_node)
        # per-node memory capacity for memory-aware placement (None =
        # unbounded: pure locality, the pre-§13 behaviour)
        self.node_budget = node_budget
        self._out_bytes: Dict[str, int] = {}   # task name -> output-size EMA
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: collections.deque = collections.deque()
        self._local_queues: Dict[int, collections.deque] = collections.defaultdict(
            collections.deque
        )
        self._qsize = 0          # incrementally-maintained total (all queues)
        # per-node locality caches: node -> (store epoch, {tid: score entry})
        self._loc_cache: Dict[int, Tuple[int, Dict[int, tuple]]] = {}
        # placement hints: task id -> preferred node (DESIGN.md §16); set
        # before the task is pushed, consumed when it is taken
        self._hints: Dict[int, int] = {}
        self._closed = False
        # ready hook (DESIGN.md §18): the async control plane sets this
        # to re-enter its dispatch pump when tasks become ready — there
        # are no dispatcher threads parked in take() to notify.  Fired
        # OUTSIDE the scheduler lock (the hook schedules loop work).
        self.on_ready: Optional[Callable[[], None]] = None

    # ------------------------------------------------------------------ admin
    def node_of(self, worker: int) -> int:
        return worker // self.workers_per_node

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def queue_len(self) -> int:
        # incrementally maintained; a bare int read is atomic under the GIL,
        # so the speculation poll never touches the scheduler lock
        return self._qsize

    def set_hint(self, task_id: int, node: int) -> None:
        """Pin a placement preference for ``task_id`` (collectives tree
        placement).  Must be called before the task is pushed; only the
        ``locality`` policy honors it — elsewhere it is inert."""
        with self._lock:
            self._hints[task_id] = node

    # ---------------------------------------------------------------- enqueue
    def push(self, task_id: int, preferred_worker: Optional[int] = None) -> None:
        with self._cond:
            if self.policy == "worksteal" and preferred_worker is not None:
                self._local_queues[preferred_worker].append(task_id)
            else:
                self._queue.append(task_id)
            self._qsize += 1
            self._cond.notify()
        cb = self.on_ready
        if cb is not None:
            cb()

    def push_many(self, task_ids: List[int]) -> None:
        if not task_ids:
            return
        with self._cond:
            self._queue.extend(task_ids)
            self._qsize += len(task_ids)
            # wake exactly as many waiters as there are new tasks: a
            # notify_all here stampedes every idle dispatcher through the
            # lock only for most to go back to sleep
            self._cond.notify(len(task_ids))
        cb = self.on_ready
        if cb is not None:
            cb()

    # ------------------------------------------------------------------- take
    def take(self, worker: int, timeout: Optional[float] = None) -> Optional[int]:
        """Blocking pop according to the policy. None => scheduler closed or
        timeout expired with nothing to run."""
        with self._cond:
            while True:
                tid = self._select(worker)
                if tid is not None:
                    self._qsize -= 1
                    self._hints.pop(tid, None)
                    return tid
                if self._closed:
                    return None
                if not self._cond.wait(timeout=timeout):
                    return None

    def _select(self, worker: int) -> Optional[int]:
        if self.policy == "fifo":
            if self._queue:
                return self._queue.popleft()
            return None
        if self.policy == "lifo":
            if self._queue:
                return self._queue.pop()
            return None
        if self.policy == "worksteal":
            own = self._local_queues[worker]
            if own:
                return own.pop()  # owner: LIFO (hot cache)
            if self._queue:
                return self._queue.popleft()
            # steal: oldest task from the longest victim queue
            victim = max(
                (q for w, q in self._local_queues.items() if w != worker and q),
                key=len,
                default=None,
            )
            if victim:
                return victim.popleft()
            return None
        return self._select_locality(worker)

    def _select_locality(self, worker: int) -> Optional[int]:
        """Pick the best-placed task in the window using the per-node score
        cache: a (task, node) pair is scored at most once per residency
        epoch, so steady-state pops only rescore what actually changed."""
        if not self._queue:
            return None
        node = self.node_of(worker)
        epoch = self.store.residency_epoch
        cached = self._loc_cache.get(node)
        if cached is None or cached[0] != epoch:
            cached = (epoch, {})
            self._loc_cache[node] = cached
        scores = cached[1]
        if len(scores) > _SCORE_CACHE_MAX:
            scores.clear()
        window = min(len(self._queue), LOCALITY_WINDOW)
        best_i, best_score = 0, float("-inf")
        for i in range(window):
            tid = self._queue[i]
            score = scores.get(tid)
            if score is None:
                score = self._placement_score(tid, node)
                scores[tid] = score
            if score > best_score:
                best_i, best_score = i, score
                if best_score >= 1.0 and not self._hints:
                    break   # fully local, no overflow — can't be beaten
                    # (an outstanding hint could still outscore this)
        self._queue.rotate(-best_i)
        tid = self._queue.popleft()
        self._queue.rotate(best_i)
        scores.pop(tid, None)
        return tid

    # ------------------------------------------------- placement scoring
    def note_output_bytes(self, name: str, nbytes: int) -> None:
        """Feed back an observed output size so projections for future
        tasks of the same name track reality (simple half-life EMA)."""
        with self._lock:
            prev = self._out_bytes.get(name)
            self._out_bytes[name] = int(nbytes) if prev is None \
                else (prev + int(nbytes)) // 2

    def _placement_score(self, task_id: int, node: int) -> float:
        """Locality score minus a memory-overflow penalty (DESIGN.md §13).

        Projected footprint of running the task on ``node`` = bytes of
        inputs *not yet resident* there (they would have to be pulled in)
        plus the projected output (EMA of past outputs of the same task
        name).  The fraction of that projection exceeding the node's
        remaining budget, weighted by :data:`MEMORY_PENALTY`, comes off
        the locality score — so tasks drift to nodes with headroom, but
        a worker with nothing better to do still makes progress (the
        budget is a gradient, not an admission check)."""
        t = self.graph.get(task_id)
        score, nonlocal_b = self._locality_score(t, node)
        if self._hints.get(task_id) == node:
            score += HINT_BONUS
        if self.node_budget:
            projected = nonlocal_b + self._out_bytes.get(t.name, 0)
            if projected > 0:
                remaining = max(0, self.node_budget - self.store.node_bytes(node))
                overflow = max(0, projected - remaining)
                score -= MEMORY_PENALTY * overflow / projected
        return score

    def _locality_score(self, t, node: int):
        """(fraction of input *bytes* already resident in this worker's
        address-space domain, non-resident input bytes).  Falls back to
        input count when sizes are unknown, e.g. scalars."""
        if not t.dep_keys:
            return 0.0, 0
        total_b = local_b = 0
        local_n = 0
        for key in t.dep_keys:
            b = self.store.nbytes(key)
            total_b += b
            if node in self.store.locations(key):
                local_n += 1
                local_b += b
        if total_b > 0:
            return local_b / total_b, total_b - local_b
        return local_n / len(t.dep_keys), 0
