"""Zero-dependency live dashboard (DESIGN.md §17).

A stdlib ``http.server`` serving the three views dask's monitors proved
out — task-stream timeline, per-node memory-vs-budget gauges, node×node
transfer matrix — as one embedded HTML page polling JSON endpoints:

* ``/api/status``    — runtime identity, task counters, per-node
  heartbeat view (memory, occupancy, in-flight depth)
* ``/api/tasks``     — task-lifecycle ring events (``?since=<seq>`` for
  incremental polling, ``?limit=<n>`` to cap)
* ``/api/transfers`` — node×node byte matrix from the §15 p2p ledger
* ``/api/trace``     — the full Chrome-trace JSON (open in Perfetto)

Enable with ``runtime_start(dashboard_port=8787)`` (0 = ephemeral port)
or ``RJAX_DASHBOARD=8787``.  The server runs a daemon thread pool and
never blocks the scheduler: every endpoint renders from the telemetry
hub's lock-guarded snapshots.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import urlparse, parse_qs

_PAGE = """<!doctype html>
<html><head><meta charset="utf-8"><title>rjax dashboard</title>
<style>
 body{background:#14161a;color:#d8dde3;font:13px/1.45 system-ui,sans-serif;
      margin:0;padding:16px}
 h1{font-size:16px;margin:0 0 4px} h2{font-size:13px;color:#8b97a5;
      margin:18px 0 6px;text-transform:uppercase;letter-spacing:.06em}
 .meta{color:#8b97a5} .cards{display:flex;gap:10px;flex-wrap:wrap}
 .card{background:#1d2127;border:1px solid #2a2f37;border-radius:6px;
      padding:8px 12px;min-width:130px}
 .card .v{font-size:18px;color:#e8eef4} .card .k{color:#8b97a5;font-size:11px}
 canvas{background:#1d2127;border:1px solid #2a2f37;border-radius:6px;
      width:100%;height:220px;display:block}
 table{border-collapse:collapse} td,th{border:1px solid #2a2f37;
      padding:3px 9px;text-align:right} th{color:#8b97a5;font-weight:normal}
 .bar{background:#2a2f37;border-radius:3px;height:10px;width:180px;
      display:inline-block;vertical-align:middle;overflow:hidden}
 .bar i{display:block;height:100%;background:#4e9af1}
 .bar i.hot{background:#e06c5a}
 .ok{color:#6fc17a}.bad{color:#e06c5a}
</style></head><body>
<h1>rjax <span id="backend"></span> <span class="meta" id="ident"></span></h1>
<div class="meta" id="counters"></div>
<h2>Task stream</h2><canvas id="stream" width="1200" height="220"></canvas>
<div class="meta" id="streamlegend"></div>
<h2>Nodes</h2><div id="nodes"></div>
<h2>Transfer matrix (bytes)</h2><div id="transfers"></div>
<script>
const colors={}, palette=['#4e9af1','#6fc17a','#e0b05a','#b87ae0','#5ad0c8',
 '#e06c5a','#9aa9ff','#cddc6f'];
let nc=0;
function color(n){if(!(n in colors))colors[n]=palette[nc++%palette.length];
 return colors[n];}
let events=[], lastSeq=0;
function fmtB(b){if(b>1<<30)return (b/(1<<30)).toFixed(2)+' GiB';
 if(b>1<<20)return (b/(1<<20)).toFixed(1)+' MiB';
 if(b>1024)return (b/1024).toFixed(1)+' KiB';return b+' B';}
async function poll(){
 try{
  const st=await (await fetch('api/status')).json();
  document.getElementById('backend').textContent=st.backend;
  document.getElementById('ident').textContent=
   st.name+' · '+st.n_workers+' workers · up '+st.uptime_s.toFixed(0)+'s';
  const c=st.tasks||{};
  document.getElementById('counters').innerHTML=
   'tasks: <b>'+(c.done||0)+'</b> done · '+(c.running||0)+' running · '+
   (c.ready||0)+' ready · '+(c.waiting||0)+' waiting · '+
   '<span class="'+((c.failed||0)?'bad':'ok')+'">'+(c.failed||0)+
   ' failed</span> · queue '+st.queue_len+' · ring '+st.ring.size+'/'+
   st.ring.capacity+(st.ring.dropped?' ('+st.ring.dropped+' dropped)':'');
  renderNodes(st);
  const tk=await (await fetch('api/tasks?since='+lastSeq)).json();
  if(tk.events.length){events.push(...tk.events);lastSeq=tk.last_seq;
   if(events.length>4096)events=events.slice(-4096);}
  renderStream(tk.now);
  const tr=await (await fetch('api/transfers')).json();
  renderTransfers(tr);
 }catch(e){}
 setTimeout(poll,1000);
}
function renderStream(now){
 const cv=document.getElementById('stream'),g=cv.getContext('2d');
 g.clearRect(0,0,cv.width,cv.height);
 const done=events.filter(e=>e.kind=='done'||e.kind=='fail'||e.kind=='retry');
 if(!done.length)return;
 const span=15, t1=now, t0=t1-span;
 const lanes=[...new Set(done.map(e=>e.node+'/'+e.worker))].sort();
 const lh=Math.min(24,Math.max(6,(cv.height-16)/Math.max(1,lanes.length)));
 const names=new Set();
 g.font='10px sans-serif';
 lanes.forEach((ln,i)=>{g.fillStyle='#566070';
  g.fillText(ln,2,14+i*lh+lh*0.7);});
 for(const e of done){
  if(e.t1<t0)continue;
  const i=lanes.indexOf(e.node+'/'+e.worker);
  const x0=Math.max(0,(Math.max(e.t_run||e.t0,t0)-t0)/span*cv.width);
  const x1=Math.min(cv.width,(e.t1-t0)/span*cv.width);
  g.fillStyle=e.kind=='done'?color(e.name):'#e06c5a';
  g.fillRect(x0,16+i*lh,Math.max(1.5,x1-x0),lh-2);
  if(e.t_run&&e.t_run>e.t0){ // fetch/stall gap rendered dimmer
   const s0=Math.max(0,(Math.max(e.t0,t0)-t0)/span*cv.width);
   g.globalAlpha=0.25;g.fillRect(s0,16+i*lh,Math.max(1,x0-s0),lh-2);
   g.globalAlpha=1;}
  names.add(e.name);}
 document.getElementById('streamlegend').innerHTML='last '+span+'s · '+
  [...names].map(n=>'<span style="color:'+color(n)+'">■</span> '+n).join('  ');
}
function renderNodes(st){
 let h='<table><tr><th>node</th><th>state</th><th>heartbeats</th><th>age</th>'+
  '<th>in-flight</th><th>queued</th><th>memory</th><th>spills</th>'+
  '<th>p2p fetches</th><th>replicas</th></tr>';
 for(const [nid,n] of Object.entries(st.nodes)){
  const used=n.plane_bytes_used??n.plane_bytes??n.store_bytes_used??0;
  const budget=n.plane_budget_bytes??n.store_budget_bytes??0;
  const pct=budget?Math.min(100,100*used/budget):0;
  const sc={alive:'#5ad18b',suspect:'#e0b25a',dead:'#e06c5a',
   respawning:'#e0b25a',disconnected:'#e0b25a',
   reconnecting:'#4e9af1'}[n.state]||'#888';
  const state=n.state?'<span style="color:'+sc+'">'+n.state+'</span>'+
   (n.beat_age_s!=null?' <span class="meta">'+n.beat_age_s.toFixed(1)+
   's</span>':''):'-';
  h+='<tr><td>'+nid+'</td><td>'+state+'</td><td>'+n.heartbeats+'</td><td>'+
   (n.age_s!=null?n.age_s.toFixed(1)+'s':'-')+'</td><td>'+(n.inflight||0)+'</td><td>'+
   (n.queued??'-')+'</td><td><span class="bar"><i class="'+
   (pct>85?'hot':'')+'" style="width:'+pct+'%"></i></span> '+
   fmtB(used)+(budget?' / '+fmtB(budget):'')+'</td><td>'+
   (n.plane_spills??n.store_spills??0)+'</td><td>'+(n.p2p_fetches??0)+
   '</td><td>'+(n.replicas??0)+'</td></tr>';}
 document.getElementById('nodes').innerHTML=h+'</table>';
}
function renderTransfers(tr){
 const m=tr.matrix||[];
 if(!m.length){document.getElementById('transfers').innerHTML=
  '<span class="meta">no transfers yet</span>';return;}
 const ns=[...new Set(m.flatMap(e=>[e.src,e.dst]))].sort((a,b)=>a-b);
 const by={};m.forEach(e=>by[e.src+','+e.dst]=e.bytes);
 const mx=Math.max(...m.map(e=>e.bytes));
 let h='<table><tr><th>src\\\\dst</th>'+
  ns.map(n=>'<th>'+(n<0?'sched':n)+'</th>').join('')+'</tr>';
 for(const s of ns){h+='<tr><th>'+(s<0?'sched':s)+'</th>';
  for(const d of ns){const b=by[s+','+d]||0;
   const a=b?0.15+0.85*b/mx:0;
   h+='<td style="background:rgba(78,154,241,'+a.toFixed(2)+')">'+
    (b?fmtB(b):'·')+'</td>';}
  h+='</tr>';}
 h+='</table><div class="meta">p2p '+fmtB(tr.p2p_bytes)+
  ' · scheduler relay '+fmtB(tr.scheduler_relay_bytes)+'</div>';
 document.getElementById('transfers').innerHTML=h;
}
poll();
</script></body></html>
"""


class DashboardServer:
    """Serve the live dashboard for one runtime on ``host:port``.

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    All state comes from the runtime's :class:`TelemetryHub` snapshots,
    so requests never touch scheduler locks."""

    def __init__(self, runtime, port: int = 0, host: str = "127.0.0.1"):
        self.runtime = runtime
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):   # keep the terminal quiet
                pass

            def do_GET(self):
                try:
                    outer._route(self)
                except (BrokenPipeError, ConnectionResetError):
                    pass

        self._httpd = ThreadingHTTPServer((host, int(port)), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name=f"{runtime.name}-dashboard")
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/"

    def _route(self, handler) -> None:
        parsed = urlparse(handler.path)
        path = parsed.path.rstrip("/") or "/"
        rt, hub = self.runtime, self.runtime.telemetry
        if path == "/":
            body = _PAGE.encode()
            ctype = "text/html; charset=utf-8"
        elif path == "/api/status":
            body = self._json(hub.snapshot_status(rt))
            ctype = "application/json"
        elif path == "/api/tasks":
            q = parse_qs(parsed.query)
            since = int(q.get("since", ["0"])[0] or 0)
            limit = q.get("limit")
            limit = int(limit[0]) if limit else None
            body = self._json(hub.snapshot_tasks(rt, since=since, limit=limit))
            ctype = "application/json"
        elif path == "/api/transfers":
            body = self._json(hub.snapshot_transfers(rt))
            ctype = "application/json"
        elif path == "/api/trace":
            body = rt.tracer.to_chrome_trace().encode()
            ctype = "application/json"
        else:
            handler.send_response(404)
            handler.send_header("Content-Length", "0")
            handler.end_headers()
            return
        handler.send_response(200)
        handler.send_header("Content-Type", ctype)
        handler.send_header("Content-Length", str(len(body)))
        handler.end_headers()
        handler.wfile.write(body)

    @staticmethod
    def _json(obj) -> bytes:
        return json.dumps(obj, default=str).encode()

    def close(self) -> None:
        try:
            self._httpd.shutdown()
            self._httpd.server_close()
        except Exception:
            pass
