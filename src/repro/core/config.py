"""Unified runtime configuration (DESIGN.md §18).

One dataclass — :class:`RuntimeConfig` — declares every knob the runtime
understands: the ``runtime_start(...)`` keyword arguments *and* the
``RJAX_*`` environment variables that used to be scattered across the
modules that read them.  Each field carries its env-var name, built-in
default, cast, and doc string, so the README knob table is **generated**
from this file (``python -m repro.core.config``) rather than
hand-maintained, and ``tests/test_config.py`` asserts no module grows an
undeclared knob.

One precedence rule, applied everywhere (including the agent CLI)::

    explicit kwarg / CLI flag  >  env var  >  welcome-handshake value
                               >  built-in default

Evaluated **per process**: an agent's local env var outranks the value
the scheduler's welcome message carries (the welcome is how the
scheduler's *own* resolution propagates to agents that set nothing).
``resolve()`` is the single implementation of that rule; every consumer
(``Runtime``, ``NodeAgent``, the agent argparser) routes through it.

A ``RuntimeConfig`` field that is ``None`` means *unset* — resolution
falls through to the environment and the built-in default.  This is what
lets ``runtime_start(pipeline_depth=8)``, ``RJAX_PIPELINE_DEPTH=8`` and
the welcome handshake all land in the same place without the call sites
knowing which one fired.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass, field, fields
from typing import Any, Callable, Dict, Optional

__all__ = [
    "RuntimeConfig", "resolve", "knob_table", "declared_env_knobs",
    "parse_bool", "add_agent_cli_args",
]

_UNSET = None   # field value meaning "fall through to env/welcome/default"


# --------------------------------------------------------------------- casts
def parse_bool(value: Any) -> bool:
    """``RJAX_P2P=0`` / ``off`` / ``false`` / ``no`` are all false."""
    if isinstance(value, bool):
        return value
    if value is None:
        return False
    return str(value).strip().lower() not in ("0", "false", "off", "no", "")


def _parse_port(value: Any) -> Optional[int]:
    if value is None or value == "":
        return None
    return int(value)


def _parse_budget(value: Any):
    from .memory import parse_bytes
    return parse_bytes(value)


# --------------------------------------------------------------------- knobs
def knob(*, env: Optional[str] = None, default: Any = None,
         cast: Callable[[Any], Any] = None, doc: str = "",
         scope: str = "runtime", cli: Optional[str] = None):
    """Declare one configuration field.

    ``scope`` records where the knob is consumed, for the generated docs:
    ``runtime`` (a ``runtime_start`` kwarg, possibly env-backed), ``env``
    (read from the environment by a leaf module — still declared here so
    the orphan-knob test can see it), ``agent`` (also mirrored onto the
    ``repro.cluster.agent`` CLI), ``object`` (a live Python object that
    never crosses env/CLI, e.g. ``cluster``).
    """
    return field(default=_UNSET, metadata={
        "env": env, "default": default, "cast": cast, "doc": doc,
        "scope": scope, "cli": cli,
    })


def resolve(explicit: Any, env: Optional[str], welcome: Any = None,
            default: Any = None, cast: Callable[[Any], Any] = None) -> Any:
    """THE precedence rule: explicit > env var > welcome > default."""
    if explicit is not None:
        value = explicit
    elif env is not None and os.environ.get(env) not in (None, ""):
        value = os.environ[env]
    elif welcome is not None:
        value = welcome
    else:
        value = default
    if value is not None and cast is not None:
        value = cast(value)
    return value


@dataclass
class RuntimeConfig:
    """Every runtime knob in one place.  All fields default to *unset*
    (``None``); construct with only what you mean to pin::

        with runtime_start(config=RuntimeConfig(backend="cluster",
                                                n_agents=4)) as rt:
            ...
    """

    # -- core topology ----------------------------------------------------
    n_workers: Optional[int] = knob(
        default=4, cast=int,
        doc="Worker slots the runtime dispatches to (cluster backend: "
            "derived as n_agents x workers_per_node).")
    workers_per_node: Optional[int] = knob(
        default=None, cast=int,
        doc="Worker processes per node agent (cluster backend; default 2).")
    n_agents: Optional[int] = knob(
        default=None, cast=int,
        doc="Node agents a LocalCluster spawns (cluster backend; default 2).")
    backend: Optional[str] = knob(
        default="thread",
        doc="Executor backend: thread | process | cluster.")
    cluster: Optional[Any] = knob(
        default=None, scope="object",
        doc="Pre-built LocalCluster to adopt instead of spawning one.")
    policy: Optional[str] = knob(
        default="fifo",
        doc="Scheduling policy: fifo | lifo | worksteal | locality.")

    # -- retry / speculation ----------------------------------------------
    max_retries: Optional[int] = knob(
        default=0, cast=int,
        doc="Automatic re-submissions per failed task.")
    retry_backoff_s: Optional[float] = knob(
        env="RJAX_RETRY_BACKOFF_S", default=0.0, cast=float,
        doc="Base re-queue delay after a failed attempt; grows "
            "exponentially (x2 per attempt, capped at 30 s) with up to "
            "25% jitter.  0 = immediate (lost-input pacing still applies).")
    speculation: Optional[bool] = knob(
        default=False, cast=parse_bool,
        doc="Duplicate straggler tasks (first completion wins).")
    speculation_factor: Optional[float] = knob(
        default=3.0, cast=float,
        doc="A task is a straggler past factor x its name's mean duration.")

    # -- fault tolerance (DESIGN.md §19) ----------------------------------
    liveness: Optional[bool] = knob(
        env="RJAX_LIVENESS", default=True, cast=parse_bool,
        doc="Scheduler-side failure detector over heartbeat ages (cluster "
            "backend): a node silent past the suspicion window has its "
            "channel closed, driving the normal respawn/lineage recovery.")
    suspicion_s: Optional[float] = knob(
        env="RJAX_SUSPICION_S", default=5.0, cast=float,
        doc="Heartbeat age after which a node is suspect; dead (and "
            "recovered) at 2x this, never sooner than 3 beat periods.")
    deadline_s: Optional[float] = knob(
        env="RJAX_DEADLINE_S", default=None, cast=float,
        doc="Default per-task deadline: a task body running longer has "
            "its worker killed and fails retryable.  Per-call "
            "submit(deadline_s=) overrides; unset = no deadline.")
    resolve_timeout_s: Optional[float] = knob(
        env="RJAX_RESOLVE_TIMEOUT_S", default=30.0, cast=float,
        doc="Seconds a dispatch may wait for an input datum to resolve "
            "(spill fault-back, §15 lineage rebuild) before failing "
            "retryable.")
    reconnect_grace_s: Optional[float] = knob(
        env="RJAX_RECONNECT_GRACE_S", default=5.0, cast=float,
        doc="Seconds a disconnected agent is parked awaiting session "
            "resumption (DESIGN.md §20) before the scheduler falls back "
            "to respawn + lineage recovery.  0 disables resumption "
            "(every disconnect is treated as death, the pre-§20 "
            "behaviour).  Async control plane only.")
    replication: Optional[int] = knob(
        env="RJAX_REPLICATION", default=0, cast=int,
        doc="Replicas kept of expensive node-resident intermediates "
            "(DESIGN.md §20): results whose producer duration crosses "
            "the TaskGraph-derived threshold are pushed to k buddy "
            "nodes over the p2p plane, so node death recovers by "
            "refetch instead of lineage replay.  0 = off.")
    chaos: Optional[str] = knob(
        env="RJAX_CHAOS", default=None, scope="env",
        doc="Deterministic fault injection, '<seed>:<fault>[=arg][@rate],"
            "...' (repro.cluster.chaos); faults: delay, drop, stall, "
            "freeze, hang, fetch-slow, partition, bitflip.  Unset = "
            "zero-overhead no-op.")

    # -- memory -----------------------------------------------------------
    memory_budget: Optional[Any] = knob(
        env="RJAX_MEMORY_BUDGET", default=None, cast=_parse_budget,
        scope="agent", cli="--memory-budget",
        doc="Per-domain object-plane budget (e.g. 256M, 2G); unset = "
            "unbounded.  Welcome-propagated to agents that set nothing.")
    spill_dir: Optional[str] = knob(
        default=None,
        doc="Directory for spill files (default: the system tmpdir).")
    spill_min_bytes: Optional[int] = knob(
        env="RJAX_SPILL_MIN_BYTES", default=4096, cast=int, scope="env",
        doc="Smallest ndarray the memory governor will spill.")
    shm_min_bytes: Optional[int] = knob(
        env="RJAX_SHM_MIN_BYTES", default=16384, cast=int, scope="env",
        doc="Smallest ndarray shipped via shared-memory segments "
            "(process pool); smaller ones ride the pipe.")

    # -- dispatch pipeline ------------------------------------------------
    pipeline_depth: Optional[int] = knob(
        env="RJAX_PIPELINE_DEPTH", default=4, cast=int,
        doc="In-flight task credits per worker slot (DESIGN.md §14); "
            "1 = stop-and-wait.")
    control_plane: Optional[str] = knob(
        env="RJAX_CONTROL_PLANE", default="async",
        doc="Cluster scheduler comm layer: async (single event-loop "
            "thread, DESIGN.md §18) | threads (legacy reader thread per "
            "agent + dispatcher thread per slot).")
    lost_input_retries: Optional[int] = knob(
        env="RJAX_LOST_INPUT_RETRIES", default=3, cast=int, scope="env",
        doc="Extra retry budget for tasks whose inputs died with a node.")
    fn_cache_max: Optional[int] = knob(
        env="RJAX_FN_CACHE_MAX", default=512, cast=int, scope="env",
        doc="Deserialized-function cache entries per worker process.")
    graph_retain: Optional[int] = knob(
        env="RJAX_GRAPH_RETAIN", default=0, cast=int, scope="env",
        doc="Completed-task records kept for lineage (0 = automatic).")
    mp_context: Optional[str] = knob(
        env="RJAX_MP_CONTEXT", default="fork", scope="agent",
        cli="--mp-context",
        doc="multiprocessing start method for worker pools (fork | spawn).")

    # -- cluster wire / data plane ----------------------------------------
    inline_max: Optional[int] = knob(
        env="RJAX_INLINE_MAX", default=8192, cast=int,
        scope="agent", cli="--inline-max",
        doc="Results under this many bytes ride the reply inline; larger "
            "ones stay node-resident behind a RemoteRef (DESIGN.md §15).  "
            "Welcome-propagated.")
    p2p: Optional[bool] = knob(
        env="RJAX_P2P", default=True, cast=parse_bool,
        doc="Peer-to-peer data plane; 0 restores the all-relay star "
            "topology for A/B runs.  Welcome-propagated.")
    wire_coalesce: Optional[int] = knob(
        env="RJAX_WIRE_COALESCE", default=65536, cast=int, scope="env",
        doc="Messages up to this size are coalesced into one socket write "
            "(the async control plane batches consecutive small messages "
            "up to ~16x this per flush).")
    data_host: Optional[str] = knob(
        env="RJAX_DATA_HOST", default=None, scope="env",
        doc="Interface the agent data server binds/advertises "
            "(multi-homed deployments).")
    peer_fetch_timeout: Optional[float] = knob(
        env="RJAX_PEER_FETCH_TIMEOUT", default=60.0, cast=float, scope="env",
        doc="Seconds a peer pull may take before it fails as retryable.")
    wire_checksum: Optional[bool] = knob(
        env="RJAX_WIRE_CHECKSUM", default=False, cast=parse_bool,
        scope="env",
        doc="CRC32 trailer on every out-of-band array frame (control "
            "and data plane): a corrupted frame surfaces as a retryable "
            "transfer error instead of silent data corruption.  Off by "
            "default (overhead gated in bench_gate.py).")

    # -- telemetry ---------------------------------------------------------
    tracing: Optional[bool] = knob(
        default=True, cast=parse_bool,
        doc="Task-lifecycle tracer (Paraver/Chrome exports).")
    telemetry: Optional[bool] = knob(
        default=None, cast=parse_bool,
        doc="Live telemetry plane (DESIGN.md §17); default follows "
            "tracing.")
    heartbeat_s: Optional[float] = knob(
        env="RJAX_HEARTBEAT_S", default=1.0, cast=float,
        scope="agent", cli="--heartbeat-s",
        doc="Agent heartbeat cadence in seconds (0 disables).  "
            "Welcome-propagated.")
    telemetry_ring: Optional[int] = knob(
        env="RJAX_TELEMETRY_RING", default=4096, cast=int, scope="env",
        doc="Task-lifecycle ring capacity (events kept for /api/tasks).")
    dashboard_port: Optional[int] = knob(
        env="RJAX_DASHBOARD", default=None, cast=_parse_port,
        doc="Serve the live dashboard on this port (0 = ephemeral); "
            "unset = off.")

    # ------------------------------------------------------------------ api
    def resolved(self, name: str, welcome: Any = None) -> Any:
        """Resolve one field through the precedence rule."""
        f = _field_map()[name]
        return resolve(getattr(self, name), f.metadata["env"], welcome,
                       f.metadata["default"], f.metadata["cast"])

    def merged(self, **overrides: Any) -> "RuntimeConfig":
        """Copy with explicit (non-None) overrides applied on top —
        the ``runtime_start(config=..., pipeline_depth=8)`` shim."""
        known = _field_map()
        unknown = [k for k in overrides if k not in known]
        if unknown:
            raise TypeError(
                f"runtime_start() got unexpected keyword argument(s) "
                f"{', '.join(sorted(unknown))!s}; known knobs: "
                f"{', '.join(sorted(known))}")
        kept = {k: v for k, v in overrides.items() if v is not None}
        return dataclasses.replace(self, **kept)

    def runtime_kwargs(self) -> Dict[str, Any]:
        """The kwargs ``Runtime.__init__`` consumes, unset fields
        omitted (Runtime's own env-aware defaults then apply — same
        precedence, evaluated at the leaf)."""
        out = {}
        for name in ("n_workers", "workers_per_node", "policy", "tracing",
                     "backend", "cluster", "n_agents", "memory_budget",
                     "spill_dir", "pipeline_depth", "telemetry",
                     "dashboard_port", "control_plane", "inline_max",
                     "heartbeat_s", "p2p", "liveness", "suspicion_s",
                     "deadline_s", "resolve_timeout_s",
                     "reconnect_grace_s", "replication"):
            v = getattr(self, name)
            if v is not None:
                out[name] = v
        return out


def _field_map() -> Dict[str, dataclasses.Field]:
    return {f.name: f for f in fields(RuntimeConfig)}


def declared_env_knobs() -> Dict[str, str]:
    """``{env var name: field name}`` for every env-backed knob — the
    contract ``tests/test_config.py`` checks ``src/`` against."""
    return {f.metadata["env"]: f.name for f in fields(RuntimeConfig)
            if f.metadata.get("env")}


# ----------------------------------------------------------------- knob table
def knob_table() -> str:
    """The README's knob table, generated.  Markdown; stable ordering
    (declaration order) so the README-sync test is byte-exact."""
    lines = [
        "| knob | env var | default | what it does |",
        "|---|---|---|---|",
    ]
    for f in fields(RuntimeConfig):
        m = f.metadata
        if m["scope"] == "object":
            continue
        if m["scope"] == "env" and m["env"] is None:
            continue
        name = f"`{f.name}`" if m["scope"] != "env" else "—"
        env = f"`{m['env']}`" if m["env"] else "—"
        default = m["default"]
        if default is None:
            default = "unset"
        elif isinstance(default, bool):
            default = "on" if default else "off"
        doc = " ".join(str(m["doc"]).split())
        lines.append(f"| {name} | {env} | {default} | {doc} |")
    return "\n".join(lines)


# ------------------------------------------------------------------ agent CLI
def add_agent_cli_args(parser) -> None:
    """Mirror the agent-scoped knobs onto ``repro.cluster.agent``'s
    argparser, docs included — one source of truth for flag/env/welcome
    precedence (the flag is the *explicit* tier of ``resolve``)."""
    for f in fields(RuntimeConfig):
        m = f.metadata
        if not m.get("cli"):
            continue
        env_note = f" (env {m['env']}; welcome-propagated)" if m["env"] else ""
        parser.add_argument(
            m["cli"], dest=f.name, default=None, metavar=f.name.upper(),
            help=" ".join(str(m["doc"]).split()) + env_note)


def _main() -> int:
    print(knob_table())
    return 0


if __name__ == "__main__":   # pragma: no cover - exercised via README sync
    raise SystemExit(_main())
