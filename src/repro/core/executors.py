"""Pluggable executor backends (paper §3.3.2: persistent per-node workers).

The runtime's dispatch loop is backend-agnostic: one dispatcher thread per
worker pulls ready tasks from the :class:`~repro.core.scheduler.Scheduler`
and asks the executor backend to *invoke* the task function.  Backends
differ only in **where** the function body runs:

* ``"thread"``   — in the dispatcher thread itself (the original model:
                   shared address space, values passed by reference; great
                   for NumPy/JAX tasks that release the GIL).
* ``"cluster"``  — in one of N persistent worker processes *on a remote
                   node agent* reached over TCP (DESIGN.md §12): the
                   scheduler ships task bodies and only the inputs the
                   target node does not already hold across a wire-framed
                   data plane (:mod:`repro.cluster`), and every node runs
                   its own ``"process"``-style pool, so the shared-memory
                   plane below serves as the intra-node tier.
* ``"process"``  — in one of N *persistent* worker processes forked at
                   runtime start (the paper's worker model: Python-level
                   task bodies run truly in parallel, unconstrained by the
                   GIL).  Task parameters and results cross the
                   address-space boundary through a shared-memory object
                   plane built on the ``raw`` codec from
                   :mod:`repro.core.serialization`: an ndarray is written
                   once into a ``multiprocessing.shared_memory`` segment
                   laid out exactly like a ``raw``-codec blob (packed
                   header + contiguous buffer), and every worker that later
                   reads the same ``(data_id, version)`` reconstructs a
                   zero-copy view from its per-process segment cache — the
                   RMVL memory-mapped-deserialization property the paper
                   credits in §3.3.3 / Table 1.  Non-array values fall back
                   to pickle, and task functions stdlib pickle cannot ship
                   (lambdas, closures) go through cloudpickle with a
                   per-worker code cache so each function body crosses the
                   pipe at most once.

Dispatch pipelining (DESIGN.md §14): the out-of-process backends are
*credit-based*.  Each worker (process pipe / cluster agent slot) accepts
up to ``pipeline_depth`` in-flight task descriptors; the dispatcher thread
hands a task off and immediately pulls the next one, while completions
are drained elsewhere — a per-pool collector thread for the process
backend, the per-agent channel reader for the cluster backend.  For the
common all-keyed-ndarray task the process backend replaces the per-task
pickle frame with a compact binary descriptor (fn-registry token, segment
refs, evict piggyback).  A worker/agent that dies with depth > 1 tasks in
flight fails *all* of them as retryable :class:`WorkerCrashedError`.

Semantics that differ under ``"process"`` (DESIGN.md §11):

* task bodies observe *read-only* views of plane-resident ndarray inputs —
  in-place mutation raises instead of silently corrupting the shared copy
  (mutation is expressed through INOUT parameters, which produce a new
  datum version);
* closure state mutated inside a task body stays in the worker process —
  side-channel communication through captured Python objects does not
  propagate back to the submitting process.
"""
from __future__ import annotations

import collections
import os
import pickle
import struct
import threading
import time
import traceback
from multiprocessing import get_context
from multiprocessing import shared_memory as _shm_mod
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from .memory import MemoryBudget, MemoryGovernor
from .serialization import _pack_header, _unpack_header, as_c_contiguous

try:  # optional, but present in the baked image; required for lambda tasks
    import cloudpickle as _cloudpickle
except Exception:  # pragma: no cover - cloudpickle is available in CI
    _cloudpickle = None

# ndarrays at or above this size ride the shared-memory plane; smaller ones
# are cheaper to pickle straight through the pipe.
SHM_MIN_BYTES = int(os.environ.get("RJAX_SHM_MIN_BYTES", 16384))
_MP_CONTEXT = os.environ.get("RJAX_MP_CONTEXT", "fork")
# serialized-function cache entries kept per side (parent and each worker);
# oldest entries are evicted so apps creating task wrappers in a loop don't
# leak closures
_FN_CACHE_MAX = int(os.environ.get("RJAX_FN_CACHE_MAX", 512))


class WorkerCrashedError(RuntimeError):
    """A worker process died mid-task (segfault/OOM-kill).  Retryable."""


class DeadlineExceededError(WorkerCrashedError):
    """A task body overran its ``deadline_s`` and its worker was killed
    (DESIGN.md §19).  Retryable like any crash: pair ``deadline_s`` with
    ``max_retries`` when the overrun is expected to be transient."""


class RemoteTaskError(RuntimeError):
    """A worker-side exception that could not be unpickled; carries the
    original type name and traceback text."""

    def __init__(self, type_name: str, message: str, traceback_text: str = ""):
        super().__init__(f"{type_name}: {message}")
        self.type_name = type_name
        self.traceback_text = traceback_text


def _walk(obj: Any, fn: Callable[[Any], Any], leaf_types: tuple) -> Any:
    """Structure-preserving map over lists/tuples/dicts applying ``fn`` to
    leaves of ``leaf_types`` (mirrors runtime._walk, typed)."""
    if isinstance(obj, leaf_types):
        return fn(obj)
    if isinstance(obj, (list, tuple)):
        mapped = [_walk(o, fn, leaf_types) for o in obj]
        if isinstance(obj, tuple):
            return type(obj)(*mapped) if hasattr(obj, "_fields") else tuple(mapped)
        return mapped
    if isinstance(obj, dict):
        return {k: _walk(v, fn, leaf_types) for k, v in obj.items()}
    return obj


def _dispose_segment(seg: _shm_mod.SharedMemory, unlink: bool) -> None:
    """Release a segment, tolerating live numpy views.

    Store values handed to user code are zero-copy views into the mapping;
    if any are still referenced, ``close`` raises BufferError.  The mapping
    then simply lives until those views are collected — we unlink the name
    (freeing it immediately) and disarm the object so interpreter exit does
    not spray "cannot close exported pointers exist" tracebacks."""
    if unlink:
        try:
            seg.unlink()
        except Exception:
            pass
    try:
        seg.close()
    except BufferError:
        seg._buf = None       # type: ignore[attr-defined]
        seg._mmap = None      # type: ignore[attr-defined]
        try:
            fd = getattr(seg, "_fd", -1)
            if fd >= 0:
                os.close(fd)
                seg._fd = -1  # type: ignore[attr-defined]
        except Exception:
            pass
    except Exception:
        pass


# Resource-tracker accounting: workers — forked AND spawned — inherit the
# parent's tracker over an fd, so there is exactly ONE tracker per runtime.
# Its name-set is idempotent under re-registration (create in a worker,
# attach in the parent, attach in other workers all collapse to one entry)
# and the explicit `unlink` in SegmentPlane.close/evict unregisters it.
# Nobody must ever call resource_tracker.unregister manually: that strips
# the single shared entry and turns the later unlink into tracker noise,
# while also losing the crash safety-net (tracker unlinks leftovers if the
# parent dies without cleanup).


class ShmRef:
    """Picklable handle to one ndarray in the shared-memory plane.

    The segment holds exactly a ``raw``-codec blob body: the packed header
    travels in the ref, the buffer lives in the segment, so decoding is
    ``_unpack_header`` + ``np.frombuffer`` — zero copies."""

    __slots__ = ("name", "header", "nbytes", "key")

    def __init__(self, name: str, header: bytes, nbytes: int,
                 key: Optional[Tuple[int, int]] = None):
        self.name = name
        self.header = header
        self.nbytes = nbytes
        self.key = key

    def __getstate__(self):
        return (self.name, self.header, self.nbytes, self.key)

    def __setstate__(self, state):
        self.name, self.header, self.nbytes, self.key = state


def _array_to_segment(arr: np.ndarray) -> Tuple[_shm_mod.SharedMemory, ShmRef]:
    arr = as_c_contiguous(arr)
    header = _pack_header(arr)
    seg = _shm_mod.SharedMemory(create=True, size=max(1, arr.nbytes))
    if arr.nbytes:
        np.frombuffer(seg.buf, dtype=arr.dtype, count=arr.size)[...] = arr.reshape(-1)
    return seg, ShmRef(seg.name, header, arr.nbytes)


def _segment_to_array(seg: _shm_mod.SharedMemory, ref: ShmRef) -> np.ndarray:
    dtype, shape, _ = _unpack_header(memoryview(ref.header))
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    arr = np.frombuffer(seg.buf, dtype=dtype, count=count).reshape(shape)
    arr.flags.writeable = False
    return arr


def _shm_eligible(arr: np.ndarray) -> bool:
    if arr.nbytes < SHM_MIN_BYTES or arr.dtype.hasobject:
        return False
    try:
        _pack_header(arr)
        return True
    except TypeError:  # dtype outside the raw-codec table
        return False


class SegmentPlane:
    """Parent-side registry of shared-memory segments keyed by the datum
    key ``(data_id, version)`` (plus anonymous result segments).  One datum
    is copied into the plane at most once no matter how many workers read
    it; the per-worker segment caches then make repeated reads zero-copy.

    With a memory budget configured (DESIGN.md §13), the plane is a
    *bounded* cache tier: keyed segments past the high watermark are
    evicted coldest-first (the authoritative copy lives in the scheduler's
    ObjectStore, which spills to disk under its own governor, so dropping
    the shm copy loses nothing).  A later ``ensure`` of an evicted key
    re-planes it — counted as a fault.  Keys of in-flight task inputs are
    pinned by the executor so a ref already on a pipe can never point at
    an unlinked segment, and every worker is told (piggybacked on its next
    task message) to drop its cached mapping of evicted names so the
    memory is actually returned to the OS."""

    def __init__(self, memory_budget=None):
        # reentrant: governed ensure() may evict (and re-enter plane
        # bookkeeping) while holding the lock
        self._lock = threading.RLock()
        self._by_key: Dict[Tuple[int, int], Tuple[_shm_mod.SharedMemory, ShmRef]] = {}
        self._anon: Dict[str, _shm_mod.SharedMemory] = {}
        self._by_name: Dict[str, _shm_mod.SharedMemory] = {}  # every live segment
        self.bytes_planed = 0      # bytes copied into the plane (once per datum)
        self.refs_shipped = 0      # ShmRefs sent over pipes (dedup wins show here)
        self.governor: Optional[MemoryGovernor] = None
        self.on_evict: Optional[Callable[[str], None]] = None
        self._evicted_keys: Set[Tuple[int, int]] = set()
        self.configure_memory(memory_budget)

    def configure_memory(self, budget, high_frac: float = 0.9,
                         low_frac: float = 0.7) -> None:
        from .memory import parse_bytes
        cap = parse_bytes(budget)
        self.governor = None if cap is None else MemoryGovernor(
            MemoryBudget(cap, high_frac, low_frac), self._spill_key,
            name="shm-plane")

    def reclaim(self) -> None:
        """Re-run watermark enforcement under the PLANE lock.  Spill
        callbacks mutate ``_by_key``, so every governor entry that can
        evict must hold the plane lock first (plane → governor is the
        global lock order; entering via the governor alone would race
        ``ensure``'s check-then-read and can deadlock ABBA)."""
        if self.governor is None:
            return
        with self._lock:
            self.governor.reclaim()

    def _spill_key(self, key: Tuple[int, int]) -> int:
        """Governor callback: drop one keyed segment (unlink frees the
        name immediately; the pages return once every attached worker
        drops its cached mapping — see ``on_evict``).  Only ever invoked
        with the plane lock held (admit via ensure, or :meth:`reclaim`)."""
        item = self._by_key.pop(key, None)
        if item is None:
            return 0
        seg, ref = item
        self._by_name.pop(seg.name, None)
        self._evicted_keys.add(key)
        if self.on_evict is not None:
            self.on_evict(seg.name)
        _dispose_segment(seg, unlink=True)
        return ref.nbytes

    def ensure(self, key: Tuple[int, int], arr: np.ndarray) -> ShmRef:
        with self._lock:
            if key in self._by_key:
                self.refs_shipped += 1
                if self.governor is not None:
                    self.governor.touch(key)
                return self._by_key[key][1]
        seg, ref = _array_to_segment(arr)
        ref.key = key
        with self._lock:
            dup = self._by_key.get(key)
            if dup is not None:  # lost a publish race: keep the first
                _dispose_segment(seg, unlink=True)
                self.refs_shipped += 1
                return dup[1]
            self._by_key[key] = (seg, ref)
            self._by_name[ref.name] = seg
            self.bytes_planed += ref.nbytes
            self.refs_shipped += 1
            if self.governor is not None:
                if key in self._evicted_keys:   # re-plane of an evicted key
                    self._evicted_keys.discard(key)
                    self.governor.fault(key, ref.nbytes)
                self.governor.admit(key, ref.nbytes)
        return ref

    def attach(self, ref: ShmRef) -> Tuple[np.ndarray, bool]:
        """View a worker-shipped segment.  Returns ``(array, fresh)`` —
        ``fresh`` is False when the segment is already plane-resident (a
        pass-through result reshipping a ref the parent owns)."""
        with self._lock:
            seg = self._by_name.get(ref.name)
            if seg is not None:
                return _segment_to_array(seg, ref), False
        seg = _shm_mod.SharedMemory(name=ref.name)
        with self._lock:
            raced = self._by_name.get(ref.name)
            if raced is not None:
                _dispose_segment(seg, unlink=False)
                return _segment_to_array(raced, ref), False
            self._anon[ref.name] = seg
            self._by_name[ref.name] = seg
            self.bytes_planed += ref.nbytes
        return _segment_to_array(seg, ref), True

    def alias(self, key: Tuple[int, int], ref: ShmRef) -> None:
        """Promote an adopted (anonymous) result segment to a datum key so
        later ships of the same datum reuse it instead of re-copying."""
        with self._lock:
            seg = self._anon.pop(ref.name, None)
            if seg is None:
                return
            if key in self._by_key:
                self._anon[ref.name] = seg  # keep ownership; key already bound
                return
            self._by_key[key] = (seg, ShmRef(ref.name, ref.header, ref.nbytes, key))
            if self.governor is not None:
                self.governor.admit(key, ref.nbytes)

    def evict(self, key: Tuple[int, int]) -> None:
        with self._lock:
            item = self._by_key.pop(key, None)
            self._evicted_keys.discard(key)   # datum GC'd: no fault ahead
            if item is not None:
                self._by_name.pop(item[0].name, None)
                if self.governor is not None:
                    self.governor.release(key)
                if self.on_evict is not None:
                    self.on_evict(item[0].name)
        if item is not None:
            _dispose_segment(item[0], unlink=True)

    def drop_anonymous(self, name: str) -> None:
        """Reclaim an adopted-but-never-published result segment."""
        with self._lock:
            seg = self._anon.pop(name, None)
            if seg is not None:
                self._by_name.pop(name, None)
        if seg is not None:
            _dispose_segment(seg, unlink=True)

    def stats(self) -> dict:
        with self._lock:
            s = {
                "segments": len(self._by_key) + len(self._anon),
                "bytes_planed": self.bytes_planed,
                "refs_shipped": self.refs_shipped,
            }
            if self.governor is not None:
                s.update({f"plane_{k}": v
                          for k, v in self.governor.stats().items()})
            return s

    def close(self) -> None:
        with self._lock:
            segs = [s for s, _ in self._by_key.values()] + list(self._anon.values())
            self._by_key.clear()
            self._anon.clear()
            self._by_name.clear()
        for seg in segs:
            _dispose_segment(seg, unlink=True)


# --------------------------------------------------------------- worker side
class _WorkerSegmentCache:
    """Per-process cache: segment name -> (shm, zero-copy array view)."""

    def __init__(self):
        self._cache: Dict[str, Tuple[_shm_mod.SharedMemory, np.ndarray]] = {}
        self._refs: Dict[int, ShmRef] = {}   # id(view) -> its ref
        self.hits = 0
        self.attaches = 0

    def get(self, ref: ShmRef) -> np.ndarray:
        hit = self._cache.get(ref.name)
        if hit is not None:
            self.hits += 1
            return hit[1]
        seg = _shm_mod.SharedMemory(name=ref.name)
        arr = _segment_to_array(seg, ref)
        self._cache[ref.name] = (seg, arr)
        self._refs[id(arr)] = ref
        self.attaches += 1
        return arr

    def ref_for(self, arr: np.ndarray) -> Optional[ShmRef]:
        """The ref of ``arr`` if it IS a cached plane view (identity, not
        a slice) — lets pass-through results reship instead of re-copy."""
        ref = self._refs.get(id(arr))
        if ref is not None:
            cached = self._cache.get(ref.name)
            if cached is not None and cached[1] is arr:
                return ref
        return None

    def drop(self, name: str) -> None:
        """The parent evicted this segment: close our mapping so the
        memory actually returns to the OS (an unlinked segment lives on
        until every attached process closes it).  Safe mid-stream — the
        parent only sends drops for segments no in-flight task uses."""
        hit = self._cache.pop(name, None)
        if hit is None:
            return
        seg, arr = hit
        self._refs.pop(id(arr), None)
        _dispose_segment(seg, unlink=False)

    def close(self) -> None:
        for seg, _ in self._cache.values():
            _dispose_segment(seg, unlink=False)
        self._cache.clear()


def _dumps_fn(fn: Callable) -> bytes:
    """Serialize a task function for another address space.

    Functions living in ``__main__`` don't resolve by *reference* in a
    process with a different ``__main__`` (a TCP node agent, a
    spawn-context worker), so those ship by *value* via cloudpickle;
    everything else tries stdlib pickle first, falling back to
    cloudpickle for lambdas/closures."""
    by_value = getattr(fn, "__module__", None) in (None, "__main__")
    if not by_value:
        try:
            return b"P" + pickle.dumps(fn, protocol=5)
        except Exception:
            pass
    if _cloudpickle is not None:
        return b"C" + _cloudpickle.dumps(fn)
    # forked workers share our __main__, so by-reference still works there
    return b"P" + pickle.dumps(fn, protocol=5)


def _loads_fn(blob: bytes) -> Callable:
    tag, body = blob[:1], blob[1:]
    if tag == b"P":
        return pickle.loads(body)
    if tag == b"C":
        if _cloudpickle is None:
            raise RuntimeError("cloudpickle unavailable in worker")
        return _cloudpickle.loads(body)
    raise RuntimeError("function body missing from worker cache")


def _rebuild_remote_error(enc, tb) -> BaseException:
    """Reconstruct an exception shipped from another address space (a
    pool worker's ``E`` reply, an agent's ``err`` meta) without raising:
    unpickle the original and chain the remote traceback text, or fall
    back to the ``type|message|tb`` encoding when it didn't pickle."""
    if enc is not None:
        try:
            exc = pickle.loads(enc)
        except Exception:
            exc = None
        if isinstance(exc, BaseException):
            # chain the remote traceback text so failures are debuggable
            # from the submitting process
            exc.__cause__ = RemoteTaskError(type(exc).__name__,
                                            str(exc), tb or "")
            return exc
    type_name, _, rest = (tb or "RemoteTaskError||").partition("|")
    message, _, tb_text = rest.partition("|")
    return RemoteTaskError(type_name, message, tb_text)


class _FnRegistry:
    """Token registry for serialized task functions: one monotonically
    increasing token per distinct function object, so each boundary (a
    worker pipe, an agent socket) sees a function body at most once.  The
    cached strong ref keeps ``id(fn)`` unique while cached; the registry
    is bounded by ``RJAX_FN_CACHE_MAX``."""

    def __init__(self):
        self._lock = threading.Lock()
        self._cache: Dict[int, Tuple[int, Any, bytes]] = {}
        self._next_token = 1

    def entry(self, fn: Callable) -> Tuple[int, bytes]:
        with self._lock:
            entry = self._cache.get(id(fn))
            if entry is not None and entry[1] is fn:
                return entry[0], entry[2]
            blob = _dumps_fn(fn)
            token = self._next_token
            self._next_token += 1
            self._cache[id(fn)] = (token, fn, blob)
            while len(self._cache) > _FN_CACHE_MAX:
                self._cache.pop(next(iter(self._cache)))
            return token, blob


def _encode_result(result: Any, cache: "_WorkerSegmentCache"
                   ) -> Tuple[bytes, List[_shm_mod.SharedMemory]]:
    created: List[_shm_mod.SharedMemory] = []

    def enc(arr: np.ndarray):
        passthrough = cache.ref_for(arr)
        if passthrough is not None:   # identity result: reship, don't re-copy
            return passthrough
        if not _shm_eligible(arr):
            return arr
        seg, ref = _array_to_segment(arr)   # parent takes ownership on adopt()
        created.append(seg)
        return ref

    structure = _walk(result, enc, (np.ndarray,))
    try:
        return pickle.dumps(structure, protocol=5), created
    except Exception:
        if _cloudpickle is None:
            raise
        return _cloudpickle.dumps(structure), created


# --------------------------------------------- pipe wire format (DESIGN.md §14)
# Parent -> worker messages are raw byte strings (send_bytes/recv_bytes), the
# first byte selecting the kind:
#
#   b"X"  exit
#   b"P"  pickle.dumps((token, fn_blob, structure, evicted)) — the general
#         task message, ONE pickle pass with the args/kwargs structure
#         inline (ShmRefs and small values pickle fine)
#   b"Q"  like "P" but the structure needed cloudpickle: the tuple carries
#         cloudpickle.dumps(structure) as bytes instead
#   b"D"  compact binary descriptor for the common all-keyed-ndarray case:
#         fn token + evict piggyback + flat ShmRef args — no pickle frame
#         on the hot path at all
#   b"M"  batch: u32 count, then per task u32 length + sub-message (each a
#         P/Q/D message) — a dispatcher with several credits free ships
#         them in ONE pipe write; the worker answers one reply per
#         sub-message, preserving per-task FIFO.
#
# Worker -> parent replies are raw byte strings too, one per task message in
# FIFO order (which is what lets the parent run a single completion
# collector per pool):
#
#   b"K" + result-structure pickle          task succeeded
#   b"E" + pickle.dumps((exc_blob, tb))     task raised
_DESC_HEAD = struct.Struct("<QHH")   # fn token, n_evicted, n_refs
_DESC_U16 = struct.Struct("<H")
_DESC_U64 = struct.Struct("<Q")


def _pack_descriptor(token: int, evicted: Tuple[str, ...],
                     refs: Tuple[ShmRef, ...]) -> bytes:
    out = [b"D", _DESC_HEAD.pack(token, len(evicted), len(refs))]
    for name in evicted:
        nb = name.encode("ascii")
        out.append(_DESC_U16.pack(len(nb)))
        out.append(nb)
    for ref in refs:
        nb = ref.name.encode("ascii")
        out.append(_DESC_U16.pack(len(nb)))
        out.append(nb)
        out.append(_DESC_U16.pack(len(ref.header)))
        out.append(ref.header)
        out.append(_DESC_U64.pack(ref.nbytes))
    return b"".join(out)


def _unpack_descriptor(buf: bytes):
    token, n_ev, n_refs = _DESC_HEAD.unpack_from(buf, 1)
    off = 1 + _DESC_HEAD.size
    evicted = []
    for _ in range(n_ev):
        (ln,) = _DESC_U16.unpack_from(buf, off)
        off += 2
        evicted.append(buf[off:off + ln].decode("ascii"))
        off += ln
    refs = []
    for _ in range(n_refs):
        (ln,) = _DESC_U16.unpack_from(buf, off)
        off += 2
        name = buf[off:off + ln].decode("ascii")
        off += ln
        (hl,) = _DESC_U16.unpack_from(buf, off)
        off += 2
        header = bytes(buf[off:off + hl])
        off += hl
        (nb,) = _DESC_U64.unpack_from(buf, off)
        off += 8
        refs.append(ShmRef(name, header, nb))
    return token, evicted, refs


_BATCH_U32 = struct.Struct("<I")


def _worker_main(conn, worker_index: int, close_fds: tuple = ()) -> None:
    """Persistent worker loop: one process, many tasks (§3.3.2).  Tasks
    arrive pipelined (up to the parent's credit depth queued in the pipe,
    possibly several per batch message) and are answered strictly in
    arrival order."""
    for fd in close_fds:   # inherited sibling/parent fds — see _spawn
        try:
            os.close(fd)
        except OSError:
            pass
    cache = _WorkerSegmentCache()
    fns: Dict[int, Callable] = {}

    def run_task(raw) -> bool:
        """Execute one P/Q/D task message; False = parent is gone.  The
        parse runs INSIDE the try: an argument whose unpickling raises
        (import drift, reduce hooks) must cost one error reply, not the
        worker — killing the worker would take every pipelined sibling
        down with it and re-crash the respawn on retry."""
        try:
            kind = raw[:1]
            desc_refs = None
            structure = None
            if kind == b"D":
                fn_token, evicted, desc_refs = _unpack_descriptor(raw)
                fn_blob = b""
            else:  # b"P"/b"Q": general pickled task tuple
                fn_token, fn_blob, structure, evicted = \
                    pickle.loads(memoryview(raw)[1:])
            if "*" in evicted:     # overflow sentinel: drop everything
                for name in list(cache._cache):
                    cache.drop(name)
            else:
                for name in evicted:   # parent-evicted: drop mappings
                    cache.drop(name)
            fn = fns.get(fn_token)
            if fn is None:
                fn = _loads_fn(fn_blob)
                fns[fn_token] = fn
                while len(fns) > _FN_CACHE_MAX:
                    fns.pop(min(fns))   # tokens are monotonic: min = oldest
            if desc_refs is not None:
                args = tuple(cache.get(r) for r in desc_refs)
                kwargs: dict = {}
            else:
                if kind == b"Q":   # cloudpickled structure
                    if _cloudpickle is None:
                        raise RuntimeError("cloudpickle unavailable in worker")
                    structure = _cloudpickle.loads(structure)
                args, kwargs = _walk(structure, cache.get, (ShmRef,))
            result = fn(*args, **kwargs)
            blob, created = _encode_result(result, cache)
            conn.send_bytes(b"K" + blob)
            for seg in created:  # parent adopts; drop our handles
                seg.close()
        except BaseException as err:  # noqa: BLE001 - ships to parent
            import traceback
            tb = traceback.format_exc()
            try:
                conn.send_bytes(b"E" + pickle.dumps(
                    (pickle.dumps(err, protocol=5), tb), protocol=5))
            except (BrokenPipeError, ConnectionResetError):
                return False   # parent is gone — exit quietly
            except Exception:
                try:
                    conn.send_bytes(b"E" + pickle.dumps(
                        (None, f"{type(err).__name__}|{err}|{tb}"),
                        protocol=5))
                except OSError:
                    return False
        return True

    try:
        while True:
            try:
                raw = conn.recv_bytes()
            except (EOFError, OSError):
                break
            kind = raw[:1]
            if kind == b"X":
                break
            if kind == b"M":   # batch: unpack, run each in order
                (count,) = _BATCH_U32.unpack_from(raw, 1)
                off = 1 + 4
                alive = True
                for _ in range(count):
                    (ln,) = _BATCH_U32.unpack_from(raw, off)
                    off += 4
                    alive = run_task(raw[off:off + ln])
                    off += ln
                    if not alive:
                        break
                if not alive:
                    break
                continue
            if not run_task(raw):
                break
    finally:
        cache.close()
        try:
            conn.close()
        except Exception:
            pass


# ------------------------------------------------------------------ backends
class ExecutorBackend:
    """Owns the persistent workers and the dispatch loop threads.

    ``pipelined`` backends run the credit-based dispatch loop: the
    dispatcher thread of worker ``w`` may have up to ``pipeline_depth``
    tasks in flight (begin_task → async submit), and the backend promises
    that every submitted task eventually reaches exactly one completion
    (success, failure, or crash-requeue) on some completion thread."""

    name = "base"
    pipelined = False

    def __init__(self, n_workers: int, label: str = "rjax",
                 pipeline_depth: int = 1):
        self.n_workers = int(n_workers)
        self.label = label
        self.pipeline_depth = max(1, int(pipeline_depth))
        self.runtime = None
        self._threads: List[threading.Thread] = []
        self._credits: Optional[List[threading.Semaphore]] = None
        self._stop_dispatch = False

    # -- lifecycle -----------------------------------------------------------
    def start(self, runtime) -> None:
        self.runtime = runtime
        if self.pipelined:
            self._credits = [threading.Semaphore(self.pipeline_depth)
                             for _ in range(self.n_workers)]
        for w in range(self.n_workers):
            t = threading.Thread(target=self._dispatch_loop, args=(w,),
                                 daemon=True, name=f"{self.label}-w{w}")
            t.start()
            self._threads.append(t)

    def _dispatch_loop(self, worker: int) -> None:
        rt = self.runtime
        node_id = rt.locality_domain(worker)
        if not self.pipelined:
            while True:
                tid = rt.scheduler.take(worker)
                if tid is None:
                    return
                rt._note_worker_busy()
                try:
                    rt._execute(tid, worker, node_id)
                finally:
                    rt._note_worker_idle()
                    self.task_done()   # reclaim unpublished result segments
            return
        # credit-based pipelined dispatch (DESIGN.md §14)
        credits = self._credits[worker]
        depth = self.pipeline_depth
        while True:
            credits.acquire()
            if self._stop_dispatch:
                credits.release()
                return
            tid = rt.scheduler.take(worker)
            if tid is None:
                credits.release()
                return
            tids = [tid]
            # opportunistic batching: while credits are free AND ready
            # tasks are queued, grab them too — they ship in one write
            while len(tids) < depth and credits.acquire(blocking=False):
                if self._stop_dispatch:
                    credits.release()
                    break
                nxt = rt.scheduler.take(worker, timeout=0)
                if nxt is None:
                    credits.release()
                    break
                tids.append(nxt)
            exs = []
            for t in tids:
                rt._note_worker_busy()
                ex = rt.begin_task(t, worker, node_id)
                if ex is None:   # cancelled / completed during resolution
                    rt._note_worker_idle()
                    credits.release()
                    continue
                exs.append(ex)
            if exs:
                # hand off; the backend guarantees exactly one completion
                # call per execution
                self._submit_batch(worker, exs)

    def _submit_pipelined(self, worker: int, ex) -> None:
        raise NotImplementedError

    def _submit_batch(self, worker: int, exs: List) -> None:
        for ex in exs:
            self._submit_pipelined(worker, ex)

    def _halt_dispatch(self) -> None:
        """Wake dispatchers blocked on credits so they observe shutdown."""
        self._stop_dispatch = True
        if self._credits:
            for c in self._credits:
                for _ in range(self.pipeline_depth):
                    c.release()

    def shutdown(self, wait: bool = True, timeout: float = 10.0) -> None:
        self._halt_dispatch()
        for t in self._threads:
            t.join(timeout=timeout if wait else 0.2)

    # -- invocation ----------------------------------------------------------
    def invoke(self, worker: int, fn: Callable, args: tuple, kwargs: dict,
               input_keys: Optional[Dict[int, Tuple[int, int]]] = None) -> Any:
        """Run ``fn(*args, **kwargs)`` on ``worker`` and return the result.
        ``input_keys`` maps ``id(value) -> (data_id, version)`` for inputs
        resolved from the object store (lets the plane dedup by datum)."""
        raise NotImplementedError

    def publish(self, key: Tuple[int, int], value: Any) -> None:
        """Hook: ``value`` was published to the store under ``key``."""

    def task_done(self) -> None:
        """Hook: the current completion thread finished a task's
        completion path (success or failure)."""

    def stats(self) -> dict:
        # every backend reports how its dispatch side is driven, for
        # stats-key parity across backends: the in-process and pool
        # executors use per-worker dispatcher threads; the cluster
        # executor overrides this with its control-plane knob
        # (DESIGN.md §18)
        return {"backend": self.name, "control_plane": "threads"}


class ThreadExecutor(ExecutorBackend):
    """The original in-process model: invoke == plain call."""

    name = "thread"

    def invoke(self, worker, fn, args, kwargs, input_keys=None):
        return fn(*args, **kwargs)


class _Inflight:
    """One task on a worker's pipe, awaiting its FIFO-ordered reply."""

    __slots__ = ("ex", "pinned")

    def __init__(self, ex, pinned):
        self.ex = ex
        self.pinned = pinned


class ProcessExecutor(ExecutorBackend):
    """Persistent worker processes + shared-memory object plane.

    Runtime mode (``start()``) is pipelined: each worker pipe carries up
    to ``pipeline_depth`` in-flight task messages, dispatcher threads hand
    off without blocking, and one per-pool *collector* thread drains every
    worker's replies (replies are strictly FIFO per pipe, so completion
    matching is a deque pop).  Pool mode (``spawn_workers()`` +
    ``invoke()``, used by the cluster node agent) stays synchronous
    stop-and-wait per slot thread."""

    name = "process"
    pipelined = True

    def __init__(self, n_workers: int, label: str = "rjax",
                 mp_context: Optional[str] = None, memory_budget=None,
                 pipeline_depth: int = 1):
        super().__init__(n_workers, label, pipeline_depth=pipeline_depth)
        try:
            self._ctx = get_context(mp_context or _MP_CONTEXT)
        except ValueError:
            self._ctx = get_context("spawn")
        self.plane = SegmentPlane(memory_budget=memory_budget)
        self.plane.on_evict = self._note_evicted
        # evicted segment names each worker has not yet been told to drop;
        # drained into (and piggybacked on) that worker's next task message
        self._evict_lock = threading.Lock()
        self._pending_evicts: List[Set[str]] = [set() for _ in range(n_workers)]
        self._fns = _FnRegistry()
        self._procs: List[Any] = [None] * self.n_workers
        self._conns: List[Any] = [None] * self.n_workers
        self._conn_locks = [threading.Lock() for _ in range(self.n_workers)]
        self._shipped: List[Set[int]] = [set() for _ in range(self.n_workers)]
        # pipelined-mode state: per-worker FIFO of in-flight tasks and the
        # reply collector thread
        self._inflight: List[collections.deque] = [collections.deque()
                                                   for _ in range(self.n_workers)]
        self._inflight_locks = [threading.Lock() for _ in range(self.n_workers)]
        self._collector: Optional[threading.Thread] = None
        self._collector_stop = threading.Event()
        self._conn_gen = 0   # bumped per (re)spawn; keys the selector registry
        # fds (beyond sibling pipe ends) that forked workers must close so
        # a dead parent actually EOFs its peers — e.g. the node agent's TCP
        # socket: a worker inheriting it would keep the scheduler's
        # connection half-open after the agent dies, masking the crash
        self.inherit_blockers: List[int] = []
        self._tl = threading.local()   # per-completion-thread decoded views
        self._closing = False
        self.worker_restarts = 0
        self.descriptor_sends = 0      # compact-descriptor fast-path hits
        self.batched_sends = 0         # multi-task M messages shipped
        # deadline enforcement (DESIGN.md §19, pipelined mode): lazily
        # started monitor killing workers whose head-of-pipe task has sat
        # at the head (≈ been running) past its deadline_s
        self._deadline_monitor: Optional[threading.Thread] = None
        self._deadline_victims: Dict[int, Any] = {}
        self.deadline_kills = 0

    # -- process management --------------------------------------------------
    def spawn_workers(self) -> None:
        """Fork the persistent worker pool.  Public because the cluster
        node agent drives this pool directly (no dispatcher threads)."""
        # the tracker must exist BEFORE the first fork, or each worker
        # lazily starts its own and the one-tracker accounting (and the
        # crash safety-net) silently fragments
        try:
            from multiprocessing import resource_tracker
            resource_tracker.ensure_running()
        except Exception:
            pass
        for w in range(self.n_workers):
            self._spawn(w)

    def start(self, runtime) -> None:
        # fork the workers *before* the dispatcher threads exist: forking a
        # multithreaded process risks inheriting locks held mid-operation
        self.spawn_workers()
        super().start(runtime)
        self._collector = threading.Thread(target=self._collector_loop,
                                           daemon=True,
                                           name=f"{self.label}-collect")
        self._collector.start()

    def _spawn(self, worker: int) -> None:
        parent, child = self._ctx.Pipe(duplex=True)
        close_fds: List[int] = []
        if self._ctx.get_start_method() == "fork":
            # a forked worker inherits the parent-side pipe end of every
            # worker spawned so far — INCLUDING ITS OWN — plus any
            # registered blocker fd.  Unless the child closes them, a dead
            # parent never EOFs the pipe (the worker itself keeps it open)
            # and orphaned workers block forever in recv()
            try:
                close_fds.append(parent.fileno())
            except (OSError, ValueError):
                pass
            for c in self._conns:
                if c is not None and c is not parent:
                    try:
                        close_fds.append(c.fileno())
                    except (OSError, ValueError):
                        pass
            close_fds.extend(self.inherit_blockers)
        p = self._ctx.Process(target=_worker_main,
                              args=(child, worker, tuple(close_fds)),
                              daemon=True, name=f"{self.label}-p{worker}")
        p.start()
        child.close()
        self._procs[worker] = p
        self._conns[worker] = parent
        self._shipped[worker] = set()
        self._conn_gen += 1   # collector rebuilds its selector registry
        with self._evict_lock:   # fresh process, empty segment cache
            self._pending_evicts[worker] = set()

    # an idle worker's pending-evict set is drained only when it next runs
    # a task; past this size, collapse it to a drop-everything sentinel so
    # a cold worker can't accumulate unbounded names
    _EVICT_PENDING_MAX = 4096

    def _note_evicted(self, name: str) -> None:
        """Plane hook: queue an evicted segment name for every worker."""
        with self._evict_lock:
            for w, pending in enumerate(self._pending_evicts):
                if "*" in pending:
                    continue
                pending.add(name)
                if len(pending) > self._EVICT_PENDING_MAX:
                    self._pending_evicts[w] = {"*"}

    # -- the object plane ----------------------------------------------------
    def _encode_structure(self, args: tuple, kwargs: dict,
                          input_keys: Dict[int, Tuple[int, int]]):
        def enc(arr: np.ndarray):
            key = input_keys.get(id(arr))
            # only *keyed* data (store-resident, re-readable) enters the
            # plane; a direct one-shot ndarray argument rides the pipe —
            # a segment for it could never be deduped or evicted
            if key is None or not _shm_eligible(arr):
                return arr
            return self.plane.ensure(key, arr)

        return _walk((args, kwargs), enc, (np.ndarray,))

    def _pack_task_bytes(self, token: int, blob: bytes, first: bool,
                         structure, evicted) -> bytes:
        """The general task message: one pickle pass with the structure
        inline; cloudpickle fallback rides a ``Q`` message."""
        fn_field = blob if first else b""
        try:
            return b"P" + pickle.dumps((token, fn_field, structure, evicted),
                                       protocol=5)
        except Exception:
            if _cloudpickle is None:
                raise
            return b"Q" + pickle.dumps(
                (token, fn_field, _cloudpickle.dumps(structure), evicted),
                protocol=5)

    def _decode_result(self, blob: bytes) -> Any:
        views: Dict[int, ShmRef] = {}

        def dec(ref: ShmRef):
            arr, fresh = self.plane.attach(ref)
            if fresh:   # newly adopted: publish() aliases it or task_done() reclaims it
                views[id(arr)] = ref
            return arr

        result = _walk(pickle.loads(blob), dec, (ShmRef,))
        self._tl.views = views   # consumed by publish() in the same thread
        return result

    def publish(self, key, value):
        """Alias a just-decoded result segment to its datum key, so later
        reads of ``(data_id, version)`` ship a ref instead of bytes."""
        views = getattr(self._tl, "views", None)
        if views and isinstance(value, np.ndarray):
            ref = views.pop(id(value), None)
            if ref is not None:
                self.plane.alias(key, ref)

    def task_done(self):
        """Dispose result segments that were adopted but never published —
        discarded outputs (``returns=0``), lost speculation races, arity
        failures — so anonymous segments cannot accumulate."""
        views = getattr(self._tl, "views", None)
        if views:
            for ref in views.values():
                self.plane.drop_anonymous(ref.name)
        self._tl.views = None

    def _remote_error(self, enc, tb) -> BaseException:
        return _rebuild_remote_error(enc, tb)

    # -- pipelined dispatch (runtime mode) -----------------------------------
    def _submit_pipelined(self, worker: int, ex) -> None:
        self._submit_batch(worker, [ex])

    def _submit_batch(self, worker: int, exs: List) -> None:
        """Ship up to ``pipeline_depth`` claimed tasks in ONE pipe write
        (an ``M`` batch when more than one) — fewer syscalls and worker
        wakeups per task.  Every task ends up either in the in-flight FIFO
        (the collector completes it) or completed here (encode/send
        failure)."""
        items: List[Tuple[bytes, _Inflight]] = []
        with self._conn_locks[worker]:
            conn = self._conns[worker]
            for ex in exs:
                # pin this task's keyed inputs BEFORE encoding plants them
                # in the plane: a concurrent completion's reclaim (or a
                # sibling input's admit) could otherwise evict a segment
                # between its ensure() and the send, leaving a ref on the
                # pipe that points at an unlinked name.  Pins work for
                # keys not yet admitted; unpinned at completion.
                pinned = frozenset(ex.input_keys.values())
                if self.plane.governor is not None and pinned:
                    self.plane.governor.pin_many(pinned)
                try:
                    token, blob = self._fns.entry(ex.t.fn)
                    structure = self._encode_structure(ex.args, ex.kwargs,
                                                       ex.input_keys)
                    first = token not in self._shipped[worker]
                    with self._evict_lock:
                        evicted = tuple(self._pending_evicts[worker])
                        self._pending_evicts[worker] = set()
                    args_s, kwargs_s = structure
                    if not first and not kwargs_s \
                            and isinstance(args_s, tuple) \
                            and all(type(a) is ShmRef for a in args_s):
                        # the common all-keyed-ndarray case: compact
                        # binary descriptor, no per-task pickle frame
                        msg = _pack_descriptor(token, evicted, args_s)
                        self.descriptor_sends += 1
                    else:
                        msg = self._pack_task_bytes(token, blob, first,
                                                    structure, evicted)
                        if first:
                            # committed optimistically: a failed send is a
                            # crash, and respawn resets the shipped set
                            self._shipped[worker].add(token)
                except BaseException as err:   # encode failure: task fails
                    self._finish_entry(worker, _Inflight(ex, pinned),
                                       error=err)
                    continue
                items.append((msg, _Inflight(ex, pinned)))
            if not items:
                return
            if len(items) == 1:
                out = items[0][0]
            else:
                parts = [b"M", _BATCH_U32.pack(len(items))]
                for msg, _ in items:
                    parts.append(_BATCH_U32.pack(len(msg)))
                    parts.append(msg)
                out = b"".join(parts)
                self.batched_sends += 1
            with self._inflight_locks[worker]:
                for _, entry in items:
                    self._inflight[worker].append(entry)
            if any(entry.ex.t.deadline_s is not None for _, entry in items):
                self._ensure_deadline_monitor()
            try:
                conn.send_bytes(out)
                return   # in flight; the collector completes them
            except BaseException as err:
                # send failed — usually a crashed worker.  If the collector
                # already drained our entries (it races us on EOF), it owns
                # those completions; we own whatever is still queued.
                owned = []
                with self._inflight_locks[worker]:
                    for _, entry in items:
                        try:
                            self._inflight[worker].remove(entry)
                            owned.append(entry)
                        except ValueError:
                            pass
                for entry in owned:
                    crash = WorkerCrashedError(
                        f"worker process {worker} died executing "
                        f"{getattr(entry.ex.t.fn, '__name__', entry.ex.t.fn)!r}")
                    crash.__cause__ = err
                    self._finish_entry(worker, entry, error=crash)

    def _finish_entry(self, worker: int, entry: _Inflight, *,
                      result: Any = None, error: Optional[BaseException] = None
                      ) -> None:
        """Exactly-once completion bookkeeping for one in-flight task."""
        rt = self.runtime
        try:
            if error is not None:
                rt.fail_task(entry.ex, error)
            else:
                rt.complete_task(entry.ex, result)
        finally:
            if self.plane.governor is not None and entry.pinned:
                self.plane.governor.unpin_many(entry.pinned)
                # admits under a fully-pinned working set skip eviction;
                # re-enforce the watermark now that this task's pins are
                # off (via the plane: it must hold its lock to evict)
                self.plane.reclaim()
            self.task_done()
            rt._note_worker_idle()
            self._credits[worker].release()

    def _collector_loop(self) -> None:
        import selectors
        sel = selectors.DefaultSelector()
        my_gen = -1
        try:
            while not self._collector_stop.is_set():
                if my_gen != self._conn_gen:
                    # a worker was (re)spawned: rebuild the registry — the
                    # selector itself is persistent across wakes, which is
                    # the whole point (mp.connection.wait builds and tears
                    # one down per call)
                    my_gen = self._conn_gen
                    sel.close()
                    sel = selectors.DefaultSelector()
                    for w, c in enumerate(self._conns):
                        if c is not None:
                            try:
                                sel.register(c, selectors.EVENT_READ, w)
                            except (ValueError, OSError):
                                pass
                try:
                    events = sel.select(timeout=0.1)
                except OSError:
                    time.sleep(0.005)
                    continue
                for key, _ in events:
                    w, conn = key.data, key.fileobj
                    if self._conns[w] is not conn:
                        continue
                    # one message per event: the persistent selector is
                    # level-triggered, so leftover replies re-arm it
                    # immediately — no per-message poll() (which would
                    # rebuild a selector per call, the very cost this
                    # thread exists to avoid)
                    try:
                        self._collect_one(w, conn)
                    except BaseException:
                        # a completion that raises (publish failure, shm
                        # exhaustion) must not kill the ONLY collector —
                        # that would freeze every pipeline with no error
                        import traceback
                        traceback.print_exc()
        finally:
            sel.close()

    def _collect_one(self, w: int, conn) -> None:
        try:
            resp = conn.recv_bytes()
        except (EOFError, OSError):
            self._on_worker_crash(w, conn)
            return
        kind = resp[:1]
        with self._inflight_locks[w]:
            entry = (self._inflight[w].popleft()
                     if self._inflight[w] else None)
        if entry is None:
            return   # stray reply (e.g. raced a crash drain)
        if kind == b"K":
            self._tl.views = None
            try:
                result = self._decode_result(memoryview(resp)[1:])
            except BaseException as err:
                self._finish_entry(w, entry, error=err)
            else:
                self._finish_entry(w, entry, result=result)
        else:
            enc, tb = pickle.loads(memoryview(resp)[1:])
            self._finish_entry(w, entry, error=self._remote_error(enc, tb))

    def _on_worker_crash(self, worker: int, conn) -> None:
        """EOF on a worker pipe: fail EVERY in-flight task of that worker
        as a retryable crash and respawn it."""
        with self._conn_locks[worker]:
            if self._conns[worker] is not conn:
                return   # already handled
            with self._inflight_locks[worker]:
                entries = list(self._inflight[worker])
                self._inflight[worker].clear()
            if self._closing:
                try:
                    conn.close()
                except Exception:
                    pass
                self._conns[worker] = None
            else:
                self._restart(worker)
        victim = self._deadline_victims.pop(worker, None)
        n = len(entries)
        for entry in entries:
            if entry is victim:
                err: WorkerCrashedError = DeadlineExceededError(
                    f"task {entry.ex.t.name!r} exceeded its deadline of "
                    f"{entry.ex.t.deadline_s}s on worker {worker} (killed)")
            else:
                err = WorkerCrashedError(
                    f"worker process {worker} died with {n} task(s) in flight "
                    f"(executing up to {entry.ex.t.name!r})")
            self._finish_entry(worker, entry, error=err)

    # -- deadline enforcement (DESIGN.md §19, pipelined mode) ----------------
    def kill_worker(self, worker: int) -> None:
        """Forcibly terminate a (wedged) worker process *without*
        respawning it here: the pipe EOF surfaces wherever its replies
        are awaited — the collector's crash handler (pipelined mode) or
        a blocked ``invoke`` (pool mode, the agent watchdog's case) —
        and THAT path does the single restart, so enforcement rides the
        existing crash machinery instead of racing it.  SIGKILL, not
        SIGTERM: forked workers inherit the parent's signal handlers (the
        node agent turns SIGTERM into ``SystemExit``), and a catchable
        signal would come back as a non-retryable task error from a
        still-wedgeable worker instead of a crash."""
        proc = self._procs[worker]
        try:
            if proc is not None and proc.is_alive():
                proc.kill()
        except Exception:
            pass

    def _ensure_deadline_monitor(self) -> None:
        if self._deadline_monitor is not None or self._closing:
            return
        t = threading.Thread(target=self._deadline_loop, daemon=True,
                             name=f"{self.label}-deadline")
        self._deadline_monitor = t
        t.start()

    def _deadline_loop(self) -> None:
        """Kill workers whose head-of-pipe task overran its deadline.
        Replies are FIFO per pipe, so head-of-queue residency is the
        closest observable proxy for "the body is running" — a queued
        task's clock only starts once its predecessors' replies drain."""
        heads: Dict[int, Tuple[Any, float]] = {}
        while not self._closing and not self._collector_stop.is_set():
            now = time.monotonic()
            for w in range(self.n_workers):
                with self._inflight_locks[w]:
                    entry = self._inflight[w][0] if self._inflight[w] else None
                if entry is None:
                    heads.pop(w, None)
                    continue
                prev = heads.get(w)
                if prev is None or prev[0] is not entry:
                    heads[w] = (entry, now)
                    continue
                dl = entry.ex.t.deadline_s
                if dl is not None and now - prev[1] > dl:
                    self._deadline_victims[w] = entry
                    self.deadline_kills += 1
                    self.kill_worker(w)
                    heads.pop(w, None)
            time.sleep(0.02)

    # -- synchronous invocation (pool mode: the cluster node agent) ----------
    def invoke(self, worker, fn, args, kwargs, input_keys=None):
        token, blob = self._fns.entry(fn)
        # pin this task's keyed inputs for the whole round-trip: a ref on
        # the pipe must never point at a segment the governor unlinked
        pinned = frozenset((input_keys or {}).values())
        if self.plane.governor is not None and pinned:
            self.plane.governor.pin_many(pinned)
        try:
            structure = self._encode_structure(args, kwargs, input_keys or {})
            with self._conn_locks[worker]:
                conn = self._conns[worker]
                first = token not in self._shipped[worker]
                with self._evict_lock:
                    evicted = tuple(self._pending_evicts[worker])
                    self._pending_evicts[worker] = set()
                try:
                    conn.send_bytes(self._pack_task_bytes(
                        token, blob, first, structure, evicted))
                    self._shipped[worker].add(token)
                    resp = conn.recv_bytes()
                except (EOFError, OSError, BrokenPipeError) as err:
                    if not self._closing:
                        self._restart(worker)
                    raise WorkerCrashedError(
                        f"worker process {worker} died executing "
                        f"{getattr(fn, '__name__', fn)!r}") from err
            if resp[:1] == b"K":
                # decode while the inputs stay pinned: a pass-through
                # result reships an input ref, which must still attach
                return self._decode_result(memoryview(resp)[1:])
        finally:
            if self.plane.governor is not None and pinned:
                self.plane.governor.unpin_many(pinned)
        enc, tb = pickle.loads(memoryview(resp)[1:])
        raise self._remote_error(enc, tb)

    def _restart(self, worker: int) -> None:
        self.worker_restarts += 1
        proc = self._procs[worker]
        try:
            if proc is not None and proc.is_alive():
                proc.terminate()
                proc.join(timeout=2.0)
        except Exception:
            pass
        old = self._conns[worker]
        if old is not None:
            try:
                old.close()
            except Exception:
                pass
        self._spawn(worker)

    # -- lifecycle -----------------------------------------------------------
    def shutdown(self, wait: bool = True, timeout: float = 10.0) -> None:
        self._closing = True
        self._halt_dispatch()
        for w, conn in enumerate(self._conns):
            if conn is None:
                continue
            # a slot thread blocked in recv holds the lock (pool mode):
            # skip the polite exit for that worker and terminate it below
            if self._conn_locks[w].acquire(timeout=0.5 if wait else 0.05):
                try:
                    conn.send_bytes(b"X")
                except Exception:
                    pass
                finally:
                    self._conn_locks[w].release()
        for p in self._procs:
            if p is None:
                continue
            p.join(timeout=2.0 if wait else 0.2)
            if p.is_alive():
                try:
                    p.terminate()
                    p.join(timeout=1.0)
                except Exception:
                    pass
        self._collector_stop.set()
        if self._collector is not None:
            self._collector.join(timeout=2.0)
        for conn in self._conns:
            try:
                if conn is not None:
                    conn.close()
            except Exception:
                pass
        super().shutdown(wait=wait, timeout=timeout)
        self.plane.close()

    def stats(self) -> dict:
        s = {"backend": self.name, "control_plane": "threads",
             "worker_restarts": self.worker_restarts,
             "pipeline_depth": self.pipeline_depth,
             "descriptor_sends": self.descriptor_sends,
             "batched_sends": self.batched_sends,
             "deadline_kills": self.deadline_kills}
        s.update(self.plane.stats())
        return s


class ClusterExecutor(ExecutorBackend):
    """Dispatch tasks to TCP node agents (DESIGN.md §12).

    Slot ``worker`` maps to agent ``worker // workers_per_node``, which
    is also the task's locality domain, so the ``locality`` policy
    scores real cross-node residency.  Each slot streams up to
    ``pipeline_depth`` task requests before any completion arrives
    (DESIGN.md §14).

    Two control planes (``RJAX_CONTROL_PLANE`` / the ``control_plane``
    knob, DESIGN.md §18): the default ``async`` plane runs every channel
    as a coroutine pair on one IOLoop thread and dispatches from a loop
    pump — scheduler-side thread count is O(1) in agent count; the
    legacy ``threads`` plane keeps one dispatcher thread per slot and
    one reader thread per channel, with replies routed on the reader.

    Data plane: the scheduler keeps the authoritative copy of every datum
    (v1 is scheduler-mediated transfer) and tracks, per agent, which keys
    that node already caches.  A keyed ndarray input is shipped inside the
    task message (``Put``) the *first* time a node needs it and referenced
    (``Ref``) ever after — the wire-level send-once/reuse-many property.
    Result arrays come back tagged with agent-side cache tokens; when the
    runtime publishes them, an ``alias`` control message pins them into
    the producing node's plane under their datum key, so a node never
    re-downloads its own outputs.

    Per-agent consistency relies on connection FIFO ordering: residency
    marks and the messages that justify them are emitted under one
    per-agent ordering lock, so a ``Ref`` can never overtake its ``Put``
    or ``alias`` on the wire — pipelining does not change this, because
    the marks are made at *send* time under the same lock.

    Failure model: a dropped agent connection fails every in-flight task
    on that agent as a retryable :class:`WorkerCrashedError`; if the
    cluster harness can respawn the agent, the executor does so and clears
    that node's residency ledger, after which retries re-ship whatever the
    replacement needs.
    """

    name = "cluster"
    pipelined = True
    # dispatch resolves inputs WITHOUT materializing node-resident
    # results: RemoteValue placeholders flow through pack_payload as
    # Ref/Fetch directives instead (DESIGN.md §15)
    remote_values_ok = True

    def __init__(self, n_workers: int, label: str = "rjax", cluster=None,
                 pipeline_depth: int = 1, p2p=None, control_plane=None,
                 liveness=None, suspicion_s=None, reconnect_grace_s=None,
                 replication=None):
        super().__init__(n_workers, label, pipeline_depth=pipeline_depth)
        from .config import parse_bool, resolve as resolve_knob
        from .fault import LivenessConfig
        if cluster is None:
            raise ValueError(
                'backend="cluster" needs a cluster= harness '
                "(e.g. repro.cluster.LocalCluster)")
        self.cluster = cluster
        self.n_agents = int(cluster.n_agents)
        self.wpn = int(cluster.workers_per_node)
        if self.n_workers != self.n_agents * self.wpn:
            raise ValueError(
                f"n_workers={self.n_workers} != n_agents({self.n_agents}) x "
                f"workers_per_node({self.wpn})")
        # peer data plane kill-switch: RJAX_P2P=0 restores the PR-4
        # star topology (every result framed back to the scheduler)
        self.p2p = resolve_knob(p2p, "RJAX_P2P", default=True,
                                cast=parse_bool)
        # scheduler comm layer (DESIGN.md §18): "async" = one IOLoop
        # thread owns every channel + the dispatch pump (O(1) threads in
        # agent count); "threads" = the legacy reader-thread-per-channel
        # + dispatcher-thread-per-slot structure
        self.control_plane = resolve_knob(
            control_plane, "RJAX_CONTROL_PLANE", default="async")
        if self.control_plane not in ("async", "threads"):
            raise ValueError(
                f"control_plane must be 'async' or 'threads', "
                f"got {self.control_plane!r}")
        self.async_plane = self.control_plane == "async"
        # liveness failure detector (DESIGN.md §19): suspicion over
        # heartbeat age + in-flight request deadlines; a dead verdict
        # closes the channel, driving the normal on_close recovery
        self.liveness_cfg = LivenessConfig(
            enabled=resolve_knob(liveness, "RJAX_LIVENESS",
                                 default=True, cast=parse_bool),
            suspicion_s=resolve_knob(suspicion_s, "RJAX_SUSPICION_S",
                                     default=5.0, cast=float))
        self._detector = None
        self._liveness_stop = threading.Event()
        self._liveness_thread: Optional[threading.Thread] = None
        # per-agent in-flight scheduler-side deadlines: id(ex) ->
        # monotonic kill time (deadline + slack), under _stats_lock.
        # The agent watchdog fires first at deadline_s; this is the
        # backstop for an agent too wedged to run its own watchdog
        self._deadline_inflight: List[Dict[int, float]] = []
        self._deadline_slack = 0.0
        self.liveness_kills = 0
        # session resumption (DESIGN.md §20): on a TCP disconnect the
        # agent is PARKED for a grace window and allowed to re-dial with
        # its session token instead of being killed and replayed.  Only
        # wired on the async plane (the legacy channel starts its
        # on_close thread before consulting the adoption hook, so parking
        # there would race the restart path).
        self.reconnect_grace_s = resolve_knob(
            reconnect_grace_s, "RJAX_RECONNECT_GRACE_S", default=5.0,
            cast=float)
        self.resumption = self.async_plane and self.reconnect_grace_s > 0
        # asynchronous k-way replication (DESIGN.md §20): node-resident
        # results whose producer cost clears the duration threshold are
        # pushed to k buddy planes over the existing p2p bcast leg
        self.replication = resolve_knob(
            replication, "RJAX_REPLICATION", default=0, cast=int)
        self._io = None            # IOLoop (async control plane only)
        self._recovery = None      # small pool for blocking recovery work
        self._agent_up = [True] * self.n_agents
        self._channels: List[Any] = [None] * self.n_agents
        self._data_addrs: List[Optional[str]] = [None] * self.n_agents
        self._order_locks = [threading.Lock() for _ in range(self.n_agents)]
        self._restart_lock = threading.Lock()
        self._resident: List[Set[Tuple[int, int]]] = [set() for _ in range(self.n_agents)]
        self._shipped_fns: List[Set[int]] = [set() for _ in range(self.n_agents)]
        self._fns = _FnRegistry()
        self._peers = None         # scheduler-side PeerPool (gather path)
        self._tl = threading.local()
        self._closing = False
        # data-plane counters are bumped from per-agent channel reader
        # threads AND dispatcher threads — bare += across threads loses
        # updates, and relay_bytes is the CI-gated §15 acceptance metric
        self._stats_lock = threading.Lock()
        # first agent each scheduler-resident key was Put to (key ->
        # (agent, nbytes), under _stats_lock): later agents needing the
        # same key pull it agent→agent instead of costing a second copy
        # over our own link (the broadcast-residue fix, DESIGN.md §16)
        self._put_home: Dict[Tuple[int, int], Tuple[int, int]] = {}
        # residency generations (§20): per-(agent, key) counter bumped on
        # every residency MARK the scheduler sends (Put/Fetch/alias/bcast
        # leg); the agent bumps its mirror on receipt.  Equal counters at
        # resume time prove the mark landed — the manifest reconciliation
        # predicate.  Survives _drop_residency (a strike is not a
        # process death); reset only when the process is replaced.
        # Guarded by _order_locks[a], like _resident.
        self._res_gen: List[Dict[Tuple[int, int], int]] = [
            dict() for _ in range(self.n_agents)]
        # process generation per agent: bumped in _restart_agent only.
        # Result-token views carry the gen they were minted under so
        # publish/drop never talk to a replacement process, while tokens
        # minted before a RESUME (same process) stay valid.
        self._proc_gen = [0] * self.n_agents
        # parked agents: a -> {"ch", "token", "pending", "next_mid",
        # "deadline", "timer", "state"}; under _park_lock.  "state" moves
        # disconnected -> reconnecting while _on_resume reconciles.
        self._park_lock = threading.Lock()
        self._disconnected: Dict[int, dict] = {}
        # ops (alias/drop) that arrived while the agent was parked; each
        # list guarded by _order_locks[a], flushed on resume in order
        self._parked_ops: List[list] = [[] for _ in range(self.n_agents)]
        # in-flight task sends by mid: a -> {mid: (worker, ex)}.  A mid
        # the resumed agent never received maps back to its task here and
        # is re-submitted on the new channel instead of burning a retry
        # (GIL-atomic dict ops; entries die with the reply or restart)
        self._inflight_reqs: List[Dict[int, tuple]] = [
            dict() for _ in range(self.n_agents)]
        # replica locations: key -> set of agents holding a pushed copy
        # (beyond the producer); under _stats_lock
        self._replicas: Dict[Tuple[int, int], Set[int]] = {}
        self.reconnects = 0        # sessions resumed in place
        self.replica_bytes = 0     # bytes pushed to buddy planes
        self.replica_hits = 0      # lost keys served from a replica
        self.agent_restarts = 0
        self.broadcasts = 0        # collective broadcast waves completed
        self.puts = 0              # keyed datums shipped to some node
        self.refs = 0              # keyed datums referenced, not re-shipped
        self.fetches = 0           # peer-fetch directives issued
        self.fetch_bytes = 0       # bytes those directives moved node↔node
        self.bytes_shipped = 0     # scheduler→agent Put bytes
        self.relay_result_bytes = 0   # agent→scheduler result frame bytes
        self.remote_results = 0       # datums left node-resident
        self.deferred_result_bytes = 0  # bytes that never crossed our link

    # -- lifecycle -----------------------------------------------------------
    def start(self, runtime) -> None:
        from ..cluster.peer import PeerPool
        from ..cluster.protocol import inline_max_from_env
        from .telemetry import heartbeat_interval
        self.cluster.p2p = self.p2p
        # ship the scheduler-side inline threshold in the welcome, so
        # external agents on other hosts apply the same encoding policy
        if getattr(self.cluster, "inline_max", None) is None:
            self.cluster.inline_max = inline_max_from_env()
        # likewise the heartbeat cadence (DESIGN.md §17): resolved here
        # from the scheduler's environment so off-host agents beat in step
        if getattr(self.cluster, "heartbeat_s", None) is None:
            self.cluster.heartbeat_s = heartbeat_interval()
        # grace window rides the welcome so agents know to re-dial
        # (None disables the agent-side reconnect loop entirely)
        if hasattr(self.cluster, "reconnect_grace_s"):
            self.cluster.reconnect_grace_s = (
                self.reconnect_grace_s if self.resumption else None)
        if self.async_plane:
            from ..cluster.eventloop import AsyncAgentChannel, IOLoop
            self._io = IOLoop(name=f"{self.label}-io")
            # every accepted/respawned agent connection becomes a
            # coroutine pair on the one loop instead of a reader thread
            self.cluster.channel_factory = (
                lambda sock, nid, hello: AsyncAgentChannel(
                    sock, nid, hello, io=self._io))
        try:
            self._channels = self.cluster.accept_agents()
        except Exception:
            self.cluster.shutdown()
            if self._io is not None:
                self._io.stop()
            raise
        self._peers = PeerPool(label=f"{self.label}-sched")
        # arm the failure detector BEFORE channels are installed so
        # note_install (the synthetic first beat) has somewhere to land
        from .fault import FailureDetector
        self._detector = FailureDetector(
            self.liveness_cfg, float(self.cluster.heartbeat_s or 0.0))
        self._deadline_slack = max(
            1.0, 2.0 * float(self.cluster.heartbeat_s or 0.0))
        self._deadline_inflight = [dict() for _ in range(self.n_agents)]
        for a, ch in enumerate(self._channels):
            self._install_channel(a, ch)
        if self.resumption and hasattr(self.cluster, "start_acceptor"):
            # re-dials land on the harness's background acceptor and are
            # routed here with the session token for reconciliation
            self.cluster.start_acceptor(self._on_resume)
        runtime.store.set_fetcher(self._fetch_remote)
        if self.liveness_cfg.enabled:
            self._liveness_thread = threading.Thread(
                target=self._liveness_loop, daemon=True,
                name=f"{self.label}-liveness")
            self._liveness_thread.start()
        if not self.async_plane:
            super().start(runtime)
            return
        # async control plane (DESIGN.md §18): no dispatcher threads.
        # The scheduler's ready hook and every completion re-enter the
        # dispatch pump on the loop; blocking recovery work (agent
        # respawn, lost-input waits) is offloaded to a 2-thread pool so
        # the loop never stalls — total scheduler-side thread count is
        # O(1) in agent count.
        from concurrent.futures import ThreadPoolExecutor
        from .runtime import InputsPending
        self._inputs_pending = InputsPending
        self.runtime = runtime
        self._credits = [threading.Semaphore(self.pipeline_depth)
                         for _ in range(self.n_workers)]
        self._recovery = ThreadPoolExecutor(
            max_workers=2, thread_name_prefix=f"{self.label}-recover")
        runtime.scheduler.on_ready = self._schedule_pump
        self._io.call_soon(self._pump)

    def _install_channel(self, a: int, ch) -> None:
        self._data_addrs[a] = ch.data_addr()
        ch.on_close = lambda _a=a, _ch=ch: self._on_channel_down(_a, _ch)
        ch.on_push = lambda meta, frames, _a=a: self._on_push(_a, meta)
        if self.resumption:
            # the channel consults this before erroring its in-flight
            # slots: True = the executor adopted them (parked, awaiting a
            # session resume); False = fail them retryably as before
            ch.on_lost_pending = (
                lambda pending, _a=a, _ch=ch:
                    self._maybe_park(_a, _ch, pending))
        if self._detector is not None:
            self._detector.note_install(a)

    def _on_push(self, a: int, meta: dict) -> None:
        """Agent-initiated push (channel reader thread): feed the failure
        detector and route heartbeats into the runtime's telemetry hub.
        Guarded — the first beats can arrive before ``super().start``
        binds the runtime."""
        if meta.get("op") != "hb" or self._closing:
            return
        if self._detector is not None:
            # liveness is independent of whether telemetry is enabled
            self._detector.note_beat(a)
        rt = self.runtime
        if rt is not None:
            rt.telemetry.note_heartbeat(meta.get("node", a),
                                        meta.get("stats") or {})

    def _on_channel_down(self, a: int, ch) -> None:
        """Connection-death hook: recover even when nothing was in
        flight — the dead node may hold the only copy of published
        results (DESIGN.md §15)."""
        if self._detector is not None:
            # this hook runs on the dead channel's drain thread and can
            # arrive AFTER a session resume already installed (and
            # note_install-ed) the successor — wiping the fresh view
            # would read as an instant DEAD verdict on the next liveness
            # poll.  The order lock serializes against _do_resume's swap.
            with self._order_locks[a]:
                if self._channels[a] is ch:
                    self._detector.note_removed(a)
        if self._closing:
            return
        # session resumption (§20): a parked channel's recovery belongs
        # to the grace timer / resume handler, not the restart path.
        # _maybe_park is idempotent — on_lost_pending (fires only when
        # requests were in flight) and this hook race freely, and an idle
        # disconnect (no pending) parks here.
        if ch is not None and self._maybe_park(a, ch, {}):
            self._agent_up[a] = False
            return
        if self.async_plane:
            self._kick_restart(a, ch)
        else:
            self._restart_agent(a, ch)

    # -- liveness monitor (DESIGN.md §19) ------------------------------------
    def _liveness_loop(self) -> None:
        """Poll the failure detector and act on ``dead`` verdicts by
        closing the node's channel — everything downstream (failing the
        in-flight tasks retryable, respawn, §15 lineage re-execution) is
        the one existing ``on_close`` recovery path."""
        from .fault import DEAD
        det = self._detector
        poll = max(0.02, min(0.25, self.liveness_cfg.suspicion_s / 8.0))
        while not self._liveness_stop.wait(poll):
            if self._closing:
                return
            for a in range(self.n_agents):
                ch = self._channels[a]
                if ch is None or ch.closed or not self._agent_up[a]:
                    continue   # down or respawning: recovery owns it
                dl = self._deadline_inflight[a]
                if dl:
                    with self._stats_lock:
                        oldest = min(dl.values()) if dl else None
                    det.note_deadline(a, oldest)
                else:
                    det.note_deadline(a, None)
                if det.assess(a) == DEAD:
                    with self._stats_lock:
                        self.liveness_kills += 1
                    # a liveness verdict means the PROCESS is gone or
                    # wedged — never park this channel for resumption
                    ch.liveness_killed = True
                    ch.close()

    # -- async dispatch pump (DESIGN.md §18) ---------------------------------
    def _schedule_pump(self) -> None:
        io = self._io
        if io is not None and not self._stop_dispatch:
            io.call_soon(self._pump)

    def _pump(self) -> None:
        """The dispatch loop, as a loop callback: drain ready tasks into
        free credits, no dispatcher threads.  Runs on the IOLoop, so it
        must never block — credit acquire and scheduler take are
        non-blocking polls, and input resolution that would wait (a
        lost-node recovery race) is offloaded to the recovery pool."""
        rt = self.runtime
        if rt is None or self._stop_dispatch:
            return
        for worker in range(self.n_workers):
            if not self._agent_up[worker // self.wpn]:
                continue
            credits = self._credits[worker]
            node_id = rt.locality_domain(worker)
            while credits.acquire(blocking=False):
                if self._stop_dispatch:
                    credits.release()
                    return
                tid = rt.scheduler.take(worker, timeout=0)
                if tid is None:
                    credits.release()
                    break
                rt._note_worker_busy()
                try:
                    ex = rt.begin_task(tid, worker, node_id,
                                       block_inputs=False)
                except self._inputs_pending as pend:
                    self._recovery.submit(self._resume_begin, worker, pend)
                    continue
                if ex is None:   # cancelled / completed during resolution
                    rt._note_worker_idle()
                    credits.release()
                    continue
                self._submit_pipelined(worker, ex)

    def _resume_begin(self, worker: int, pend) -> None:
        """Recovery-pool tail of a non-blocking ``begin_task``: wait for
        the straggling input (or its error) off the loop, then submit."""
        rt = self.runtime
        ex = rt.resume_begin(pend)
        if ex is None:
            rt._note_worker_idle()
            self._credits[worker].release()
            self._schedule_pump()
            return
        self._submit_pipelined(worker, ex)

    def _kick_restart(self, a: int, ch, park: bool = True) -> None:
        """Route an agent death to the recovery pool: respawn blocks on
        process spawn + handshake, which must never run on the loop.
        The agent's workers are skipped by the pump until the
        replacement is up.  ``park=False`` is the resumption machinery
        giving up on a session (grace expired / resume failed): the
        respawn must proceed, never re-park the same dead channel."""
        if self._closing:
            return
        # a caller that observed ``closed`` before the park registered
        # (the flag flips a beat earlier) must not respawn a channel the
        # resume path owns — park it here instead (idempotent)
        if park and ch is not None and self._maybe_park(a, ch, {}):
            self._agent_up[a] = False
            return
        self._agent_up[a] = False

        def work():
            try:
                self._restart_agent(a, ch)
            finally:
                new_ch = self._channels[a]
                self._agent_up[a] = new_ch is not None and not new_ch.closed
                self._schedule_pump()

        self._recovery.submit(work)

    def _fetch_remote(self, key, rv, timeout=None):
        """The store's gather-path materializer: pull a node-resident
        datum straight from its producer's data plane, within the
        caller's remaining deadline when one was given."""
        from ..cluster.peer import PEER_FETCH_TIMEOUT, PeerFetchError
        if rv.addr is None or self._peers is None:
            raise PeerFetchError(
                f"no data-plane address for node {rv.node} "
                f"(d{key[0]}v{key[1]})")
        t = PEER_FETCH_TIMEOUT if timeout is None \
            else max(0.1, min(timeout, PEER_FETCH_TIMEOUT))
        return self._peers.fetch(rv.addr, key, rv.token, timeout=t)

    def shutdown(self, wait: bool = True, timeout: float = 10.0) -> None:
        from ..cluster.protocol import ConnectionClosed
        self._closing = True
        self._liveness_stop.set()
        if self._liveness_thread is not None:
            self._liveness_thread.join(timeout=2.0)
        self._halt_dispatch()
        if self.runtime is not None:
            self.runtime.store.set_fetcher(None)
            sched = getattr(self.runtime, "scheduler", None)
            if sched is not None and getattr(sched, "on_ready", None) is not None:
                sched.on_ready = None
        for ch in self._channels:
            if ch is not None and not ch.closed:
                try:
                    ch.post({"op": "exit"})
                except ConnectionClosed:
                    pass
        super().shutdown(wait=wait, timeout=timeout)
        if self._recovery is not None:
            # pending respawns observe _closing and exit fast; an
            # in-flight one must not wedge shutdown
            self._recovery.shutdown(wait=False, cancel_futures=True)
        if self._peers is not None:
            self._peers.close()
        for ch in self._channels:
            if ch is not None:
                ch.close()
        try:
            self.cluster.shutdown()
        except Exception:
            pass
        if self._io is not None:
            self._io.stop()

    # -- pipelined dispatch --------------------------------------------------
    def _submit_pipelined(self, worker: int, ex) -> None:
        from ..cluster.protocol import ConnectionClosed, pack_payload
        a, slot = divmod(worker, self.wpn)
        ch = self._channels[a]
        if ch is None or ch.closed:
            if self.async_plane:
                # a parked channel (§20) is coming back: hold the task
                # for the resumed session rather than burning a retry
                if self._defer_if_parked(a, worker, ex):
                    return
                # never respawn inline (it blocks); fail retryably and
                # let the recovery pool bring the agent back — unless the
                # channel is parked for session resumption (§20), whose
                # grace timer owns recovery
                if not self._closing and not self._is_parked(a):
                    self._kick_restart(a, ch)
                self._finish_cluster(worker, ex, error=WorkerCrashedError(
                    f"node agent {a} is down"))
                return
            if not self._closing:
                self._restart_agent(a, ch)   # no-op if already replaced
            ch = self._channels[a]
            if ch is None or ch.closed:
                self._finish_cluster(worker, ex, error=WorkerCrashedError(
                    f"node agent {a} is down"))
                return
        t = ex.t
        try:
            token, blob = self._fns.entry(t.fn)
            # the agent needs the declared output arity to know which
            # result positions are whole datums (RemoteRef-eligible); a
            # speculative clone reports its primary's arity
            n_out = len(t.out_keys)
            if t.speculative_of is not None and self.runtime is not None:
                try:
                    n_out = len(self.runtime.graph.get(t.speculative_of).out_keys)
                except KeyError:
                    pass
            with self._order_locks[a]:
                srcs = self._peer_sources(a, ex.input_keys)
                structure, frames, info = pack_payload(
                    (ex.args, ex.kwargs), ex.input_keys, self._resident[a],
                    peer_sources=srcs)
                meta = {"op": "task", "slot": slot, "token": token,
                        "structure": structure, "n_out": n_out}
                if token not in self._shipped_fns[a]:
                    meta["fn"] = blob
                if t.deadline_s is not None:
                    # the agent watchdog enforces deadline_s at the body
                    # (kills the wedged pool worker); the detector's
                    # slacked copy is the backstop for an agent too
                    # wedged to run its own watchdog.  Registered BEFORE
                    # the send: the reply callback (which pops) can fire
                    # on the reader thread the instant the send lands
                    meta["deadline_s"] = t.deadline_s
                    with self._stats_lock:
                        self._deadline_inflight[a][id(ex)] = (
                            time.monotonic() + t.deadline_s
                            + self._deadline_slack)
                mid = ch.request_cb(
                    meta, frames,
                    lambda rmeta, rframes, err, _w=worker, _a=a, _ch=ch,
                    _ex=ex: self._on_reply(_w, _a, _ch, _ex, rmeta,
                                           rframes, err))
                self._inflight_reqs[a][mid] = (worker, ex)
                self._shipped_fns[a].add(token)
                # a Fetch directive makes the key node-resident exactly
                # like a Put — the consumer agent registers the pull on
                # its reader in stream order, so later Refs are safe
                self._resident[a].update(info["put_keys"])
                self._resident[a].update(info["fetch_keys"])
                # residency generations (§20): one bump per mark message
                # sent; the agent bumps its mirror on receipt, and equal
                # counters at resume time validate a manifest entry
                gens = self._res_gen[a]
                for k in info["put_keys"]:
                    gens[k] = gens.get(k, 0) + 1
                for k in info["fetch_keys"]:
                    gens[k] = gens.get(k, 0) + 1
                with self._stats_lock:
                    self.puts += len(info["put_keys"])
                    self.refs += info["refs"]
                    self.fetches += len(info["fetch_keys"])
                    self.fetch_bytes += info["fetch_bytes"]
                    self.bytes_shipped += info["put_bytes"]
                    for k, nb in info["put_sizes"].items():
                        self._put_home.setdefault(k, (a, nb))
                if srcs:
                    # input resolution booked these copies as relayed
                    # before the transport was known — they move peer-to-
                    # peer after all
                    st = getattr(self.runtime, "store", None)
                    if st is not None:
                        for k in info["fetch_keys"]:
                            src = srcs.get(k)
                            if src is not None:
                                st.reattribute_to_p2p(k, src[0], dest=a)
        except (ConnectionClosed, OSError) as err:
            if t.deadline_s is not None and self._deadline_inflight:
                with self._stats_lock:
                    self._deadline_inflight[a].pop(id(ex), None)
            # the send failed while this call still owned the mid (the
            # reply callback will never fire): a parked channel defers
            # the task to the resumed session instead of failing it
            if self._defer_if_parked(a, worker, ex):
                return
            if not self._closing and not self._is_parked(a):
                if self.async_plane:
                    self._kick_restart(a, ch)
                else:
                    self._restart_agent(a, ch)
            crash = WorkerCrashedError(
                f"node agent {a} died executing "
                f"{getattr(t.fn, '__name__', t.fn)!r}")
            crash.__cause__ = err
            self._finish_cluster(worker, ex, error=crash)
        except BaseException as err:   # pack/pickle failure: plain failure
            self._finish_cluster(worker, ex, error=err)

    def _peer_sources(self, a: int,
                      input_keys) -> Optional[Dict[Tuple[int, int],
                                                   Tuple[int, str, int]]]:
        """Scheduler-resident input keys some OTHER live agent already
        caches: ``pack_payload`` turns them into by-key ``Fetch``
        directives so the bytes move agent→agent instead of crossing the
        scheduler link once per consumer agent (DESIGN.md §16).  Must be
        called under ``_order_locks[a]``."""
        if not self.p2p or not input_keys:
            return None
        keys = set(input_keys.values()) - self._resident[a]
        if not keys:
            return None
        with self._stats_lock:
            homes = [(k, self._put_home[k]) for k in keys
                     if k in self._put_home]
        srcs: Optional[Dict[Tuple[int, int], Tuple[int, str, int]]] = None
        for key, (home, nb) in homes:
            if home == a:
                continue   # ledger says resident elsewhere; re-Put is fine
            addr = self._data_addrs[home]
            ch = self._channels[home]
            if addr is None or ch is None or ch.closed:
                continue
            if srcs is None:
                srcs = {}
            srcs[key] = (home, addr, nb)
        return srcs

    def _on_reply(self, worker: int, a: int, ch, ex, rmeta, rframes,
                  err) -> None:
        """Completion path, on the channel reader (or its failure
        drainer): exactly one call per streamed task."""
        if rmeta is not None and rmeta.get("mid") is not None:
            self._inflight_reqs[a].pop(rmeta["mid"], None)
        if ex.t.deadline_s is not None and self._deadline_inflight:
            with self._stats_lock:
                self._deadline_inflight[a].pop(id(ex), None)
        if err is not None:
            if not self._closing and not self._is_parked(a):
                if self.async_plane:
                    self._kick_restart(a, ch)
                else:
                    self._restart_agent(a, ch)
            crash = WorkerCrashedError(
                f"node agent {a} died with task {ex.t.name!r} in flight")
            crash.__cause__ = err
            self._finish_cluster(worker, ex, error=crash)
            return
        if rmeta.get("op") == "done":
            self._tl.views = None
            # replication hint (§20): publish() consults this, in the same
            # thread, for every RemoteValue this reply produced — replicate
            # when the producer's run time clears the graph's fleet-wide
            # duration bar (re-running cheap tasks beats paying their copy)
            self._tl.replicate = False
            # the agent times the task body itself ("dur" in the done
            # reply) — scheduler-observed latency would fold pipeline
            # queue time into every producer's apparent cost.  The
            # profile is only consulted by the replication bar, so with
            # replication off the hot completion path skips the graph
            # lock entirely.
            if self.replication > 0 and self.runtime is not None:
                dur = rmeta.get("dur")
                if dur is not None:
                    dur = float(dur)
                    self.runtime.graph.note_run_s(ex.t.name, dur)
                    self._tl.replicate = (
                        dur >= self.runtime.graph.duration_threshold())
            try:
                result = self._decode_result(a, ch, rmeta, rframes)
            except BaseException as derr:
                self._finish_cluster(worker, ex, error=derr)
            else:
                self._finish_cluster(worker, ex, result=result)
        else:
            remote = self._remote_error(rmeta)
            from ..cluster.peer import PeerFetchError
            if isinstance(remote, PeerFetchError):
                # the agent failed to pull a datum we marked resident at
                # dispatch time (transient peer failure with the producer
                # channel still up — channel death has its own reset).
                # Strike this task's input keys from the agent's ledger
                # so the retry re-ships Put/Fetch instead of a Ref the
                # plane cannot resolve; over-striking a genuinely
                # resident Put key only costs a redundant re-Put (the
                # agent's pre-store skips keys it already holds)
                with self._order_locks[a]:
                    self._resident[a] -= set(ex.input_keys.values())
                # the failed pull may have chased a stale peer-source
                # home: forget it so the retry ships a fresh Put
                with self._stats_lock:
                    for k in ex.input_keys.values():
                        self._put_home.pop(k, None)
            self._finish_cluster(worker, ex, error=remote)

    def _finish_cluster(self, worker: int, ex, *, result: Any = None,
                        error: Optional[BaseException] = None) -> None:
        rt = self.runtime
        try:
            if error is not None:
                rt.fail_task(ex, error)
            else:
                rt.complete_task(ex, result)
        finally:
            self.task_done()
            rt._note_worker_idle()
            self._credits[worker].release()
            if self.async_plane:
                # a freed credit is dispatch capacity: re-enter the pump
                # (inline when the completion already runs on the loop)
                self._schedule_pump()

    def _remote_error(self, rmeta: dict) -> BaseException:
        return _rebuild_remote_error(rmeta.get("exc"), rmeta.get("tb"))

    def _decode_result(self, a: int, ch, rmeta: dict, rframes) -> Any:
        from ..core.futures import RemoteValue
        from ..cluster.protocol import (Frame, RemoteRef, frame_to_array,
                                        struct_nbytes)
        tokens = rmeta.get("tokens") or []
        gen = self._proc_gen[a]
        views: Dict[int, Tuple[int, int, Any, int]] = {}
        # inline (below-RJAX_INLINE_MAX) result arrays ride the reply
        # pickle — they crossed our link too, so the relay ledger counts
        # them (Frame/RemoteRef markers contribute 0 here; frames add
        # their own bytes below)
        with self._stats_lock:
            self.relay_result_bytes += struct_nbytes(rmeta["structure"])

        def dec(marker):
            if isinstance(marker, RemoteRef):
                # the datum stayed on the producing node: book a
                # placeholder; only this descriptor crossed our link
                rv = RemoteValue(marker.token, a, self._data_addrs[a],
                                 marker.nbytes)
                views[id(rv)] = (a, marker.token, ch, gen)
                with self._stats_lock:
                    self.remote_results += 1
                    self.deferred_result_bytes += marker.nbytes
                return rv
            arr = frame_to_array(rframes[marker.i])
            with self._stats_lock:
                self.relay_result_bytes += int(arr.nbytes)
            # the token is only meaningful in the PROCESS that minted it —
            # a respawned agent restarts its counter, so publish/drop
            # verify the process generation; a RESUMED session (§20) is
            # the same process, and its tokens stay valid across the
            # channel swap
            views[id(arr)] = (a, tokens[marker.i], ch, gen)
            return arr

        result = _walk(rmeta["structure"], dec, (Frame, RemoteRef))
        self._tl.views = views   # consumed by publish() in the same thread
        return result

    # -- data-plane hooks ----------------------------------------------------
    def publish(self, key, value):
        """The runtime bound a just-returned result to ``(data_id,
        version)``: pin it into the producing node's plane via ``alias``
        so later tasks there reference it without a wire crossing.  For a
        :class:`~repro.core.futures.RemoteValue` the alias is load-bearing
        — the node's token side-table holds the ONLY copy until it is
        bound to the datum key."""
        from ..core.futures import RemoteValue
        from ..cluster.protocol import ConnectionClosed
        views = getattr(self._tl, "views", None)
        if not views or not isinstance(value, (np.ndarray, RemoteValue)):
            return
        entry = views.pop(id(value), None)
        if entry is None:
            return
        a, token, ch, gen = entry
        key = tuple(key)
        if isinstance(value, RemoteValue):
            value.key = key
        nb = int(getattr(value, "nbytes", 0) or 0)
        published = False
        try:
            with self._order_locks[a]:
                # the token survives as long as the agent PROCESS does:
                # valid on the original channel and on any resumed
                # successor (§20), dead after a respawn (gen mismatch)
                if self._proc_gen[a] == gen:
                    cur = self._channels[a]
                    if cur is not None and not cur.closed:
                        cur.post({"op": "alias", "token": token,
                                  "key": key})
                        self._resident[a].add(key)
                        self._res_gen[a][key] = (
                            self._res_gen[a].get(key, 0) + 1)
                        if not isinstance(value, RemoteValue):
                            # a framed result relayed through us now
                            # lives BOTH here and on its producer: other
                            # agents can pull it from that plane instead
                            # of costing a second Put
                            with self._stats_lock:
                                self._put_home.setdefault(key, (a, nb))
                        published = True
                    elif self._is_parked(a):
                        # parked for resumption: defer the alias; the
                        # resume flush posts it (FIFO before any later
                        # Ref) or the grace-expiry restart discards it
                        self._parked_ops[a].append(
                            ("alias", token, key, nb,
                             isinstance(value, RemoteValue)))
                        published = True
        except ConnectionClosed:
            return   # the restart path resets this node's residency ledger
        if not published:
            # agent died/respawned since.  A plain array is already safe
            # in the store; a RemoteValue just entered the store pointing
            # at a dead node AFTER the crash sweep.  Recovery cannot run
            # HERE: publish() is called mid-completion, before mark_done,
            # so graph.resurrect would refuse the still-RUNNING producer
            # — park the key and let task_done() (which runs after the
            # completion) invalidate + re-execute from lineage
            if isinstance(value, RemoteValue) and not self._closing:
                orphans = getattr(self._tl, "orphaned", None)
                if orphans is None:
                    orphans = self._tl.orphaned = []
                orphans.append(key)
            return
        # asynchronous replication (§20): push a costly node-resident
        # result to k buddy planes over the existing p2p bcast leg —
        # fire-and-forget, outside the producer's ordering lock
        if (isinstance(value, RemoteValue) and self.replication > 0
                and getattr(self._tl, "replicate", False)
                and not self._closing):
            self._replicate(key, value, a)

    def task_done(self):
        """Drop result tokens that were never published (discarded
        outputs, lost speculation races) so agent side-tables don't grow
        — and recover keys orphaned by a publish that raced the
        producer's death (the task is DONE by now, so lineage
        re-execution can actually resurrect it)."""
        from ..cluster.protocol import ConnectionClosed
        views = getattr(self._tl, "views", None)
        if views:
            for a, token, ch, gen in views.values():
                with self._order_locks[a]:
                    if self._proc_gen[a] != gen:
                        continue   # the minting process is gone
                    cur = self._channels[a]
                    if cur is not None and not cur.closed:
                        try:
                            cur.post({"op": "drop", "token": token})
                        except ConnectionClosed:
                            pass
                    elif self._is_parked(a):
                        self._parked_ops[a].append(("drop", token))
        self._tl.views = None
        orphans = getattr(self._tl, "orphaned", None)
        self._tl.orphaned = None
        if orphans and self.runtime is not None and not self._closing:
            self.runtime.store.invalidate_keys(orphans)
            self._drop_residency(orphans)
            # relaunch every orphan key that is not (re-)published by now
            # — NOT just the ones invalidate_keys caught: the restart
            # sweep may have deleted the placeholder already, back when
            # the producer was still RUNNING and resurrect had to refuse
            # (it is DONE now, completions run before task_done).
            # relaunch_lost is idempotent for producers the sweep did
            # resurrect (resurrect no-ops unless DONE)
            need = [k for k in orphans
                    if not self.runtime.store.is_ready(k)]
            self.runtime.relaunch_lost(need)

    # -- collectives (DESIGN.md §16) -----------------------------------------
    def broadcast(self, key, value, store=None) -> int:
        """Fan a scheduler-resident datum out to every live agent: ONE
        encoded copy crosses the scheduler link (to a root agent), then
        the bytes move agent→agent in a doubling frontier — every ack
        promotes the receiver to a source for the next wave, so the wave
        count is ⌈log2(agents)⌉ (a binomial tree).  With p2p disabled the
        copies go out over each agent link concurrently instead (star
        topology, but never serialized behind one ordering lock).

        Blocks until the wave settles; returns the number of agents that
        hold the key.  Dead agents are skipped — a respawned agent picks
        the key up as a normal Put/peer-Fetch when a task needs it."""
        from ..cluster.peer import PEER_FETCH_TIMEOUT
        from ..cluster.protocol import (ConnectionClosed, pack_payload,
                                        struct_nbytes)
        key = tuple(key)
        nbytes = struct_nbytes(value)
        cv = threading.Condition()
        pending = [0]
        failed = [0]
        holders: List[int] = []
        free: List[int] = []
        waiting: List[int] = []
        enc: List[Any] = []   # lazily packed [structure, frames]

        for a in range(self.n_agents):
            ch = self._channels[a]
            if ch is None or ch.closed:
                continue
            with self._order_locks[a]:
                resident = key in self._resident[a]
            (holders if resident else waiting).append(a)
        free.extend(holders)

        def send_root(a: int) -> bool:
            ch = self._channels[a]
            if ch is None or ch.closed:
                return False
            if not enc:
                structure, frames, _ = pack_payload(value)
                enc.extend((structure, frames))
            try:
                with self._order_locks[a]:
                    if self._channels[a] is not ch:
                        return False
                    ch.request_cb(
                        {"op": "bcast", "key": key, "root": True,
                         "structure": enc[0]},
                        enc[1],
                        lambda rm, rf, err, _a=a: on_leg(_a, None, rm, err))
                    with self._stats_lock:
                        self.puts += 1
                        self.bytes_shipped += nbytes
                return True
            except (ConnectionClosed, OSError):
                return False

        def send_pull(child: int, parent: int) -> bool:
            ch = self._channels[child]
            addr = self._data_addrs[parent]
            if ch is None or ch.closed or addr is None:
                return False
            try:
                with self._order_locks[child]:
                    if self._channels[child] is not ch:
                        return False
                    ch.request_cb(
                        {"op": "bcast", "key": key, "addr": addr,
                         "node": parent, "nbytes": nbytes},
                        (),
                        lambda rm, rf, err, _c=child, _p=parent:
                            on_leg(_c, _p, rm, err))
                    with self._stats_lock:
                        self.fetches += 1
                        self.fetch_bytes += nbytes
                return True
            except (ConnectionClosed, OSError):
                return False

        def pump() -> None:
            """Launch every leg the current sources can serve.  Runs with
            ``cv`` held (re-entrant from on_leg: Condition uses an RLock)."""
            while waiting:
                if not self.p2p or (not holders and pending[0] == 0):
                    a = waiting.pop(0)
                    if send_root(a):
                        pending[0] += 1
                    else:
                        failed[0] += 1
                    continue
                if not free:
                    return
                parent = free.pop(0)
                child = waiting.pop(0)
                if send_pull(child, parent):
                    pending[0] += 1
                else:
                    failed[0] += 1
                    free.append(parent)

        def on_leg(a: int, parent: Optional[int], rmeta, err) -> None:
            ok = err is None and rmeta is not None \
                and rmeta.get("op") == "bcast_ok"
            with cv:
                pending[0] -= 1
                if parent is not None:
                    free.append(parent)
                if ok:
                    with self._order_locks[a]:
                        if self._channels[a] is not None:
                            self._resident[a].add(key)
                            # the agent bumped its mirror when the bcast
                            # landed; bump ours on the ack (§20)
                            self._res_gen[a][key] = (
                                self._res_gen[a].get(key, 0) + 1)
                    with self._stats_lock:
                        self._put_home.setdefault(key, (a, nbytes))
                    holders.append(a)
                    free.append(a)
                    if store is not None:
                        store.note_location(key, a, source=parent)
                else:
                    failed[0] += 1
                pump()
                cv.notify_all()

        deadline = time.monotonic() + PEER_FETCH_TIMEOUT + 30.0
        with cv:
            pump()
            while pending[0] > 0:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                cv.wait(timeout=left)
            with self._stats_lock:
                self.broadcasts += 1
            return len(holders)

    # -- failure handling ----------------------------------------------------
    def _drop_residency(self, keys) -> None:
        """Strike lost datum keys from EVERY agent's residency ledger: a
        retried consumer must get a fresh Put/Fetch for the recomputed
        value, never a Ref into a plane that predates the loss."""
        if not keys:
            return
        keyset = set(tuple(k) for k in keys)
        for a in range(self.n_agents):
            with self._order_locks[a]:
                self._resident[a] -= keyset
        with self._stats_lock:
            for k in keyset:
                self._put_home.pop(k, None)
                self._replicas.pop(k, None)

    # -- session resumption (DESIGN.md §20) ----------------------------------
    def _is_parked(self, a: int) -> bool:
        with self._park_lock:
            return a in self._disconnected

    def _maybe_park(self, a: int, ch, pending) -> bool:
        """Channel-death first refusal: adopt the in-flight slots and
        park the node for the grace window instead of killing it.
        Idempotent — the on_lost_pending hook and the on_close hook race
        freely, and later calls merge extra pending slots into the
        existing entry.  Returns False when resumption cannot apply
        (disabled, closing, liveness-killed, or already replaced) — the
        caller then runs the PR-9 fail/respawn path unchanged."""
        if (not self.resumption or self._closing
                or getattr(ch, "liveness_killed", False)):
            return False
        tok = getattr(self.cluster, "session_tokens", {}).get(a)
        if not tok:
            return False
        with self._park_lock:
            entry = self._disconnected.get(a)
            if entry is not None:
                if entry["ch"] is not ch:
                    return False   # a successor channel died, not ours
                entry["pending"].update(pending)
                return True
            if self._channels[a] is not ch:
                return False       # already replaced: restart owns it
            entry = {"ch": ch, "token": tok, "pending": dict(pending),
                     "next_mid": ch.next_mid, "state": "disconnected",
                     "deferred": [],
                     "deadline": time.monotonic() + self.reconnect_grace_s}
            timer = threading.Timer(self.reconnect_grace_s,
                                    self._grace_expired, (a, entry))
            timer.daemon = True
            entry["timer"] = timer
            self._disconnected[a] = entry
            # stop the pump from offering this agent's workers new tasks
            # while parked (set HERE, synchronously on the failing loop
            # thread, so no dispatch can slip between close and park)
            self._agent_up[a] = False
            timer.start()
        return True

    def _defer_if_parked(self, a: int, worker: int, ex) -> bool:
        """A dispatch raced the park: hold the task (credit and all)
        until the session resumes instead of burning one of its retries
        on a channel that is coming back."""
        with self._park_lock:
            entry = self._disconnected.get(a)
            if entry is None:
                return False
            entry["deferred"].append((worker, ex))
            return True

    def _grace_expired(self, a: int, entry: dict) -> None:
        """The agent did not re-dial in time: fall through to the
        normal kill-and-replay path (fail adopted slots retryably,
        respawn, §15 lineage re-execution)."""
        if self._closing:
            return
        with self._park_lock:
            if self._disconnected.get(a) is not entry \
                    or entry["state"] != "disconnected":
                return   # resumed (or resuming) in time
            del self._disconnected[a]
        self._fail_slots(a, entry["pending"].values())
        for worker, ex in entry["deferred"]:
            self._finish_cluster(worker, ex, error=WorkerCrashedError(
                f"node agent {a} session lost (grace expired)"))
        if self.async_plane:
            self._kick_restart(a, entry["ch"], park=False)
        else:
            self._restart_agent(a, entry["ch"])

    def _fail_slots(self, a: int, slots) -> None:
        """Error adopted slots retryably (grace expiry, or mids the
        resumed agent never received)."""
        from ..cluster.protocol import ConnectionClosed
        err = ConnectionClosed(
            f"agent {a} session lost", mid_message=True)
        for slot in slots:
            cb = getattr(slot, "callback", None)
            if cb is not None:
                try:
                    cb(None, None, err)
                except BaseException:
                    traceback.print_exc()
            else:
                slot.error = err
                slot.event.set()

    def _on_resume(self, conn, hello: dict) -> None:
        """A parked agent re-dialed with its session token (runs on the
        cluster's acceptor thread).  Reconcile and swap the channel in;
        any failure falls back to reject + the kill-and-replay path."""
        from ..cluster.protocol import send_msg
        a = hello.get("node_id")
        tokens = getattr(self.cluster, "session_tokens", {})
        ok = (isinstance(a, int) and 0 <= a < self.n_agents
              and not self._closing and self.resumption
              and tokens.get(a) == hello.get("resume"))
        entry = None
        if ok:
            # the park entry may lag the re-dial (the scheduler-side
            # read loop notices the break asynchronously): force the old
            # channel down and wait briefly for the park to land
            deadline = time.monotonic() + 2.0
            kicked = False
            while entry is None and time.monotonic() < deadline:
                with self._park_lock:
                    cur = self._disconnected.get(a)
                    if cur is not None and cur["state"] == "disconnected":
                        cur["state"] = "reconnecting"
                        entry = cur
                        break
                    if cur is not None:
                        break   # another resume is already in progress
                if not kicked:
                    kicked = True
                    old = self._channels[a]
                    if old is not None and not old.closed:
                        old.close()
                time.sleep(0.01)
        if entry is None:
            try:
                send_msg(conn, {"op": "welcome", "resumed": False})
            except Exception:
                pass
            conn.close()
            return
        timer = entry.get("timer")
        if timer is not None:
            timer.cancel()
        try:
            self._do_resume(a, conn, hello, entry)
        except BaseException:
            traceback.print_exc()
            with self._park_lock:
                self._disconnected.pop(a, None)
            try:
                conn.close()
            except OSError:
                pass
            self._fail_slots(a, entry["pending"].values())
            for worker, ex in entry["deferred"]:
                self._finish_cluster(worker, ex, error=WorkerCrashedError(
                    f"node agent {a} resume failed"))
            self._kick_restart(a, entry["ch"], park=False)

    def _do_resume(self, a: int, conn, hello: dict, entry: dict) -> None:
        """The resumption body: strike stale residency via the manifest,
        split in-flight mids at the agent's receive high-water, welcome,
        swap the channel, flush parked ops — the partition costs zero
        task re-executions (§20)."""
        from ..cluster.eventloop import AsyncAgentChannel
        from ..cluster.protocol import send_msg
        pending = entry["pending"]
        seen = int(hello.get("seen_mid") or 0)
        # the async writer drains its queue in mid order, so a mid the
        # agent has not seen implies nothing after it arrived either:
        # mids <= seen survive (the agent replays their recorded replies
        # or is still executing them); mids > seen never arrived — fail
        # them retryably once the channel is live again
        kept = {mid: slot for mid, slot in pending.items() if mid <= seen}
        lost = {mid: slot for mid, slot in pending.items() if mid > seen}
        # a lost mid that maps back to a task in the send ledger is not
        # dead work — the request never reached the agent, so it re-sends
        # on the resumed channel with a fresh mid, costing zero retries.
        # Only mids with no ledger entry (stats probes, bcast legs) fail.
        reqs = self._inflight_reqs[a]
        resend = []
        orphans = []
        for mid, slot in lost.items():
            req = reqs.pop(mid, None)
            if req is not None:
                resend.append(req)
            else:
                orphans.append(slot)
        # manifest reconciliation: an entry is valid iff the agent's
        # per-key mark generation matches ours — every mark message that
        # was in flight when the wire broke shows up as a mismatch and
        # is struck (conservative: a struck Put key only costs a re-ship)
        manifest = hello.get("manifest") or ()
        struck: Set[Tuple[int, int]] = set()
        with self._order_locks[a]:
            gens = self._res_gen[a]
            valid = set()
            for item in manifest:
                k = tuple(item[0])
                if gens.get(k, 0) == int(item[1]):
                    valid.add(k)
            struck = self._resident[a] - valid
            self._resident[a] = valid
            # an fn body that first shipped inside a lost message never
            # landed: strike the ship ledger so the re-send carries the
            # body again (the agent's blob table dedupes if it did land)
            for _w, _ex in resend:
                self._shipped_fns[a].discard(self._fns.entry(_ex.t.fn)[0])
            if struck:
                with self._stats_lock:
                    for k in struck:
                        home = self._put_home.get(k)
                        if home is not None and home[0] == a:
                            del self._put_home[k]
            # welcome + channel swap still under the ordering lock: a
            # dispatcher blocked on it must see the fully-resumed state
            send_msg(conn, {"op": "welcome", "node_id": a,
                            "resumed": True,
                            "epoch": int(hello.get("epoch") or 0),
                            "outstanding": sorted(kept)})
            new_ch = AsyncAgentChannel(conn, a, hello, io=self._io,
                                       start_mid=entry["next_mid"])
            new_ch.adopt_pending(kept)
            self._install_channel(a, new_ch)
            self._channels[a] = new_ch
            # flush ops that landed while parked, in arrival order (FIFO
            # before anything a dispatcher sends after the lock drops)
            for op in self._parked_ops[a]:
                if op[0] == "alias":
                    _, token, k, nb, _remote = op
                    new_ch.post({"op": "alias", "token": token, "key": k})
                    self._resident[a].add(k)
                    gens[k] = gens.get(k, 0) + 1
                elif op[0] == "drop":
                    new_ch.post({"op": "drop", "token": op[1]})
            self._parked_ops[a] = []
            with self._park_lock:
                self._disconnected.pop(a, None)
        # node-resident values homed here whose manifest entry was struck
        # are actually gone: invalidate + lineage, like a partial loss
        if struck and self.runtime is not None:
            gone = [k for k in self.runtime.store.homed_keys(a)
                    if k in struck]
            if gone:
                self.runtime.store.invalidate_keys(gone)
                self._drop_residency(gone)
                self.runtime.relaunch_lost(
                    [k for k in gone
                     if not self.runtime.store.is_ready(k)])
        self._agent_up[a] = True
        with self._stats_lock:
            self.reconnects += 1
        if orphans:
            self._fail_slots(a, orphans)
        # tasks whose send died on the wire, then tasks a dispatcher
        # deferred while the node was parked, go out on the resumed
        # channel — off this (acceptor) thread, in order
        for worker, ex in resend + entry["deferred"]:
            self._recovery.submit(self._submit_pipelined, worker, ex)
        self._schedule_pump()

    # -- replication (DESIGN.md §20) -----------------------------------------
    def _replicate(self, key, rv, a: int) -> None:
        """Fire-and-forget: ask up to k buddy agents to pull ``key``
        from its producer over the p2p data plane (the bcast leg, which
        is §13 memory-governed on the receiving plane).  Failures are
        ignored — a missing replica just means lineage recovery later."""
        from ..cluster.protocol import ConnectionClosed
        addr = self._data_addrs[a]
        if addr is None or not self.p2p:
            return
        want = min(self.replication, self.n_agents - 1)
        placed = 0
        for off in range(1, self.n_agents):
            if placed >= want:
                break
            b = (a + off) % self.n_agents
            ch = self._channels[b]
            if ch is None or ch.closed or not self._agent_up[b]:
                continue
            try:
                with self._order_locks[b]:
                    if self._channels[b] is not ch:
                        continue
                    ch.request_cb(
                        {"op": "bcast", "key": key, "addr": addr,
                         "node": a, "nbytes": rv.nbytes,
                         "token": rv.token},
                        (),
                        lambda rm, rf, err, _b=b, _k=key, _nb=rv.nbytes,
                        _a=a: self._on_replica(_b, _k, _nb, _a, rm, err))
            except (ConnectionClosed, OSError):
                continue
            placed += 1

    def _on_replica(self, b: int, key, nb: int, src: int, rmeta,
                    err) -> None:
        """A replica pull settled: book the copy (residency mark, store
        location, replica ledger) on success; on failure do nothing."""
        if err is not None or rmeta is None \
                or rmeta.get("op") != "bcast_ok" or self._closing:
            return
        with self._order_locks[b]:
            if self._channels[b] is None:
                return
            self._resident[b].add(key)
            self._res_gen[b][key] = self._res_gen[b].get(key, 0) + 1
        with self._stats_lock:
            self.replica_bytes += nb
            self._replicas.setdefault(key, set()).add(b)
        if self.runtime is not None:
            self.runtime.store.note_location(key, b, source=src)

    def _redirect_replicas(self, a: int) -> int:
        """Node ``a`` is really dead: point every store placeholder it
        homed at a surviving replica holder instead, so
        ``invalidate_lost`` skips them and zero producers re-execute for
        replicated keys.  Returns the number of keys redirected."""
        rt = self.runtime
        if rt is None:
            return 0
        # snapshot candidate homes OUTSIDE the store lock (redirect_node
        # runs under it and must not take executor locks)
        with self._stats_lock:
            cand: Dict[Tuple[int, int], Tuple[int, str]] = {}
            for key, holders in self._replicas.items():
                for b in sorted(holders):
                    if b == a or not self._agent_up[b]:
                        continue
                    ch = self._channels[b]
                    addr = self._data_addrs[b]
                    if ch is None or ch.closed or addr is None:
                        continue
                    cand[key] = (b, addr)
                    break
        if not cand:
            return 0
        swapped = rt.store.redirect_node(a, cand)
        if swapped:
            with self._stats_lock:
                self.replica_hits += len(swapped)
        return len(swapped)

    def _restart_agent(self, a: int, failed_ch) -> None:
        with self._restart_lock:
            if self._channels[a] is not failed_ch:
                return   # another dispatcher already replaced it
            # a stale park entry must not adopt a resume after the
            # process is replaced (respawn also mints a new session
            # token, so a late re-dial is rejected outright)
            with self._park_lock:
                stale = self._disconnected.pop(a, None)
            if stale is not None:
                timer = stale.get("timer")
                if timer is not None:
                    timer.cancel()
                if stale["pending"]:
                    self._fail_slots(a, stale["pending"].values())
                for worker, ex in stale["deferred"]:
                    self._finish_cluster(worker, ex,
                                         error=WorkerCrashedError(
                                             f"node agent {a} replaced"))
            old_addr = self._data_addrs[a]
            if failed_ch is not None:
                failed_ch.close()
            new_ch = None
            if getattr(self.cluster, "can_respawn", False) \
                    and not self._closing:
                try:
                    new_ch = self.cluster.respawn(a)
                except Exception:
                    new_ch = None
            if self._deadline_inflight:
                # in-flight deadline entries die with the channel (each
                # reply callback also pops its own — this is belt and
                # braces against the detector chasing ghosts)
                with self._stats_lock:
                    self._deadline_inflight[a].clear()
            with self._order_locks[a]:
                self._resident[a] = set()
                self._shipped_fns[a] = set()
                self._res_gen[a] = {}
                self._parked_ops[a] = []
                self._inflight_reqs[a] = {}
                # tokens minted by the dead process are invalid forever;
                # publish/drop for its results become no-ops (§20)
                self._proc_gen[a] += 1
                self._data_addrs[a] = None
                if new_ch is not None:
                    # data addr + on_close BEFORE the channel is exposed:
                    # a dispatcher blocked on this order lock ships the
                    # moment we release it, and its reply must not mint
                    # RemoteValues with addr=None
                    self._install_channel(a, new_ch)
                self._channels[a] = new_ch
            if self._peers is not None:
                self._peers.drop(old_addr)   # the pooled conn died with it
            # every peer-source home pointing at the dead plane is stale
            with self._stats_lock:
                self._put_home = {k: v for k, v in self._put_home.items()
                                  if v[0] != a}
            # the store's residency metadata must die with the agent too,
            # or locality keeps steering reads at data the replacement
            # doesn't hold and the transfer ledger undercounts re-ships —
            # and every node-resident result homed there is GONE: first
            # rehome what a surviving replica can serve (§20), then the
            # runtime invalidates the remaining placeholders and
            # re-executes their producers from graph lineage (§15)
            with self._stats_lock:
                for k in list(self._replicas):
                    self._replicas[k].discard(a)
                    if not self._replicas[k]:
                        del self._replicas[k]
            if self.runtime is not None:
                self._redirect_replicas(a)
                self.runtime.store.forget_node(a)
                lost = self.runtime.recover_lost_node(a)
                self._drop_residency(lost)
            if new_ch is not None:
                self.agent_restarts += 1

    # -- metrics -------------------------------------------------------------
    def liveness(self) -> Dict[int, dict]:
        """Per-agent liveness view (state, beat age, beat count) for
        ``/api/status`` and the dashboard — the failure detector's own
        numbers, so what the UI shows is exactly what verdicts use.
        Agents between channel death and reinstall report ``respawning``;
        agents parked for session resumption (§20) report
        ``disconnected`` (grace window open) or ``reconnecting`` (a
        resume is being reconciled), and every row carries its replica
        count."""
        det = self._detector
        snap = det.snapshot() if det is not None else {}
        with self._park_lock:
            parked = {a: e["state"] for a, e in self._disconnected.items()}
        repl: Dict[int, int] = {}
        with self._stats_lock:
            for holders in self._replicas.values():
                for b in holders:
                    repl[b] = repl.get(b, 0) + 1
        out: Dict[int, dict] = {}
        for a in range(self.n_agents):
            ent = snap.get(a)
            if ent is None:
                ent = {"state": "respawning", "beat_age_s": None, "beats": 0}
            st = parked.get(a)
            if st is not None:
                ent = dict(ent, state=st)
            ent = dict(ent, replicas=repl.get(a, 0))
            out[a] = ent
        return out

    def agent_stats(self) -> List[Optional[dict]]:
        """Round-trip per-agent stats (pool + node plane); ``None`` for
        agents that are down."""
        out: List[Optional[dict]] = []
        for ch in self._channels:
            if ch is None or ch.closed:
                out.append(None)
                continue
            try:
                meta, _ = ch.request({"op": "stats"}, timeout=10.0)
                out.append(meta.get("stats"))
            except Exception:
                out.append(None)
        return out

    def stats(self) -> dict:
        return {
            "backend": self.name,
            "n_agents": self.n_agents,
            "workers_per_node": self.wpn,
            "pipeline_depth": self.pipeline_depth,
            "control_plane": self.control_plane,
            "agent_restarts": self.agent_restarts,
            "liveness_kills": self.liveness_kills,
            "reconnects": self.reconnects,
            "replica_bytes": self.replica_bytes,
            "replica_hits": self.replica_hits,
            "p2p": self.p2p,
            "broadcasts": self.broadcasts,
            "puts": self.puts,
            "refs": self.refs,
            "fetches": self.fetches,
            "fetch_bytes": self.fetch_bytes,
            "bytes_shipped": self.bytes_shipped,
            "relay_result_bytes": self.relay_result_bytes,
            "remote_results": self.remote_results,
            "deferred_result_bytes": self.deferred_result_bytes,
            # everything that crossed the scheduler's own link for task
            # data: Put payloads out + result frames back.  The §15
            # acceptance metric — peer traffic lives in fetch_bytes and
            # the store's transfer_detail() instead
            "relay_bytes": self.bytes_shipped + self.relay_result_bytes,
        }


BACKENDS = {"thread": ThreadExecutor, "process": ProcessExecutor,
            "cluster": ClusterExecutor}


def make_executor(backend: str, n_workers: int, label: str = "rjax",
                  **kw) -> ExecutorBackend:
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown executor backend {backend!r}; choose from {sorted(BACKENDS)}")
    return BACKENDS[backend](n_workers, label, **kw)
