"""Pluggable serialization codecs (paper §3.3.3 + Table 1).

COMPSs exchanges task parameters through a language-agnostic byte channel;
RCOMPSs benchmarked nine R serializers and picked RMVL (a low-overhead,
memory-mappable binary format).  We reproduce the *methodology*: a codec
registry with a common interface, a benchmark harness that measures
serialize/deserialize times across block sizes, and a default choice made
from the measurements.

Codecs
------
* ``pickle``   — stdlib pickle protocol 5 (general, baseline — the
                 ``serialize``/``RDS`` analogue).
* ``npy``      — ``numpy.save`` container (the ``fst``/``qs`` analogue:
                 array-only, fast, portable).
* ``raw``      — 24-byte header + raw buffer ``tobytes()`` (the
                 ``writeBin`` analogue; arrays only, no copy on encode for
                 contiguous data).
* ``mmap``     — RMVL analogue: header + raw buffer written to a file;
                 deserialization returns a ``numpy.memmap`` view — *zero-copy
                 reconstruction*, the property the paper credits for RMVL's
                 win on the deserialize side.

In-process task hand-off passes values by reference (no codec) — see
DESIGN.md §3: serialization only happens at address-space boundaries
(checkpoint, host↔host transport, spill).
"""
from __future__ import annotations

import io
import os
import pickle
import struct
import tempfile
import time
import weakref
from typing import Any, Callable, Dict, Tuple

import numpy as np

_MAGIC = b"RJX1"
_DTYPES = {
    "f2": np.float16, "f4": np.float32, "f8": np.float64,
    "i1": np.int8, "i2": np.int16, "i4": np.int32, "i8": np.int64,
    "u1": np.uint8, "u2": np.uint16, "u4": np.uint32, "u8": np.uint64,
    "b1": np.bool_,
}
_DTYPE_CODES = {np.dtype(v).str[1:]: k for k, v in _DTYPES.items()}


def _pack_header(arr: np.ndarray) -> bytes:
    code = arr.dtype.str[1:]
    if code not in _DTYPE_CODES:
        raise TypeError(f"raw codec does not support dtype {arr.dtype}")
    shape = arr.shape
    return (
        _MAGIC
        + struct.pack("<2sH", code.encode(), len(shape))
        + struct.pack(f"<{len(shape)}q", *shape)
    )


def _unpack_header(buf: memoryview) -> Tuple[np.dtype, tuple, int]:
    if bytes(buf[:4]) != _MAGIC:
        raise ValueError("bad magic")
    code, ndim = struct.unpack_from("<2sH", buf, 4)
    shape = struct.unpack_from(f"<{ndim}q", buf, 8)
    return np.dtype(_DTYPES[code.decode()]), tuple(shape), 8 + 8 * ndim


# --------------------------------------------------------------------- codecs
def _pickle_ser(obj: Any) -> bytes:
    return pickle.dumps(obj, protocol=5)


def _pickle_de(data: bytes) -> Any:
    return pickle.loads(data)


def _npy_ser(obj: Any) -> bytes:
    arr = np.asarray(obj)
    bio = io.BytesIO()
    np.save(bio, arr, allow_pickle=False)
    return bio.getvalue()


def _npy_de(data: bytes) -> Any:
    return np.load(io.BytesIO(data), allow_pickle=False)


def as_c_contiguous(obj: Any) -> np.ndarray:
    """Copy-on-encode for non-contiguous inputs (strided slices, Fortran
    order): sliced blocks crossing an address-space boundary must
    round-trip, not raise.  Unlike ``np.ascontiguousarray``, this keeps
    0-d arrays 0-d (ascontiguousarray silently promotes them to shape
    ``(1,)``, corrupting the codec header).  Shared by the raw/mmap
    codecs, the shm object plane, and the cluster wire frames."""
    return np.asarray(obj, order="C")


def _raw_ser(obj: Any) -> bytes:
    arr = as_c_contiguous(obj)
    return _pack_header(arr) + arr.tobytes()


def _raw_de(data: bytes) -> Any:
    mv = memoryview(data)
    dtype, shape, off = _unpack_header(mv)
    return np.frombuffer(mv, dtype=dtype, offset=off).reshape(shape)


class Codec:
    def __init__(self, name: str, ser: Callable[[Any], bytes], de: Callable[[bytes], Any],
                 array_only: bool = False):
        self.name = name
        self.ser = ser
        self.de = de
        self.array_only = array_only


CODECS: Dict[str, Codec] = {
    "pickle": Codec("pickle", _pickle_ser, _pickle_de),
    "npy": Codec("npy", _npy_ser, _npy_de, array_only=True),
    "raw": Codec("raw", _raw_ser, _raw_de, array_only=True),
}

DEFAULT_CODEC = "raw"  # measured winner — see benchmarks/serialization_bench.py


# ----------------------------------------------------------------- file-based
def _unlink_quiet(path: str) -> None:
    try:
        os.unlink(path)
    except OSError:
        pass


class MmapCodec:
    """RMVL analogue: file-backed zero-copy deserialization.

    A deserialized ``numpy.memmap`` *view* pins its backing file: nothing
    else knows when the view dies, so temp spill files used to accumulate
    in ``$TMPDIR`` forever.  ``owned=True`` ties the file's lifetime to
    the returned view (a ``weakref.finalize`` unlinks it at GC — on POSIX
    the mapping stays valid even after the unlink, so live slices keep
    working); :meth:`spill` packages the write-then-own round trip.
    """

    name = "mmap"
    array_only = True

    def ser_to_file(self, obj: Any, path: str) -> int:
        arr = as_c_contiguous(obj)
        header = _pack_header(arr)
        with open(path, "wb") as f:
            f.write(struct.pack("<I", len(header)))
            f.write(header)
            arr.tofile(f)
        return 4 + len(header) + arr.nbytes

    def de_from_file(self, path: str, owned: bool = False) -> np.ndarray:
        with open(path, "rb") as f:
            (hlen,) = struct.unpack("<I", f.read(4))
            header = f.read(hlen)
        dtype, shape, _ = _unpack_header(memoryview(header))
        view = np.memmap(path, dtype=dtype, mode="r", offset=4 + hlen, shape=shape)
        if owned:
            weakref.finalize(view, _unlink_quiet, path)
        return view

    def spill(self, obj: Any, dir: str = None) -> np.ndarray:
        """Write ``obj`` to a fresh temp file and return a self-cleaning
        zero-copy view: the file is unlinked when the view is collected."""
        fd, path = tempfile.mkstemp(prefix="rjax_spill_", suffix=".rjx", dir=dir)
        os.close(fd)
        try:
            self.ser_to_file(obj, path)
            return self.de_from_file(path, owned=True)
        except BaseException:
            _unlink_quiet(path)
            raise


def serialize(obj: Any, codec: str = DEFAULT_CODEC) -> bytes:
    c = CODECS[codec]
    if c.array_only and not isinstance(obj, np.ndarray):
        c = CODECS["pickle"]  # graceful fallback for non-array payloads
    return c.ser(obj)


def deserialize(data: bytes, codec: str = DEFAULT_CODEC) -> Any:
    # pickle fallback is self-describing; raw/npy have magic we can sniff
    if codec in ("raw", "npy") and not (
        data[:4] == _MAGIC or data[:6] == b"\x93NUMPY"
    ):
        return CODECS["pickle"].de(data)
    return CODECS[codec].de(data)


# -------------------------------------------------------------- Table 1 bench
def benchmark_codecs(sizes=(1024, 4096, 8192), dtype=np.float64, repeats: int = 3):
    """Reproduces Table 1's methodology: square blocks of increasing size,
    serialize (S) and deserialize (D) wall times per codec.  Returns
    ``{codec: {size: (s_seconds, d_seconds)}}``."""
    rng = np.random.default_rng(0)
    results: Dict[str, Dict[int, Tuple[float, float]]] = {}
    tmpdir = tempfile.mkdtemp(prefix="rjax_serbench_")
    for size in sizes:
        arr = rng.standard_normal((size, size)).astype(dtype)
        for name, codec in CODECS.items():
            s_best = d_best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                blob = codec.ser(arr)
                t1 = time.perf_counter()
                out = codec.de(blob)
                t2 = time.perf_counter()
                s_best = min(s_best, t1 - t0)
                d_best = min(d_best, t2 - t1)
            assert np.asarray(out).shape == arr.shape
            results.setdefault(name, {})[size] = (s_best, d_best)
        # file-backed mmap codec
        mc = MmapCodec()
        path = os.path.join(tmpdir, f"blk{size}.rjx")
        s_best = d_best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            mc.ser_to_file(arr, path)
            t1 = time.perf_counter()
            view = mc.de_from_file(path)
            _ = view[0, 0]  # touch first page
            t2 = time.perf_counter()
            s_best = min(s_best, t1 - t0)
            d_best = min(d_best, t2 - t1)
        results.setdefault("mmap", {})[size] = (s_best, d_best)
        del view
        _unlink_quiet(path)
    try:
        os.rmdir(tmpdir)
    except OSError:
        pass
    return results
