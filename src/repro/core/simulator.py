"""Calibrated discrete-event simulator for scaling studies.

The paper's evaluation (Figs. 6-9) is wall-clock weak/strong scaling on two
supercomputers.  This repository runs on one CPU core, so multi-core speedup
is physically unobservable here; instead we *replay the very same task DAGs*
under a virtual machine model:

* N nodes × W workers, greedy list scheduling (same policies as the real
  scheduler);
* per-task durations from cost models **calibrated against real measured
  executions** of the task functions (see ``algorithms/*.cost_model``);
* a transport model — crossing nodes costs ``latency + bytes/bandwidth`` plus
  serialize/deserialize at the measured codec throughput (paper §3.3.3);
* a master dispatch overhead per task — the serial component that produces
  the paper's efficiency roll-off at high core counts.

The simulator is property-tested against classic scheduling bounds: for zero
transport/dispatch overhead a greedy schedule satisfies
``max(T1/P, T∞) ≤ T_P ≤ T1/P + T∞`` (Graham).
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class SimTask:
    tid: int
    name: str
    duration: float               # seconds of pure compute
    deps: Tuple[int, ...] = ()
    out_bytes: int = 0


@dataclass
class MachineModel:
    n_nodes: int = 1
    workers_per_node: int = 1
    # transport (paper §3.3.3: file-based parameter passing between spaces)
    bandwidth_Bps: float = 12.5e9        # ~100 Gb/s interconnect
    latency_s: float = 25e-6
    ser_Bps: Optional[float] = 2e9       # codec throughput (raw codec measured)
    intranode_free: bool = True          # same-node hand-off is by reference
    dispatch_overhead_s: float = 0.0     # serial master cost per task launch
    worker_init_s: float = 0.0           # per-worker startup (paper §5.4:
                                         # slow worker init hurt MareNostrum)

    @property
    def n_workers(self) -> int:
        return self.n_nodes * self.workers_per_node


@dataclass
class ScheduledTask:
    tid: int
    name: str
    worker: int
    node: int
    start: float
    transfer: float
    end: float


@dataclass
class SimResult:
    makespan: float
    total_work: float
    critical_path: float
    n_workers: int
    schedule: List[ScheduledTask] = field(default_factory=list)
    transfer_total: float = 0.0

    @property
    def speedup(self) -> float:
        return self.total_work / self.makespan if self.makespan > 0 else 0.0

    @property
    def efficiency(self) -> float:
        return self.speedup / self.n_workers if self.n_workers else 0.0


def critical_path(tasks: Sequence[SimTask]) -> float:
    by_id = {t.tid: t for t in tasks}
    memo: Dict[int, float] = {}

    def depth(tid: int) -> float:
        if tid in memo:
            return memo[tid]
        t = by_id[tid]
        memo[tid] = t.duration + max((depth(d) for d in t.deps), default=0.0)
        return memo[tid]

    # iterative topological accumulation to avoid recursion limits
    order = _topo_order(tasks)
    for tid in order:
        t = by_id[tid]
        memo[tid] = t.duration + max((memo[d] for d in t.deps), default=0.0)
    return max(memo.values(), default=0.0)


def _topo_order(tasks: Sequence[SimTask]) -> List[int]:
    indeg = {t.tid: len(t.deps) for t in tasks}
    children: Dict[int, List[int]] = {t.tid: [] for t in tasks}
    for t in tasks:
        for d in t.deps:
            children[d].append(t.tid)
    q = deque(sorted(tid for tid, k in indeg.items() if k == 0))
    order = []
    while q:
        tid = q.popleft()
        order.append(tid)
        for c in children[tid]:
            indeg[c] -= 1
            if indeg[c] == 0:
                q.append(c)
    if len(order) != len(tasks):
        raise ValueError("cycle in task graph")
    return order


def simulate(
    tasks: Sequence[SimTask],
    machine: MachineModel,
    policy: str = "fifo",
) -> SimResult:
    """Greedy event-driven list scheduling of ``tasks`` on ``machine``."""
    by_id = {t.tid: t for t in tasks}
    if len(by_id) != len(tasks):
        raise ValueError("duplicate task ids")
    indeg = {t.tid: len(t.deps) for t in tasks}
    children: Dict[int, List[int]] = {t.tid: [] for t in tasks}
    for t in tasks:
        for d in t.deps:
            if d not in by_id:
                raise ValueError(f"task {t.tid} depends on unknown {d}")
            children[d].append(t.tid)

    ready: deque = deque(sorted(tid for tid, k in indeg.items() if k == 0))
    data_loc: Dict[int, set] = {}
    idle: List[int] = list(range(machine.n_workers))
    events: List[Tuple[float, int, int, int]] = []   # (time, seq, tid, worker)
    seq = itertools.count()
    master_free = 0.0
    schedule: List[ScheduledTask] = []
    transfer_total = 0.0
    done_t: Dict[int, float] = {}

    def node_of(w: int) -> int:
        return w // machine.workers_per_node

    def transfer_cost(t: SimTask, node: int) -> float:
        cost = 0.0
        for d in t.deps:
            locs = data_loc.get(d, set())
            if machine.intranode_free and node in locs:
                continue
            nbytes = by_id[d].out_bytes
            if nbytes <= 0:
                continue
            cost += machine.latency_s + nbytes / machine.bandwidth_Bps
            if machine.ser_Bps:
                cost += 2.0 * nbytes / machine.ser_Bps  # serialize + deserialize
            locs = data_loc.setdefault(d, set())
            locs.add(node)
        return cost

    def pick(worker: int) -> Optional[int]:
        if not ready:
            return None
        if policy == "lifo":
            return ready.pop()
        if policy == "locality":
            node = node_of(worker)
            best_i, best = 0, -1.0
            for i, tid in enumerate(ready):
                t = by_id[tid]
                if not t.deps:
                    score = 0.0
                else:
                    score = sum(1.0 for d in t.deps if node in data_loc.get(d, ()))
                    score /= len(t.deps)
                if score > best:
                    best_i, best = i, score
            ready.rotate(-best_i)
            tid = ready.popleft()
            ready.rotate(best_i)
            return tid
        return ready.popleft()  # fifo

    now = 0.0

    def try_assign(now: float) -> float:
        nonlocal master_free, transfer_total
        while idle and ready:
            w = idle.pop(0)
            tid = pick(w)
            t = by_id[tid]
            start = now
            if machine.dispatch_overhead_s > 0:
                start = max(start, master_free)
                master_free = start + machine.dispatch_overhead_s
                start = master_free
            tr = transfer_cost(t, node_of(w))
            if machine.worker_init_s > 0:
                start = max(start, machine.worker_init_s)
            end = start + tr + t.duration
            transfer_total += tr
            schedule.append(ScheduledTask(tid, t.name, w, node_of(w), start, tr, end))
            heapq.heappush(events, (end, next(seq), tid, w))
        return master_free

    try_assign(0.0)
    while events:
        now, _, tid, w = heapq.heappop(events)
        done_t[tid] = now
        data_loc.setdefault(tid, set()).add(node_of(w))
        for c in children[tid]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
        idle.append(w)
        idle.sort()
        try_assign(now)

    if len(done_t) != len(tasks):
        raise RuntimeError("simulation dead-locked (graph not fully executed)")

    total_work = sum(t.duration for t in tasks)
    return SimResult(
        makespan=now,
        total_work=total_work,
        critical_path=critical_path(tasks),
        n_workers=machine.n_workers,
        schedule=schedule,
        transfer_total=transfer_total,
    )


# --------------------------------------------------------------- calibration
class CostModel:
    """Affine cost model ``seconds = a + b * units`` fitted from measured
    (units, seconds) samples of real task executions (least squares)."""

    def __init__(self, a: float, b: float, name: str = ""):
        self.a = max(0.0, a)
        self.b = max(0.0, b)
        self.name = name

    def __call__(self, units: float) -> float:
        return self.a + self.b * units

    @classmethod
    def fit(cls, samples: Sequence[Tuple[float, float]], name: str = "") -> "CostModel":
        if len(samples) == 1:
            u, s = samples[0]
            return cls(0.0, s / max(u, 1e-12), name)
        import numpy as np

        us = np.array([u for u, _ in samples], dtype=np.float64)
        ts = np.array([t for _, t in samples], dtype=np.float64)
        A = np.stack([np.ones_like(us), us], axis=1)
        coef, *_ = np.linalg.lstsq(A, ts, rcond=None)
        return cls(float(coef[0]), float(coef[1]), name)


def replay_graph(graph, default_bytes: int = 0) -> List[SimTask]:
    """Convert a *measured* runtime TaskGraph into SimTasks (durations =
    observed durations), so a real small-scale run can be re-scheduled on a
    virtual large machine."""
    from .dag import TaskState

    nodes = [n for n in graph.nodes() if n.speculative_of is None]
    keep = {n.task_id for n in nodes if n.state == TaskState.DONE}
    producer: Dict[Tuple[int, int], int] = {}
    for n in nodes:
        for key in n.out_keys:
            producer[key] = n.task_id
    out = []
    for n in nodes:
        if n.task_id not in keep:
            continue
        deps = tuple(sorted({producer[k] for k in n.dep_keys
                             if k in producer and producer[k] in keep}))
        out.append(SimTask(n.task_id, n.name, n.duration, deps,
                           out_bytes=default_bytes or n.nbytes_in))
    return out
