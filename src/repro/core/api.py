"""The RCOMPSs user-facing API, reproduced (paper §3.2).

The paper exposes five functions; we keep the names (aliased) plus the
pythonic spellings used throughout this repo:

==========================  =============================
paper (R)                   here (Python)
==========================  =============================
``compss_start()``          ``runtime_start()``
``task(f, ...)``            ``task(f, ...)`` (also usable as decorator)
``compss_barrier()``        ``barrier()``
``compss_wait_on(x)``       ``wait_on(x)``
``compss_stop()``           ``runtime_stop()``
==========================  =============================

Example (the paper's Fig. 2 program, see examples/quickstart.py)::

    from repro.core import api

    def add(x, y):
        return x + y

    api.runtime_start(n_workers=4)
    add_t = api.task(add)
    res1 = add_t(4, 5)
    res2 = add_t(6, 7)
    res3 = add_t(res1, res2)          # dependency discovered automatically
    print(api.wait_on(res3))          # -> 22
    api.runtime_stop()
"""
from __future__ import annotations

import functools
import threading
from typing import Any, Callable, Iterable, List, Optional

from .config import RuntimeConfig
from .fault import RetryPolicy, SpeculationConfig
from .runtime import Runtime

_lock = threading.Lock()
_runtime: Optional[Runtime] = None


def runtime_start(n_workers: Optional[int] = None, *,
                  config: Optional[RuntimeConfig] = None,
                  **kwargs: Any) -> Runtime:
    """Initialize the global runtime (``compss_start``).

    Configuration is one :class:`repro.core.config.RuntimeConfig`
    (DESIGN.md §18): pass ``config=RuntimeConfig(...)``, plain keyword
    arguments (every pre-existing ``runtime_start`` kwarg is a
    ``RuntimeConfig`` field, so old call sites run unmodified), or both —
    explicit kwargs override the config object, and unset knobs fall
    through env vars to the built-in defaults under the one documented
    precedence rule (explicit > env > welcome > default).  The returned
    runtime is a context manager::

        with api.runtime_start(backend="cluster", n_agents=2) as rt:
            ...                       # runtime_stop guaranteed on exit

    ``backend`` selects the executor model (see
    :mod:`repro.core.executors`): ``"thread"`` runs task bodies on the
    dispatcher threads in this address space; ``"process"`` runs them in
    persistent worker processes behind a shared-memory object plane (the
    paper's per-node worker architecture, §3.3.2); ``"cluster"`` runs
    them on real TCP node agents (DESIGN.md §12) — pass a started
    ``cluster=`` harness (e.g. ``repro.cluster.LocalCluster``, which also
    accepts externally-launched ``python -m repro.cluster.agent``
    processes with ``spawn=False``), or just ``n_agents=N`` to spawn a
    localhost cluster with ``workers_per_node`` workers on each agent.
    Under ``"cluster"``, ``n_workers`` is derived:
    ``n_agents × workers_per_node``.

    ``memory_budget`` bounds every object plane (DESIGN.md §13): e.g.
    ``"256M"`` or ``2**30``; cold arrays past the high watermark spill
    to mmap-codec files (``spill_dir`` or ``$TMPDIR``) and fault back
    transparently on the next read, so working sets larger than one
    node's RAM degrade instead of dying.  Defaults to
    ``RJAX_MEMORY_BUDGET``; ``None``/``0`` = unbounded.

    ``pipeline_depth`` bounds the in-flight task descriptors per worker
    on the out-of-process backends (DESIGN.md §14): depth 1 is classic
    stop-and-wait dispatch, higher depths overlap dispatch with remote
    execution.  Defaults to ``RJAX_PIPELINE_DEPTH`` (4).

    ``telemetry`` toggles the live telemetry plane (DESIGN.md §17):
    agent heartbeats (or the in-process sampler), the bounded
    task-lifecycle ring, and the transfer matrix behind
    ``runtime_stats()["data_plane"]["matrix"]``.  Defaults to following
    ``tracing``.  ``dashboard_port`` serves the zero-dependency live
    dashboard on ``127.0.0.1:<port>`` (``0`` = pick an ephemeral port,
    read it back from ``runtime.dashboard.url``; implies
    ``telemetry=True``); ``RJAX_DASHBOARD=<port>`` does the same from
    the environment."""
    global _runtime
    cfg = config if config is not None else RuntimeConfig()
    if n_workers is not None:
        kwargs = dict(kwargs, n_workers=n_workers)
    cfg = cfg.merged(**kwargs)   # kwargs > config; unknown kwarg raises
    with _lock:
        if _runtime is not None and not _runtime._stopped:
            raise RuntimeError("runtime already started; call runtime_stop() first")
        _runtime = Runtime(
            retry=RetryPolicy(max_retries=cfg.resolved("max_retries"),
                              backoff_seconds=cfg.resolved("retry_backoff_s")),
            speculation=SpeculationConfig(
                enabled=cfg.resolved("speculation"),
                factor=cfg.resolved("speculation_factor")),
            **cfg.runtime_kwargs(),
        )
        return _runtime


def current_runtime() -> Runtime:
    if _runtime is None or _runtime._stopped:
        raise RuntimeError("runtime not started; call runtime_start() first")
    return _runtime


def runtime_stats() -> dict:
    """Live statistics of the running runtime: task counters, wallclock/
    utilization, the memory ledger, and the data-plane split —
    ``scheduler_relay_bytes`` (bytes that crossed the scheduler's own
    link) vs ``p2p_bytes`` (bytes moved directly between node agents,
    attributed per source node under ``data_plane.p2p_by_source``;
    DESIGN.md §15)."""
    return current_runtime().stats()


def runtime_stop(wait: bool = True) -> dict:
    """Drain and shut down (``compss_stop``); returns run statistics."""
    global _runtime
    with _lock:
        rt = _runtime
        if rt is None:
            return {}
        rt.stop(wait=wait)
        stats = rt.stats()
        _runtime = None
        return stats


def _release_runtime(rt: Runtime, wait: bool = True) -> None:
    """``Runtime.__exit__``'s half of ``runtime_stop``: stop ``rt``
    (idempotent — an explicit ``runtime_stop()`` inside the ``with``
    body already did it) and clear the module-level current runtime if
    this instance is still it."""
    global _runtime
    with _lock:
        try:
            rt.stop(wait=wait)
        finally:
            if _runtime is rt:
                _runtime = None


class TaskFunction:
    """A function registered as an RCOMPSs task.  Calling it submits an
    asynchronous task and returns Future(s) instead of running inline."""

    def __init__(self, fn: Callable, *, returns: int = 1, name: Optional[str] = None,
                 max_retries: Optional[int] = None, priority: int = 0,
                 speculatable: bool = True, deadline_s: Optional[float] = None):
        functools.update_wrapper(self, fn)
        self.fn = fn
        self.returns = returns
        self.name = name or fn.__name__
        self.max_retries = max_retries
        self.priority = priority
        self.speculatable = speculatable
        self.deadline_s = deadline_s

    def __call__(self, *args, **kwargs):
        rt = current_runtime()
        return rt.submit(
            self.fn, args, kwargs,
            name=self.name, returns=self.returns, max_retries=self.max_retries,
            priority=self.priority, speculatable=self.speculatable,
            deadline_s=self.deadline_s,
        )

    def map(self, args_list: Iterable[tuple]) -> List[Any]:
        """Fan-out: submit one task per positional-args tuple in a single
        batch (see :func:`map_tasks`)."""
        return map_tasks(self, args_list)

    def inline(self, *args, **kwargs):
        """Run synchronously, bypassing the runtime (debugging aid)."""
        return self.fn(*args, **kwargs)


def task(fn: Optional[Callable] = None, *, returns: int = 1, name: Optional[str] = None,
         max_retries: Optional[int] = None, priority: int = 0,
         speculatable: bool = True, deadline_s: Optional[float] = None) -> Any:
    """Register ``fn`` as a task (paper's ``task()``); decorator or wrapper.

    ``deadline_s`` bounds each attempt's execution time (DESIGN.md §19):
    a body running longer has its worker killed and the attempt fails as
    a retryable :class:`~repro.core.executors.DeadlineExceededError` —
    pair it with ``max_retries`` when overruns are transient.  Defaults
    to the runtime's ``deadline_s`` knob (``RJAX_DEADLINE_S``)."""
    def wrap(f: Callable) -> TaskFunction:
        return TaskFunction(f, returns=returns, name=name, max_retries=max_retries,
                            priority=priority, speculatable=speculatable,
                            deadline_s=deadline_s)
    return wrap(fn) if fn is not None else wrap


def map_tasks(task_fn: Any, args_list: Iterable[tuple]) -> List[Any]:
    """Submit one task per entry of ``args_list`` (each a tuple of
    positional arguments) in a single batched call, amortizing the
    per-task graph/store/in-flight locking over the whole fan-out
    (DESIGN.md §14).  ``task_fn`` may be a :class:`TaskFunction` or a
    plain callable.  Returns the Futures in order — semantically identical
    to ``[task_fn(*a) for a in args_list]``, just cheaper to submit::

        frags = api.map_tasks(fill_t, [(seed + i, n, d) for i in range(k)])
    """
    rt = current_runtime()
    if isinstance(task_fn, TaskFunction):
        return rt.submit_many(
            task_fn.fn, [tuple(a) for a in args_list],
            name=task_fn.name, returns=task_fn.returns,
            max_retries=task_fn.max_retries, priority=task_fn.priority,
            speculatable=task_fn.speculatable, deadline_s=task_fn.deadline_s,
        )
    return rt.submit_many(task_fn, [tuple(a) for a in args_list])


def barrier(timeout: Optional[float] = None) -> None:
    """Wait for all submitted tasks (``compss_barrier``)."""
    current_runtime().barrier(timeout=timeout)


def wait_on(obj: Any, timeout: Optional[float] = None) -> Any:
    """Synchronize on Future(s) (``compss_wait_on``)."""
    return current_runtime().wait_on(obj, timeout=timeout)


# -- paper-spelled aliases ----------------------------------------------------
compss_start = runtime_start
compss_stop = runtime_stop
compss_barrier = barrier
compss_wait_on = wait_on

# -- collectives (DESIGN.md §16) ----------------------------------------------
# imported last: collectives resolves this module lazily at call time
from .collectives import broadcast, shuffle, tree_reduce  # noqa: E402,F401
