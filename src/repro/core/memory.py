"""Memory governance for the object planes (DESIGN.md §13).

The paper's weak-scaling results hold only while every node's working set
fits in RAM; COMPSs itself bounds that with per-node memory accounting.
This module supplies the shared machinery that turns each of our object
planes — the scheduler-side :class:`~repro.core.futures.ObjectStore`, the
process backend's :class:`~repro.core.executors.SegmentPlane`, and the
cluster agent's node-local plane — into a *bounded* cache:

* :class:`MemoryBudget` — byte accounting for one address-space domain
  with high/low watermarks (evict when ``used`` crosses the high mark,
  stop once back under the low mark) plus the spill/fault ledger.
* :class:`LRULedger` — recency order over keyed entries, with pin counts
  so in-flight data can never be evicted under a running task.
* :class:`MemoryGovernor` — budget + LRU + a plane-supplied spill
  callback.  ``admit`` charges a new entry and evicts cold ones past the
  watermark; the plane decides what "spill" means (write an mmap-codec
  file, drop a shared-memory segment whose authoritative copy lives
  elsewhere, ...).
* :class:`SpilledValue` — the on-disk form: an mmap-codec file plus
  enough metadata to fault the array back as a zero-copy ``np.memmap``
  view (the RMVL deserialize-side property, §3.3.3).

The budget knob is ``RJAX_MEMORY_BUDGET`` (e.g. ``256M``, ``2G``); unset
or ``0`` means unbounded — the pre-governance behaviour.  Faulted-back
views are read-only (file-backed); tasks that want to mutate inputs must
go through INOUT parameters, same as under the process backend.

Locking contract: every plane already serializes access with its own
lock; the governor is reentrant (``RLock``) and is only ever entered
*from* its owning plane, so the lock order is always plane → governor
and cross-component deadlock is impossible by construction.
"""
from __future__ import annotations

import os
import tempfile
import threading
from collections import OrderedDict
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from .serialization import MmapCodec, _unlink_quiet

Key = Tuple[int, int]

ENV_BUDGET = "RJAX_MEMORY_BUDGET"

# arrays below this size are not worth a spill file (the file-system
# metadata would cost more than the bytes saved)
SPILL_MIN_BYTES = int(os.environ.get("RJAX_SPILL_MIN_BYTES", 4096))

_UNITS = {
    "": 1, "b": 1,
    "k": 1 << 10, "kb": 1 << 10,
    "m": 1 << 20, "mb": 1 << 20,
    "g": 1 << 30, "gb": 1 << 30,
    "t": 1 << 40, "tb": 1 << 40,
}


def parse_bytes(value) -> Optional[int]:
    """``"256M"`` / ``"1.5g"`` / ``1048576`` → bytes; ``None``/``0``/empty
    → ``None`` (unbounded)."""
    if value is None:
        return None
    if isinstance(value, (int, float)):
        n = int(value)
        if n < 0:
            raise ValueError(f"negative memory budget: {value!r}")
        return n or None
    s = str(value).strip().lower().replace("_", "")
    if not s:
        return None
    i = len(s)
    while i > 0 and s[i - 1].isalpha():
        i -= 1
    num, unit = s[:i], s[i:]
    if unit not in _UNITS or not num:
        raise ValueError(f"cannot parse memory budget {value!r}")
    try:
        n = int(float(num) * _UNITS[unit])
    except ValueError as err:
        raise ValueError(f"cannot parse memory budget {value!r}") from err
    if n < 0:
        raise ValueError(f"negative memory budget: {value!r}")
    return n or None


def budget_from_env(explicit=None) -> Optional[int]:
    """Resolve the effective budget: an explicit value wins, otherwise
    ``RJAX_MEMORY_BUDGET``, otherwise unbounded."""
    if explicit is not None:
        return parse_bytes(explicit)
    return parse_bytes(os.environ.get(ENV_BUDGET))


class MemoryBudget:
    """Byte accounting for one address-space domain.

    ``used`` tracks resident governed bytes; crossing ``high_frac ×
    capacity`` triggers eviction down to ``low_frac × capacity`` (the
    classic two-watermark scheme, so one hot entry doesn't cause an
    evict-readmit storm at the boundary).  Spill/fault counters live here
    so every plane reports the same ledger shape.
    """

    def __init__(self, capacity, high_frac: float = 0.9, low_frac: float = 0.7):
        self.capacity = parse_bytes(capacity)
        if not 0.0 < low_frac <= high_frac <= 1.0:
            raise ValueError(
                f"watermarks must satisfy 0 < low <= high <= 1, "
                f"got low={low_frac} high={high_frac}")
        self.high_frac = high_frac
        self.low_frac = low_frac
        self._lock = threading.Lock()
        self.used = 0
        self.peak_used = 0   # high-water mark (peer fetches land here too)
        self.spills = 0
        self.faults = 0
        self.spill_bytes = 0
        self.fault_bytes = 0

    @property
    def bounded(self) -> bool:
        return self.capacity is not None

    @property
    def high_bytes(self) -> Optional[int]:
        return None if self.capacity is None else int(self.capacity * self.high_frac)

    @property
    def low_bytes(self) -> Optional[int]:
        return None if self.capacity is None else int(self.capacity * self.low_frac)

    def charge(self, nbytes: int) -> None:
        with self._lock:
            self.used += int(nbytes)
            if self.used > self.peak_used:
                self.peak_used = self.used

    def discharge(self, nbytes: int) -> None:
        with self._lock:
            self.used = max(0, self.used - int(nbytes))

    def over_high(self) -> bool:
        return self.capacity is not None and self.used > self.high_bytes

    def release_target(self) -> int:
        """Bytes to free to get back under the low watermark."""
        if self.capacity is None:
            return 0
        return max(0, self.used - self.low_bytes)

    def note_spill(self, nbytes: int) -> None:
        with self._lock:
            self.spills += 1
            self.spill_bytes += int(nbytes)

    def note_fault(self, nbytes: int) -> None:
        with self._lock:
            self.faults += 1
            self.fault_bytes += int(nbytes)

    def stats(self) -> dict:
        with self._lock:
            return {
                "budget_bytes": self.capacity,
                "bytes_used": self.used,
                "peak_bytes": self.peak_used,
                "spills": self.spills,
                "faults": self.faults,
                "spill_bytes": self.spill_bytes,
                "fault_bytes": self.fault_bytes,
            }


class LRULedger:
    """Recency order over keyed entries, with pin counts.

    A pinned key is never offered as an eviction victim; pins are
    counted (the same key can be pinned by several in-flight tasks) and
    work even for keys not yet admitted, closing the race between a
    dispatcher deciding to ship a datum and the plane admitting it.
    """

    def __init__(self):
        self._entries: "OrderedDict[Key, int]" = OrderedDict()
        self._pins: Dict[Key, int] = {}

    def add(self, key: Key, nbytes: int) -> None:
        self._entries[key] = int(nbytes)
        self._entries.move_to_end(key)

    def touch(self, key: Key) -> None:
        if key in self._entries:
            self._entries.move_to_end(key)

    def discard(self, key: Key) -> int:
        return self._entries.pop(key, 0)

    def pin(self, key: Key) -> None:
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key: Key) -> None:
        n = self._pins.get(key, 0) - 1
        if n <= 0:
            self._pins.pop(key, None)
        else:
            self._pins[key] = n

    def pinned(self, key: Key) -> bool:
        return key in self._pins

    def victims(self, want_bytes: int, exclude: Iterable[Key] = ()) -> List[Tuple[Key, int]]:
        """Coldest-first candidates summing to at least ``want_bytes``,
        skipping pinned and excluded keys."""
        excluded = set(exclude)
        out: List[Tuple[Key, int]] = []
        total = 0
        for key, nbytes in self._entries.items():
            if total >= want_bytes:
                break
            if key in excluded or key in self._pins:
                continue
            out.append((key, nbytes))
            total += nbytes
        return out

    def __contains__(self, key: Key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)


class MemoryGovernor:
    """Budget + LRU + spill driver for one object plane.

    ``spill(key) -> bytes_freed`` is supplied by the plane; returning 0
    means "cannot spill this entry right now" and the governor moves on
    (the budget is a *soft* bound: progress always beats the watermark).
    Reentrant: planes call it while holding their own lock, and the spill
    callback may re-enter plane methods.
    """

    def __init__(self, budget: MemoryBudget, spill: Callable[[Key], int],
                 name: str = "plane"):
        self.budget = budget
        self.name = name
        self._spill = spill
        self._lock = threading.RLock()
        self._ledger = LRULedger()

    # -- residency -----------------------------------------------------------
    def admit(self, key: Key, nbytes: int) -> None:
        """Record ``key`` as resident and enforce the watermark.  The key
        being admitted is never its own victim."""
        with self._lock:
            if key in self._ledger:
                self._ledger.touch(key)
                return
            self._ledger.add(key, nbytes)
            self.budget.charge(nbytes)
            self._enforce(exclude=(key,))

    def touch(self, key: Key) -> None:
        with self._lock:
            self._ledger.touch(key)

    def release(self, key: Key) -> None:
        """The plane dropped ``key`` itself (GC, explicit evict)."""
        with self._lock:
            freed = self._ledger.discard(key)
            if freed:
                self.budget.discharge(freed)

    def fault(self, key: Key, nbytes: int) -> None:
        """A spilled entry was read back.  Faulted views are file-backed
        (``np.memmap``), so they are *not* re-charged against the budget —
        the kernel can drop their pages under pressure."""
        self.budget.note_fault(nbytes)

    # -- pinning -------------------------------------------------------------
    def pin_many(self, keys: Iterable[Key]) -> None:
        with self._lock:
            for k in keys:
                self._ledger.pin(k)

    def unpin_many(self, keys: Iterable[Key]) -> None:
        with self._lock:
            for k in keys:
                self._ledger.unpin(k)

    def reclaim(self) -> None:
        """Re-run watermark enforcement outside an admit.  Needed by deep
        dispatch pipelines (DESIGN.md §14): a working set admitted while
        every entry was pinned by in-flight tasks sails past the high
        watermark untouched, so completions re-enforce after unpinning."""
        with self._lock:
            self._enforce()

    # -- enforcement ---------------------------------------------------------
    def _enforce(self, exclude: Iterable[Key] = ()) -> None:
        if not self.budget.over_high():
            return
        target = self.budget.release_target()
        tried: set = set(exclude)
        while target > 0:
            victims = self._ledger.victims(target, exclude=tried)
            if not victims:
                return  # everything cold is pinned/unspillable: soft bound
            progress = False
            for key, nbytes in victims:
                tried.add(key)
                freed = self._spill(key)
                if freed > 0:
                    self._ledger.discard(key)
                    self.budget.discharge(freed)
                    self.budget.note_spill(freed)
                    progress = True
            if not progress:
                return
            target = self.budget.release_target()

    def stats(self) -> dict:
        with self._lock:
            s = self.budget.stats()
            s["governed_entries"] = len(self._ledger)
            return s


class SpilledValue:
    """An array that was spilled to an mmap-codec file.

    ``load()`` faults it back as a zero-copy read-only ``np.memmap`` view
    *owning* the file (unlinked when the view is collected), so a reader
    holding the view stays valid even after the plane later evicts the
    entry entirely.  ``dispose()`` is for entries dropped while still on
    disk."""

    __slots__ = ("path", "nbytes")

    def __init__(self, path: str, nbytes: int):
        self.path = path
        self.nbytes = int(nbytes)

    def load(self) -> np.ndarray:
        return MmapCodec().de_from_file(self.path, owned=True)

    def dispose(self) -> None:
        _unlink_quiet(self.path)

    def __repr__(self) -> str:
        return f"<SpilledValue {self.nbytes}B at {self.path}>"


def spillable(value, min_bytes: Optional[int] = None) -> bool:
    """Only raw-codec-eligible ndarrays are governed: they round-trip
    through the mmap codec losslessly and zero-copy.  Memmaps are already
    file-backed (spilling them would copy disk to disk)."""
    if not isinstance(value, np.ndarray) or isinstance(value, np.memmap):
        return False
    floor = SPILL_MIN_BYTES if min_bytes is None else min_bytes
    if value.nbytes < floor or value.dtype.hasobject:
        return False
    from .serialization import _pack_header
    try:
        _pack_header(np.asarray(value))
        return True
    except TypeError:
        return False


def spill_to_file(value: np.ndarray, prefix: str = "rjax_spill_",
                  dir: Optional[str] = None) -> SpilledValue:
    """Write ``value`` to a fresh mmap-codec temp file and return its
    :class:`SpilledValue` handle."""
    fd, path = tempfile.mkstemp(prefix=prefix, suffix=".rjx", dir=dir)
    os.close(fd)
    try:
        MmapCodec().ser_to_file(value, path)
    except BaseException:
        _unlink_quiet(path)
        raise
    return SpilledValue(path, value.nbytes)
