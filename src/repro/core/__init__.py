"""repro.core — the paper's primary contribution, reproduced in Python/JAX.

A COMPSs-style dynamic task-based runtime: sequential user code, automatic
dependency detection, asynchronous scheduling over persistent executors,
pluggable serialization, fault tolerance, tracing, and a calibrated
discrete-event simulator for scaling studies.
"""
from .api import (  # noqa: F401
    barrier,
    compss_barrier,
    compss_start,
    compss_stop,
    compss_wait_on,
    current_runtime,
    runtime_start,
    runtime_stop,
    task,
    wait_on,
)
from .dag import TaskGraph, TaskNode, TaskState  # noqa: F401
from .fault import PoisonedInputError, RetryPolicy, SpeculationConfig  # noqa: F401
from .futures import Future, ObjectStore, TaskFailedError  # noqa: F401
from .runtime import Runtime  # noqa: F401
from .simulator import CostModel, MachineModel, SimResult, SimTask, replay_graph, simulate  # noqa: F401
from .tracing import TraceEvent, Tracer  # noqa: F401
