"""Extrae/Paraver-style execution tracing (paper §3.3.4, Fig. 10).

The tracer records one event per task attempt (worker, node, task name,
start/end) plus runtime lifecycle events.  From a trace we derive the
quantities the paper reads off Paraver timelines: per-worker utilization,
parallel efficiency, serialization share, and an ASCII Gantt rendering for
quick terminal inspection.  A minimal ``.prv``-like export keeps the format
familiar to Paraver users.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, asdict, field
from typing import Dict, List, Optional


@dataclass
class TraceEvent:
    kind: str            # "task" | "serialize" | "transfer" | "runtime"
    name: str
    worker: int
    node: int
    t0: float
    t1: float
    task_id: int = -1
    meta: dict = field(default_factory=dict)

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


class Tracer:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._events: List[TraceEvent] = []
        self._lock = threading.Lock()
        self.t_start = time.perf_counter()
        self.t_stop: Optional[float] = None

    def record(self, ev: TraceEvent) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._events.append(ev)

    def stop(self) -> None:
        self.t_stop = time.perf_counter()

    def events(self, kind: Optional[str] = None) -> List[TraceEvent]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs

    # ------------------------------------------------------------- analysis
    def wallclock(self) -> float:
        end = self.t_stop if self.t_stop is not None else time.perf_counter()
        return end - self.t_start

    def busy_per_worker(self) -> Dict[int, float]:
        busy: Dict[int, float] = {}
        for e in self.events("task"):
            busy[e.worker] = busy.get(e.worker, 0.0) + e.dt
        return busy

    def utilization(self, n_workers: int) -> float:
        wall = self.wallclock()
        if wall <= 0 or n_workers <= 0:
            return 0.0
        return sum(self.busy_per_worker().values()) / (wall * n_workers)

    def serialization_share(self) -> float:
        task_t = sum(e.dt for e in self.events("task"))
        ser_t = sum(e.dt for e in self.events("serialize"))
        total = task_t + ser_t
        return ser_t / total if total > 0 else 0.0

    def task_duration_stats(self) -> Dict[str, dict]:
        per: Dict[str, List[float]] = {}
        for e in self.events("task"):
            per.setdefault(e.name, []).append(e.dt)
        out = {}
        for name, ds in per.items():
            ds.sort()
            out[name] = {
                "count": len(ds),
                "total": sum(ds),
                "mean": sum(ds) / len(ds),
                "p50": ds[len(ds) // 2],
                "max": ds[-1],
            }
        return out

    # -------------------------------------------------------------- exports
    def to_json(self) -> str:
        return json.dumps([asdict(e) for e in self.events()], indent=1)

    def to_prv(self) -> str:
        """Tiny Paraver-like export: header + one state record per task."""
        evs = self.events("task")
        dur_us = int(self.wallclock() * 1e6)
        workers = sorted({e.worker for e in evs}) or [0]
        lines = [f"#Paraver (rjax):{dur_us}_us:1(1):{len(workers)}"]
        for e in evs:
            t0 = int((e.t0 - self.t_start) * 1e6)
            t1 = int((e.t1 - self.t_start) * 1e6)
            # state record: 1:cpu:appl:task:thread:begin:end:state
            lines.append(f"1:{e.worker + 1}:1:1:1:{t0}:{t1}:{e.name}")
        return "\n".join(lines)

    def ascii_gantt(self, width: int = 100) -> str:
        """Terminal Gantt chart — one row per worker (paper Fig. 10 analogue)."""
        evs = self.events("task")
        if not evs:
            return "(empty trace)"
        t0 = min(e.t0 for e in evs)
        t1 = max(e.t1 for e in evs)
        span = max(t1 - t0, 1e-9)
        rows: Dict[int, List[str]] = {}
        names = sorted({e.name for e in evs})
        glyph = {n: chr(ord("A") + (i % 26)) for i, n in enumerate(names)}
        for e in evs:
            row = rows.setdefault(e.worker, [" "] * width)
            a = int((e.t0 - t0) / span * (width - 1))
            b = max(a + 1, int((e.t1 - t0) / span * (width - 1)) + 1)
            for i in range(a, min(b, width)):
                row[i] = glyph[e.name]
        legend = "  ".join(f"{g}={n}" for n, g in glyph.items())
        out = [f"trace span: {span*1e3:.2f} ms   [{legend}]"]
        for w in sorted(rows):
            out.append(f"w{w:03d} |{''.join(rows[w])}|")
        return "\n".join(out)
