"""Extrae/Paraver-style execution tracing (paper §3.3.4, Fig. 10).

The tracer records one event per task attempt (worker, node, task name,
start/end) plus runtime lifecycle events.  From a trace we derive the
quantities the paper reads off Paraver timelines: per-worker utilization,
parallel efficiency, serialization share, and an ASCII Gantt rendering for
quick terminal inspection.  Two file exports: a minimal ``.prv``-like
format familiar to Paraver users, and the Chrome trace-event JSON
(``to_chrome_trace``) that opens directly in Perfetto / ``about:tracing``.

:class:`TaskStream` is the live-telemetry counterpart (DESIGN.md §17): a
*bounded* ring of task-lifecycle events (submit → dispatch → done/fail)
that the dashboard polls incrementally by sequence number, while the
tracer above keeps the unbounded post-mortem record.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from dataclasses import dataclass, asdict, field
from typing import Dict, List, Optional


@dataclass
class TraceEvent:
    kind: str            # "task" | "serialize" | "transfer" | "runtime"
    name: str
    worker: int
    node: int
    t0: float
    t1: float
    task_id: int = -1
    meta: dict = field(default_factory=dict)

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


class Tracer:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._events: List[TraceEvent] = []
        self._lock = threading.Lock()
        self.t_start = time.perf_counter()
        self.t_stop: Optional[float] = None

    def record(self, ev: TraceEvent) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._events.append(ev)

    def stop(self) -> None:
        self.t_stop = time.perf_counter()

    def events(self, kind: Optional[str] = None) -> List[TraceEvent]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs

    # ------------------------------------------------------------- analysis
    def wallclock(self) -> float:
        end = self.t_stop if self.t_stop is not None else time.perf_counter()
        return end - self.t_start

    def busy_per_worker(self) -> Dict[int, float]:
        busy: Dict[int, float] = {}
        for e in self.events("task"):
            busy[e.worker] = busy.get(e.worker, 0.0) + e.dt
        return busy

    def utilization(self, n_workers: int) -> float:
        wall = self.wallclock()
        if wall <= 0 or n_workers <= 0:
            return 0.0
        return sum(self.busy_per_worker().values()) / (wall * n_workers)

    def serialization_share(self) -> float:
        task_t = sum(e.dt for e in self.events("task"))
        ser_t = sum(e.dt for e in self.events("serialize"))
        total = task_t + ser_t
        return ser_t / total if total > 0 else 0.0

    def task_duration_stats(self) -> Dict[str, dict]:
        per: Dict[str, List[float]] = {}
        for e in self.events("task"):
            per.setdefault(e.name, []).append(e.dt)
        out = {}
        for name, ds in per.items():
            ds.sort()
            out[name] = {
                "count": len(ds),
                "total": sum(ds),
                "mean": sum(ds) / len(ds),
                "p50": ds[len(ds) // 2],
                "max": ds[-1],
            }
        return out

    # -------------------------------------------------------------- exports
    def to_json(self) -> str:
        return json.dumps([asdict(e) for e in self.events()], indent=1)

    def to_prv(self) -> str:
        """Tiny Paraver-like export: header + one state record per task.

        Events are clamped and ordered defensively: completion threads
        record concurrently, so events may arrive out of submission order
        and a no-op task can carry ``t1 == t0`` (or, on clock hiccups,
        ``t1 < t0``) — Paraver expects ordered records with non-negative
        spans."""
        evs = sorted(self.events("task"), key=lambda e: (e.t0, e.t1))
        dur_us = max(0, int(self.wallclock() * 1e6))
        workers = sorted({e.worker for e in evs}) or [0]
        lines = [f"#Paraver (rjax):{dur_us}_us:1(1):{len(workers)}"]
        for e in evs:
            t0 = max(0, int((e.t0 - self.t_start) * 1e6))
            t1 = max(t0, int((e.t1 - self.t_start) * 1e6))
            # state record: 1:cpu:appl:task:thread:begin:end:state
            lines.append(f"1:{e.worker + 1}:1:1:1:{t0}:{t1}:{e.name}")
        return "\n".join(lines)

    def to_chrome_trace(self) -> str:
        """Chrome trace-event JSON (the ``traceEvents`` format Perfetto
        and ``about:tracing`` open directly): one complete ("X") event
        per recorded trace event, ``pid`` = locality domain / node,
        ``tid`` = worker, timestamps in µs relative to runtime start.
        Metadata records name the node/worker rows."""
        evs = self.events()
        records: List[dict] = []
        for node in sorted({e.node for e in evs}):
            records.append({"name": "process_name", "ph": "M",
                            "pid": int(node), "tid": 0,
                            "args": {"name": f"node {node}"}})
        for node, worker in sorted({(e.node, e.worker) for e in evs}):
            records.append({"name": "thread_name", "ph": "M",
                            "pid": int(node), "tid": int(worker),
                            "args": {"name": f"worker {worker}"}})
        for e in sorted(evs, key=lambda e: (e.t0, e.t1)):
            args = {"task_id": e.task_id}
            for k, v in e.meta.items():
                if isinstance(v, (bool, int, float, str)) or v is None:
                    args[k] = v
            records.append({
                "name": e.name, "cat": e.kind, "ph": "X",
                "ts": round(max(0.0, (e.t0 - self.t_start) * 1e6), 3),
                "dur": round(max(0.0, (e.t1 - e.t0) * 1e6), 3),
                "pid": int(e.node), "tid": int(e.worker),
                "args": args,
            })
        return json.dumps({"traceEvents": records,
                           "displayTimeUnit": "ms"}, indent=1)

    def ascii_gantt(self, width: int = 100) -> str:
        """Terminal Gantt chart — one row per worker (paper Fig. 10 analogue)."""
        evs = self.events("task")
        if not evs:
            return "(empty trace)"
        width = max(2, int(width))
        t0 = min(e.t0 for e in evs)
        t1 = max(max(e.t1, e.t0) for e in evs)
        span = max(t1 - t0, 1e-9)
        rows: Dict[int, List[str]] = {}
        names = sorted({e.name for e in evs})
        glyph = {n: chr(ord("A") + (i % 26)) for i, n in enumerate(names)}
        for e in evs:
            row = rows.setdefault(e.worker, [" "] * width)
            # clamp into [0, width): zero-duration events still paint one
            # cell, events with a skewed/negative span never index out
            a = min(width - 1, max(0, int((e.t0 - t0) / span * (width - 1))))
            b = min(width, max(a + 1, int((e.t1 - t0) / span * (width - 1)) + 1))
            for i in range(a, b):
                row[i] = glyph[e.name]
        legend = "  ".join(f"{g}={n}" for n, g in glyph.items())
        out = [f"trace span: {span*1e3:.2f} ms   [{legend}]"]
        for w in sorted(rows):
            out.append(f"w{w:03d} |{''.join(rows[w])}|")
        return "\n".join(out)


# ------------------------------------------------------- live task stream
# bounded lifecycle ring (DESIGN.md §17); 0/negative = default
RING_CAPACITY = int(os.environ.get("RJAX_TELEMETRY_RING", "0") or 0) or 4096


class TaskStream:
    """Bounded ring buffer of task-lifecycle events (DESIGN.md §17).

    Each event is a plain dict tagged with a monotonically increasing
    ``seq``; the oldest events are evicted once ``capacity`` is reached
    (``dropped`` counts them), so a long-running service holds a sliding
    window instead of growing without bound.  Consumers (the dashboard's
    ``/api/tasks``) poll incrementally with ``since(last_seen_seq)``.
    Appends run on the dispatch/completion hot paths: one short lock hold
    and a deque append, nothing else."""

    def __init__(self, capacity: Optional[int] = None):
        self.capacity = int(capacity) if capacity else RING_CAPACITY
        self.capacity = max(1, self.capacity)
        self._buf: collections.deque = collections.deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._dropped = 0

    def append(self, kind: str, **fields) -> int:
        with self._lock:
            self._seq += 1
            if len(self._buf) == self.capacity:
                self._dropped += 1
            fields["seq"] = self._seq
            fields["kind"] = kind
            self._buf.append(fields)
            return self._seq

    def extend(self, kind: str, rows) -> None:
        """Batch append (fan-out submission): one lock hold for the lot.
        ``rows`` is an iterable of field dicts."""
        with self._lock:
            for fields in rows:
                self._seq += 1
                if len(self._buf) == self.capacity:
                    self._dropped += 1
                fields["seq"] = self._seq
                fields["kind"] = kind
                self._buf.append(fields)

    def since(self, seq: int = 0, limit: Optional[int] = None) -> List[dict]:
        """Events with ``seq`` strictly greater than the given watermark,
        oldest first (capped at ``limit`` newest when given)."""
        with self._lock:
            evs = [dict(e) for e in self._buf if e["seq"] > seq]
        if limit is not None and len(evs) > limit:
            evs = evs[-int(limit):]
        return evs

    @property
    def last_seq(self) -> int:
        with self._lock:
            return self._seq

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)
