"""Dynamic task dependency graph.

The runtime builds this DAG *online* as the user's sequential program submits
tasks (paper §3.2).  Dependencies are discovered by scanning task arguments
for ``Future`` objects: an argument ``dXvY`` produced by task *T* makes the
new task a child of *T*.  INOUT parameters bump the datum's version, which is
exactly COMPSs' renaming scheme.

Hot-path bookkeeping (DESIGN.md §14): the graph maintains per-state
counters, a running-task index, and a bounded per-name duration history,
so ``Runtime.stats()`` and the speculation monitor are O(1)/O(running)
instead of scanning every node ever submitted.  ``RJAX_GRAPH_RETAIN``
(default 0 = keep everything) bounds how many *terminal* nodes are
retained: long-running services set it so the graph stops growing without
bound (the pruned tail disappears from ``to_dot``/``critical_path``
renderings but not from the cumulative counters).
"""
from __future__ import annotations

import collections
import enum
import itertools
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

# terminal-node retention: 0 = unbounded (retain the full graph, the
# pre-§14 behaviour); N > 0 = keep at most N DONE/FAILED/CANCELLED nodes
GRAPH_RETAIN = int(os.environ.get("RJAX_GRAPH_RETAIN", "0") or 0)
# duration samples kept per task name for speculation's median estimate
_DURATIONS_KEPT = 64


class TaskState(enum.Enum):
    PENDING = "pending"      # submitted, waiting on dependencies
    READY = "ready"          # all deps satisfied, queued for execution
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"        # exhausted retries
    CANCELLED = "cancelled"  # speculative duplicate that lost the race


@dataclass
class TaskNode:
    task_id: int
    name: str
    fn: Callable
    args: tuple
    kwargs: dict
    # dependency bookkeeping
    dep_keys: Set[Tuple[int, int]] = field(default_factory=set)   # (data_id, version) inputs
    parents: Set[int] = field(default_factory=set)
    children: Set[int] = field(default_factory=set)
    unresolved: int = 0
    # outputs
    out_keys: List[Tuple[int, int]] = field(default_factory=list)
    # execution state
    state: TaskState = TaskState.PENDING
    attempts: int = 0
    max_retries: int = 0
    worker: Optional[int] = None
    node: Optional[int] = None  # which (virtual) node executed it
    submit_t: float = 0.0
    start_t: float = 0.0
    end_t: float = 0.0
    error: Optional[BaseException] = None
    # scheduling metadata
    priority: int = 0
    nbytes_in: int = 0
    speculatable: bool = True
    speculative_of: Optional[int] = None  # set on speculative duplicates
    # fault tolerance (DESIGN.md §19): body wall-time bound; an attempt
    # running longer is killed agent-side and fails retryable
    deadline_s: Optional[float] = None

    @property
    def duration(self) -> float:
        return max(0.0, self.end_t - self.start_t)


_TERMINAL = (TaskState.DONE, TaskState.FAILED, TaskState.CANCELLED)


class TaskGraph:
    """Thread-safe DAG with in-degree tracking.

    ``add_task`` wires parent/child edges from the dependency keys; when a
    task completes, ``mark_done`` returns the children that just became
    ready.  The graph also retains completed nodes so traces and ``to_dot``
    renderings (paper Figs. 2-5) can be produced after the run — bounded
    by ``RJAX_GRAPH_RETAIN`` when set.
    """

    def __init__(self, retain: int = GRAPH_RETAIN):
        self._lock = threading.Lock()
        self._nodes: Dict[int, TaskNode] = {}
        self._producers: Dict[Tuple[int, int], int] = {}  # data key -> producer task
        self._ids = itertools.count(1)
        self.retain = int(retain)
        # -- incremental bookkeeping (replaces full-graph scans) -------------
        self._counts: Dict[TaskState, int] = {s: 0 for s in TaskState}
        self._running: Set[int] = set()          # RUNNING task ids
        self._terminal: collections.deque = collections.deque()  # completion order
        self._durations: Dict[str, collections.deque] = {}
        # body seconds as measured by the executing worker itself (the
        # cluster agent times around its pool invoke and ships ``dur``
        # in the done reply) — unlike ``_durations`` these carry no
        # dispatch/queue latency, so the replication cost bar (§20)
        # compares producer cost with producer cost
        self._run_s: Dict[str, collections.deque] = {}
        self._submitted = 0      # non-speculative adds (cumulative)
        self._speculative = 0    # speculative adds (cumulative)
        self._retries = 0        # re-executions observed (cumulative)
        self._total_work = 0.0   # sum of DONE durations (cumulative)

    def next_task_id(self) -> int:
        return next(self._ids)

    def next_task_ids(self, n: int) -> List[int]:
        return [next(self._ids) for _ in range(n)]

    # ------------------------------------------------------- state transitions
    def _set_state_locked(self, n: TaskNode, state: TaskState) -> None:
        self._counts[n.state] -= 1
        self._counts[state] += 1
        if n.state == TaskState.RUNNING:
            self._running.discard(n.task_id)
        if state == TaskState.RUNNING:
            self._running.add(n.task_id)
        n.state = state
        if state in _TERMINAL:
            self._terminal.append(n.task_id)
            self._prune_locked()

    def _prune_locked(self) -> None:
        """Drop the oldest terminal nodes past the retention bound.  Nodes
        flagged ``_speculated`` are kept (a late clone may still look its
        primary up); cumulative counters are unaffected."""
        if self.retain <= 0:
            return
        while len(self._terminal) > self.retain:
            tid = self._terminal.popleft()
            n = self._nodes.get(tid)
            if n is None or getattr(n, "_speculated", False):
                continue
            del self._nodes[tid]
            for key in n.out_keys:
                if self._producers.get(key) == tid:
                    del self._producers[key]

    # ------------------------------------------------------------------- adds
    def _add_task_locked(self, node: TaskNode) -> bool:
        """Insert one node; True if immediately ready."""
        unresolved = 0
        for key in node.dep_keys:
            producer = self._producers.get(key)
            if producer is not None:
                p = self._nodes.get(producer)
                # FAILED producers already published their error and
                # released children: counting them as unresolved would
                # block this task forever — let it run and fail fast on
                # the poisoned input instead
                # dedup by producer: a child reading two outputs of the
                # same task gets released once, so it must only count
                # one unresolved edge
                if p is not None and p.state not in (TaskState.DONE,
                                                     TaskState.FAILED) \
                        and producer not in node.parents:
                    node.parents.add(producer)
                    p.children.add(node.task_id)
                    unresolved += 1
        node.unresolved = unresolved
        node.submit_t = time.perf_counter()
        for key in node.out_keys:
            self._producers[key] = node.task_id
        self._nodes[node.task_id] = node
        if node.speculative_of is None:
            self._submitted += 1
        else:
            self._speculative += 1
        if unresolved == 0:
            node.state = TaskState.READY
            self._counts[TaskState.READY] += 1
            return True
        self._counts[TaskState.PENDING] += 1
        return False

    def add_task(self, node: TaskNode) -> List[int]:
        """Insert ``node``; returns [node.task_id] if immediately ready."""
        with self._lock:
            return [node.task_id] if self._add_task_locked(node) else []

    def add_tasks(self, nodes: Sequence[TaskNode]) -> List[int]:
        """Batch insert under ONE lock acquisition (fan-out submission);
        returns the ids of all immediately-ready nodes in order."""
        ready: List[int] = []
        with self._lock:
            for node in nodes:
                if self._add_task_locked(node):
                    ready.append(node.task_id)
        return ready

    def claim_running(self, task_id: int, worker: int,
                      node_id: int) -> Optional[TaskNode]:
        """READY→RUNNING transition returning the node — one lock pass for
        the dispatch hot path (None = lost a cancellation race, or the
        node went terminal and was pruned while its id sat in the queue)."""
        with self._lock:
            n = self._nodes.get(task_id)
            if n is None or n.state not in (TaskState.READY,):
                return None
            self._set_state_locked(n, TaskState.RUNNING)
            n.worker = worker
            n.node = node_id
            n.start_t = time.perf_counter()
            n.attempts += 1
            if n.attempts > 1:
                self._retries += 1
            return n

    def _release_children_locked(self, n: TaskNode) -> List[int]:
        newly_ready: List[int] = []
        for cid in n.children:
            c = self._nodes.get(cid)
            # only PENDING children hold unresolved edges; a resurrected
            # producer (lineage re-execution, DESIGN.md §15) completes a
            # second time with its children long released — decrementing
            # them again would corrupt the in-degree bookkeeping
            if c is None or c.state != TaskState.PENDING:
                continue
            c.unresolved -= 1
            if c.unresolved == 0:
                self._counts[TaskState.PENDING] -= 1
                self._counts[TaskState.READY] += 1
                c.state = TaskState.READY
                newly_ready.append(cid)
        return newly_ready

    def mark_done(self, task_id: int) -> List[int]:
        """Mark complete; return newly-ready children ids."""
        with self._lock:
            n = self._nodes[task_id]
            n.end_t = time.perf_counter()
            self._total_work += n.duration
            if n.speculative_of is None:
                ds = self._durations.get(n.name)
                if ds is None:
                    ds = self._durations[n.name] = collections.deque(
                        maxlen=_DURATIONS_KEPT)
                ds.append(n.duration)
            ready = self._release_children_locked(n)
            self._set_state_locked(n, TaskState.DONE)
            return ready

    def mark_failed(self, task_id: int, err: BaseException) -> List[int]:
        """Permanent failure: record error and release children (they will
        observe the stored error on their inputs and fail fast — COMPSs'
        exception propagation)."""
        with self._lock:
            n = self._nodes[task_id]
            n.end_t = time.perf_counter()
            n.error = err
            ready = self._release_children_locked(n)
            self._set_state_locked(n, TaskState.FAILED)
            return ready

    def requeue_for_retry(self, task_id: int) -> None:
        with self._lock:
            n = self._nodes[task_id]
            self._set_state_locked(n, TaskState.READY)

    def producer_of(self, key: Tuple[int, int]) -> Optional[int]:
        """The task id that produces datum ``key`` (None once pruned)."""
        with self._lock:
            return self._producers.get(key)

    def resurrect(self, task_id: int) -> bool:
        """Lineage re-execution (DESIGN.md §15): a DONE task whose
        node-resident output was lost with its node goes back to READY so
        it can run again from its recorded inputs.  Returns False when
        the node is unknown, pruned, or not DONE (already resurrected /
        failed — nothing to do)."""
        with self._lock:
            n = self._nodes.get(task_id)
            if n is None or n.state != TaskState.DONE:
                return False
            try:
                self._terminal.remove(task_id)
            except ValueError:
                pass
            self._counts[TaskState.DONE] -= 1
            self._counts[TaskState.READY] += 1
            n.state = TaskState.READY
            n.error = None
            # re-arm edges to children still PENDING: their edge to this
            # task was released by the first completion, so without the
            # +1 the SECOND completion would double-decrement and release
            # them while other parents are still running
            for cid in n.children:
                c = self._nodes.get(cid)
                if c is not None and c.state == TaskState.PENDING:
                    c.unresolved += 1
            return True

    def mark_cancelled(self, task_id: int) -> None:
        with self._lock:
            n = self._nodes.get(task_id)
            if n is None:   # already pruned (long-gone logical task)
                return
            if n.state not in (TaskState.DONE, TaskState.FAILED):
                n.end_t = time.perf_counter()
                self._set_state_locked(n, TaskState.CANCELLED)

    def get(self, task_id: int) -> TaskNode:
        with self._lock:
            return self._nodes[task_id]

    def nodes(self) -> List[TaskNode]:
        with self._lock:
            return list(self._nodes.values())

    def running_nodes(self) -> List[TaskNode]:
        """The RUNNING nodes, from the index — O(running), not O(all)."""
        with self._lock:
            return [self._nodes[tid] for tid in self._running
                    if tid in self._nodes]

    def done_durations(self, name: str) -> List[float]:
        """Recent completion durations of non-speculative tasks named
        ``name`` (bounded history; feeds speculation's median)."""
        with self._lock:
            ds = self._durations.get(name)
            return list(ds) if ds else []

    def note_run_s(self, name: str, dur: float) -> None:
        """Record a worker-measured body duration (no queue latency) for
        ``name`` — the cluster backend feeds these from the agent's done
        replies."""
        with self._lock:
            ds = self._run_s.get(name)
            if ds is None:
                ds = self._run_s[name] = collections.deque(
                    maxlen=_DURATIONS_KEPT)
            ds.append(float(dur))

    def duration_threshold(self) -> float:
        """Fleet-wide mean of the recorded task durations — the
        replication cost bar (DESIGN.md §20): a producer at or above the
        mean is worth pushing a replica for, one below it is cheaper to
        re-execute from lineage.  Prefers worker-measured body times
        (``note_run_s``) over scheduler-observed completion latencies;
        0.0 while no history exists, so early results replicate until
        the profile fills in."""
        with self._lock:
            total = 0.0
            n = 0
            for ds in (self._run_s or self._durations).values():
                total += sum(ds)
                n += len(ds)
        return (total / n) if n else 0.0

    def pending_count(self) -> int:
        with self._lock:
            return (self._counts[TaskState.PENDING]
                    + self._counts[TaskState.READY]
                    + self._counts[TaskState.RUNNING])

    def counters(self) -> dict:
        """Cumulative O(1) snapshot (unaffected by terminal pruning)."""
        with self._lock:
            return {
                "submitted": self._submitted,
                "speculative": self._speculative,
                "done": self._counts[TaskState.DONE],
                "failed": self._counts[TaskState.FAILED],
                "cancelled": self._counts[TaskState.CANCELLED],
                "retries": self._retries,
                "total_work_s": self._total_work,
                "retained_nodes": len(self._nodes),
            }

    # ------------------------------------------------------------------ export
    def to_dot(self) -> str:
        """Graphviz rendering in the paper's style (Fig. 2): nodes are task
        ids, edges labelled with the ``dXvY`` datum that carries the
        dependency."""
        lines = ["digraph G {", '  main [shape=box];', '  sync [shape=octagon];']
        with self._lock:
            key_producer = dict(self._producers)
            for n in self._nodes.values():
                lines.append(f'  t{n.task_id} [label="{n.name}\\n#{n.task_id}"];')
                if not n.parents:
                    lines.append(f"  main -> t{n.task_id};")
                if not n.children:
                    lines.append(f"  t{n.task_id} -> sync;")
            for n in self._nodes.values():
                for key in n.dep_keys:
                    p = key_producer.get(key)
                    if p is not None and p in self._nodes and p != n.task_id:
                        lines.append(
                            f'  t{p} -> t{n.task_id} [label="d{key[0]}v{key[1]}"];'
                        )
        lines.append("}")
        return "\n".join(lines)

    # -------------------------------------------------------- analysis helpers
    def critical_path_seconds(self) -> float:
        """Longest chain of measured task durations (T_inf) over the
        *retained* nodes."""
        with self._lock:
            memo: Dict[int, float] = {}
            order = sorted(self._nodes)  # task ids increase topologically
            for tid in order:
                n = self._nodes[tid]
                base = max((memo.get(p, 0.0) for p in n.parents), default=0.0)
                memo[tid] = base + n.duration
            return max(memo.values(), default=0.0)

    def total_work_seconds(self) -> float:
        """Sum of completed task durations (T_1) — cumulative, survives
        terminal pruning."""
        with self._lock:
            return self._total_work
