"""Dynamic task dependency graph.

The runtime builds this DAG *online* as the user's sequential program submits
tasks (paper §3.2).  Dependencies are discovered by scanning task arguments
for ``Future`` objects: an argument ``dXvY`` produced by task *T* makes the
new task a child of *T*.  INOUT parameters bump the datum's version, which is
exactly COMPSs' renaming scheme.
"""
from __future__ import annotations

import enum
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple


class TaskState(enum.Enum):
    PENDING = "pending"      # submitted, waiting on dependencies
    READY = "ready"          # all deps satisfied, queued for execution
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"        # exhausted retries
    CANCELLED = "cancelled"  # speculative duplicate that lost the race


@dataclass
class TaskNode:
    task_id: int
    name: str
    fn: Callable
    args: tuple
    kwargs: dict
    # dependency bookkeeping
    dep_keys: Set[Tuple[int, int]] = field(default_factory=set)   # (data_id, version) inputs
    parents: Set[int] = field(default_factory=set)
    children: Set[int] = field(default_factory=set)
    unresolved: int = 0
    # outputs
    out_keys: List[Tuple[int, int]] = field(default_factory=list)
    # execution state
    state: TaskState = TaskState.PENDING
    attempts: int = 0
    max_retries: int = 0
    worker: Optional[int] = None
    node: Optional[int] = None  # which (virtual) node executed it
    submit_t: float = 0.0
    start_t: float = 0.0
    end_t: float = 0.0
    error: Optional[BaseException] = None
    # scheduling metadata
    priority: int = 0
    nbytes_in: int = 0
    speculatable: bool = True
    speculative_of: Optional[int] = None  # set on speculative duplicates

    @property
    def duration(self) -> float:
        return max(0.0, self.end_t - self.start_t)


class TaskGraph:
    """Thread-safe DAG with in-degree tracking.

    ``add_task`` wires parent/child edges from the dependency keys; when a
    task completes, ``mark_done`` returns the children that just became
    ready.  The graph also retains completed nodes so traces and ``to_dot``
    renderings (paper Figs. 2-5) can be produced after the run.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._nodes: Dict[int, TaskNode] = {}
        self._producers: Dict[Tuple[int, int], int] = {}  # data key -> producer task
        self._ids = itertools.count(1)

    def next_task_id(self) -> int:
        return next(self._ids)

    def add_task(self, node: TaskNode) -> List[int]:
        """Insert ``node``; returns [node.task_id] if immediately ready."""
        with self._lock:
            unresolved = 0
            for key in node.dep_keys:
                producer = self._producers.get(key)
                if producer is not None:
                    p = self._nodes.get(producer)
                    # FAILED producers already published their error and
                    # released children: counting them as unresolved would
                    # block this task forever — let it run and fail fast on
                    # the poisoned input instead
                    # dedup by producer: a child reading two outputs of the
                    # same task gets released once, so it must only count
                    # one unresolved edge
                    if p is not None and p.state not in (TaskState.DONE,
                                                         TaskState.FAILED) \
                            and producer not in node.parents:
                        node.parents.add(producer)
                        p.children.add(node.task_id)
                        unresolved += 1
            node.unresolved = unresolved
            node.submit_t = time.perf_counter()
            for key in node.out_keys:
                self._producers[key] = node.task_id
            self._nodes[node.task_id] = node
            if unresolved == 0:
                node.state = TaskState.READY
                return [node.task_id]
            return []

    def mark_running(self, task_id: int, worker: int, node_id: int) -> bool:
        with self._lock:
            n = self._nodes[task_id]
            if n.state not in (TaskState.READY,):
                return False
            n.state = TaskState.RUNNING
            n.worker = worker
            n.node = node_id
            n.start_t = time.perf_counter()
            n.attempts += 1
            return True

    def _release_children_locked(self, n: TaskNode) -> List[int]:
        newly_ready: List[int] = []
        for cid in n.children:
            c = self._nodes.get(cid)
            if c is None:
                continue
            c.unresolved -= 1
            if c.unresolved == 0 and c.state == TaskState.PENDING:
                c.state = TaskState.READY
                newly_ready.append(cid)
        return newly_ready

    def mark_done(self, task_id: int) -> List[int]:
        """Mark complete; return newly-ready children ids."""
        with self._lock:
            n = self._nodes[task_id]
            n.state = TaskState.DONE
            n.end_t = time.perf_counter()
            return self._release_children_locked(n)

    def mark_failed(self, task_id: int, err: BaseException) -> List[int]:
        """Permanent failure: record error and release children (they will
        observe the stored error on their inputs and fail fast — COMPSs'
        exception propagation)."""
        with self._lock:
            n = self._nodes[task_id]
            n.state = TaskState.FAILED
            n.end_t = time.perf_counter()
            n.error = err
            return self._release_children_locked(n)

    def requeue_for_retry(self, task_id: int) -> None:
        with self._lock:
            n = self._nodes[task_id]
            n.state = TaskState.READY

    def mark_cancelled(self, task_id: int) -> None:
        with self._lock:
            n = self._nodes[task_id]
            if n.state not in (TaskState.DONE, TaskState.FAILED):
                n.state = TaskState.CANCELLED
                n.end_t = time.perf_counter()

    def get(self, task_id: int) -> TaskNode:
        with self._lock:
            return self._nodes[task_id]

    def nodes(self) -> List[TaskNode]:
        with self._lock:
            return list(self._nodes.values())

    def pending_count(self) -> int:
        with self._lock:
            return sum(
                1
                for n in self._nodes.values()
                if n.state in (TaskState.PENDING, TaskState.READY, TaskState.RUNNING)
            )

    # ------------------------------------------------------------------ export
    def to_dot(self) -> str:
        """Graphviz rendering in the paper's style (Fig. 2): nodes are task
        ids, edges labelled with the ``dXvY`` datum that carries the
        dependency."""
        lines = ["digraph G {", '  main [shape=box];', '  sync [shape=octagon];']
        with self._lock:
            key_producer = dict(self._producers)
            for n in self._nodes.values():
                lines.append(f'  t{n.task_id} [label="{n.name}\\n#{n.task_id}"];')
                if not n.parents:
                    lines.append(f"  main -> t{n.task_id};")
                if not n.children:
                    lines.append(f"  t{n.task_id} -> sync;")
            for n in self._nodes.values():
                for key in n.dep_keys:
                    p = key_producer.get(key)
                    if p is not None and p in self._nodes and p != n.task_id:
                        lines.append(
                            f'  t{p} -> t{n.task_id} [label="d{key[0]}v{key[1]}"];'
                        )
        lines.append("}")
        return "\n".join(lines)

    # -------------------------------------------------------- analysis helpers
    def critical_path_seconds(self) -> float:
        """Longest chain of measured task durations (T_inf)."""
        with self._lock:
            memo: Dict[int, float] = {}
            order = sorted(self._nodes)  # task ids increase topologically
            for tid in order:
                n = self._nodes[tid]
                base = max((memo.get(p, 0.0) for p in n.parents), default=0.0)
                memo[tid] = base + n.duration
            return max(memo.values(), default=0.0)

    def total_work_seconds(self) -> float:
        """Sum of task durations (T_1)."""
        with self._lock:
            return sum(n.duration for n in self._nodes.values() if n.state == TaskState.DONE)
