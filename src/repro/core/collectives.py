"""Runtime collectives: tree-reduce, broadcast, shuffle (DESIGN.md §16).

The paper concedes linear regression is its weakest scaler because the
reduction phase is a chain of pairwise merge tasks; the pbdR / R-Elemental
line of work gets its scaling precisely from MPI-style collectives.  This
module provides the same primitives as first-class runtime operations:

``tree_reduce``
    Schedules a balanced k-ary merge tree over Futures.  Each tree node is
    ONE task that folds up to ``arity`` children with a balanced in-task
    binary fold — so a 128-leaf reduction at arity 8 costs 19 dispatches
    over 3 levels instead of 127 dispatches over 7, while performing the
    exact same pairwise merges in the exact same order as the (fixed)
    client-side ``algorithms.common.tree_reduce``: results are bitwise
    identical, not merely numerically close.  Every merge carries a
    placement hint pinning it to the node where its largest child is
    resident, which the locality scheduler blends with the §13
    memory-aware score.

``broadcast``
    Fans a keyed datum out to every cluster agent over the §15 peer data
    plane: ONE copy crosses the scheduler's own link (to a root agent),
    the rest moves agent→agent in a doubling frontier — a binomial tree in
    which every agent that holds the bytes immediately becomes a source
    for one that does not.  On non-cluster backends it degrades to a plain
    keyed store put.

``shuffle``
    All-to-all repartition of a fragment set: each input fragment is split
    into ``n_out`` keyed pieces by a user partition function, and piece
    ``p`` of every fragment is combined into output partition ``p``.

The shape helpers (``reduce_spec`` / ``spec_depth``) are shared with the
DES simulator specs so predicted DAGs stay isomorphic to what the runtime
actually schedules.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from .executors import _dumps_fn, _loads_fn
from .futures import Future

__all__ = [
    "broadcast",
    "reduce_spec",
    "shuffle",
    "spec_depth",
    "tree_reduce",
]


# --------------------------------------------------------------------- shapes
def reduce_spec(n_leaves: int, arity: int = 2) -> List[Tuple[int, Tuple[int, ...]]]:
    """Shape of the collective reduction: merge nodes as
    ``(merge_index, children)`` where each merge folds 2..``arity``
    children and children ``>= n_leaves`` refer to merge node
    ``child - n_leaves``.  Merges appear in dependency order.  For
    ``arity=2`` this is exactly the balanced binary
    ``algorithms.common.tree_reduce_spec`` shape."""
    if arity < 2:
        raise ValueError(f"reduce arity must be >= 2, got {arity}")
    ids = list(range(n_leaves))
    merges: List[Tuple[int, Tuple[int, ...]]] = []
    next_id = n_leaves
    while len(ids) > 1:
        nxt = []
        for i in range(0, len(ids), arity):
            group = ids[i : i + arity]
            if len(group) == 1:
                nxt.append(group[0])
                continue
            merges.append((next_id - n_leaves, tuple(group)))
            nxt.append(next_id)
            next_id += 1
        ids = nxt
    return merges


def spec_depth(merges: Sequence[Tuple[int, Tuple[int, ...]]],
               n_leaves: int) -> int:
    """Critical-path length (in merge nodes) of a reduction spec — works
    on both :func:`reduce_spec` and ``common.tree_reduce_spec`` output."""
    depth: dict = {}
    for mi, children in merges:
        depth[n_leaves + mi] = 1 + max(
            (depth.get(c, 0) for c in children), default=0)
    return max(depth.values(), default=0)


class _Fn:
    """Self-contained callable for shipping as a task *argument*.

    Task functions cross address spaces through the fn registry, which
    cloudpickles ``__main__`` functions and closures by value — but the
    collectives pass the user's merge/partition callable inside the task
    args, which ride plain pickle and would resolve ``__main__`` *by
    reference* in an agent whose ``__main__`` is the agent module.  This
    wrapper pickles as the ``_dumps_fn`` blob (computed once per
    collective) and rehydrates lazily on first call."""

    __slots__ = ("blob", "_fn")

    def __init__(self, fn: Callable):
        self.blob = _dumps_fn(fn)
        self._fn: Optional[Callable] = fn

    def __call__(self, *args, **kwargs):
        fn = self._fn
        if fn is None:
            fn = self._fn = _loads_fn(self.blob)
        return fn(*args, **kwargs)

    def __getstate__(self):
        return self.blob

    def __setstate__(self, blob):
        self.blob = blob
        self._fn = None


# ------------------------------------------------------------------ reduction
def _balanced_fold(fn: Callable, vals: Sequence) -> Any:
    """Pairwise-halving fold — the same merge order ``tree_reduce_spec``
    emits for one arity group, so in-task and cross-task reductions of
    the same leaves produce bitwise-identical results."""
    vals = list(vals)
    while len(vals) > 1:
        paired = [fn(vals[j], vals[j + 1])
                  for j in range(0, len(vals) - 1, 2)]
        if len(vals) % 2:
            paired.append(vals[-1])
        vals = paired
    return vals[0]


def _group_merge(fn: Callable, *vals):
    """Task body for one k-ary tree node: balanced fold of the user's
    binary merge over up to ``arity`` children."""
    return _balanced_fold(fn, vals)


def tree_reduce(items: Sequence, merge, arity: int = 2):
    """Reduce ``items`` through a balanced k-ary tree of merge tasks.

    ``merge`` is the binary merge as an ``api.task``-decorated
    TaskFunction (its plain ``.fn`` runs inside each tree node); a bare
    callable gets a client-side balanced fold with no tasks submitted.
    Returns the Future of the root (or the folded value)."""
    from . import api

    items = list(items)
    if not items:
        raise ValueError("tree_reduce of empty sequence")
    if arity < 2:
        raise ValueError(f"tree_reduce arity must be >= 2, got {arity}")
    if len(items) == 1:
        return items[0]

    if not isinstance(merge, api.TaskFunction):
        # client-side fold, same overall binary shape as the task tree
        vals = list(items)
        for _, children in reduce_spec(len(items), arity):
            vals.append(_balanced_fold(merge, [vals[c] for c in children]))
        return vals[-1]

    if merge.returns != 1:
        raise ValueError("tree_reduce merge task must return exactly 1 value")
    rt = api.current_runtime()
    store = rt.store
    fn = _Fn(merge.fn)

    # per-leaf residency snapshot feeding the placement hints: merges are
    # pinned where their largest child lives (DESIGN.md §16); unknown
    # homes (unfinished leaves, plain values) leave placement to the
    # dynamic locality score
    sizes: List[int] = []
    homes: List[Optional[int]] = []
    for it in items:
        if isinstance(it, Future):
            sizes.append(store.nbytes(it.key))
            locs = store.locations(it.key)
            homes.append(min(locs) if locs else None)
        else:
            try:
                sizes.append(int(getattr(it, "nbytes", 0)))
            except Exception:
                sizes.append(0)
            homes.append(None)

    vals: List[Any] = list(items)
    for _, children in reduce_spec(len(items), arity):
        group = [vals[c] for c in children]
        gsizes = [sizes[c] for c in children]
        big = max(range(len(children)), key=lambda i: gsizes[i])
        hint = homes[children[big]]
        name = merge.name if len(group) == 2 else f"{merge.name}x{len(group)}"
        out = rt.submit(
            _group_merge, (fn, *group), name=name,
            max_retries=merge.max_retries, priority=merge.priority,
            speculatable=merge.speculatable, placement_hint=hint,
        )
        vals.append(out)
        # a merge of same-shaped partials is partial-sized, not sum-sized
        sizes.append(max(gsizes) if gsizes else 0)
        homes.append(hint)
    return vals[-1]


# ------------------------------------------------------------------ broadcast
def broadcast(value: Any) -> Future:
    """Publish ``value`` under a fresh datum key on every node.

    On the cluster backend the bytes cross the scheduler link once (to a
    root agent) and then move agent→agent through the peer data plane in
    a doubling frontier; every agent ends with the key resident, so tasks
    consuming the returned Future never trigger a per-agent Put.  On
    thread/process backends the value is simply stored client-side.
    Accepts a Future (materialized first) or a plain value."""
    from . import api

    rt = api.current_runtime()
    if isinstance(value, Future):
        value = rt.wait_on(value)
    store = rt.store
    key = (store.new_data_id(), 1)
    store.put(key, value)
    fan = getattr(rt.executor, "broadcast", None)
    if fan is not None:
        fan(key, value, store)
    # producer task 0 never exists: graph ids start at 1, and the store
    # already holds the value, so dependents are immediately ready
    return Future(key[0], key[1], 0, store)


# -------------------------------------------------------------------- shuffle
def _split_fragment(partition_fn: Callable, frag, n_out: int):
    parts = list(partition_fn(frag, n_out))
    if len(parts) != n_out:
        raise ValueError(
            f"partition_fn returned {len(parts)} pieces, expected {n_out}")
    return tuple(parts) if n_out > 1 else parts[0]


def _concat_parts(*parts):
    if parts and all(isinstance(p, np.ndarray) for p in parts):
        return np.concatenate(parts)
    out: list = []
    for p in parts:
        out.extend(p)
    return out


def shuffle(fragments: Sequence, partition_fn: Callable, n_out: int,
            combine=None) -> List:
    """All-to-all repartition: split every fragment into ``n_out`` pieces
    with ``partition_fn(frag, n_out)`` and combine piece ``p`` of every
    fragment into output partition ``p``.

    ``combine`` is an optional binary TaskFunction merged via
    :func:`tree_reduce`; by default pieces are concatenated (ndarray
    rows) or flattened into a list.  Returns ``n_out`` Futures."""
    from . import api

    rt = api.current_runtime()
    fragments = list(fragments)
    if not fragments:
        raise ValueError("shuffle of empty fragment set")
    if n_out < 1:
        raise ValueError(f"shuffle n_out must be >= 1, got {n_out}")

    rows = []
    part = _Fn(partition_fn)
    for frag in fragments:
        pieces = rt.submit(_split_fragment, (part, frag, n_out),
                           name="shuffle_split", returns=n_out)
        rows.append(pieces if isinstance(pieces, tuple) else (pieces,))

    outs: List = []
    for p in range(n_out):
        col = [row[p] for row in rows]
        if combine is not None:
            outs.append(tree_reduce(col, combine,
                                    arity=max(2, min(len(col), 4))))
        elif len(col) == 1:
            outs.append(col[0])
        else:
            outs.append(rt.submit(_concat_parts, tuple(col),
                                  name="shuffle_concat"))
    return outs
