"""The RJAX runtime engine — RCOMPSs' COMPSs core, reproduced.

One ``Runtime`` owns: the versioned object store, the dynamic task graph,
a scheduling policy, an *executor backend* holding the pool of persistent
workers (the paper's persistent-executor model: workers live for the whole
application and are reused across tasks, §3.3.2), the tracer, fault
handling, and the optional straggler-speculation monitor.

The executor backend is pluggable (``backend="thread"``, ``"process"`` or
``"cluster"``, see :mod:`repro.core.executors`).  The task lifecycle is
split into three runtime-owned phases so backends can *pipeline* the
middle one (DESIGN.md §14):

* :meth:`begin_task`    — claim the task (mark RUNNING) and resolve its
                          inputs from the store;
* the backend invokes the body — synchronously on the dispatcher thread
  (``thread``), or asynchronously with up to ``pipeline_depth`` task
  descriptors in flight per worker (``process``/``cluster``), completions
  drained by a collector thread / channel reader;
* :meth:`complete_task` / :meth:`fail_task` — publish outputs or apply
  the retry policy, release dependents, trace.

Users normally go through :mod:`repro.core.api` (``task`` / ``barrier`` /
``wait_on``), which mirrors the five-function RCOMPSs API.
"""
from __future__ import annotations

import os
import statistics
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .config import resolve as resolve_knob
from .dag import TaskGraph, TaskNode
from .executors import make_executor
from .fault import PoisonedInputError, RetryPolicy, SpeculationConfig
from .futures import Future, ObjectStore, TaskFailedError
from .memory import budget_from_env
from .scheduler import Scheduler
from .telemetry import TelemetryHub, normalize_executor_stats
from .tracing import TraceEvent, Tracer

# per-worker in-flight task budget for pipelined backends (DESIGN.md §14);
# 1 reproduces the stop-and-wait dispatch of earlier revisions
DEFAULT_PIPELINE_DEPTH = 4

# extra attempts granted when a task fails because its INPUT vanished
# with a dead node (error carries lost_input=True, DESIGN.md §15) — the
# task's own body never misbehaved, so this allowance is independent of
# the user-facing max_retries budget, and bounded so a permanently
# unreachable datum still fails instead of looping
LOST_INPUT_RETRIES = int(os.environ.get("RJAX_LOST_INPUT_RETRIES", 3))

# pacing for lost-input retries (per-attempt backoff slope, capped at 1s):
# a lost input can only be refetched after lineage recovery has respawned
# the dead node and re-executed the producer (~seconds), so an immediate
# requeue hot-spins through the whole retry allowance in milliseconds —
# the async control plane (DESIGN.md §18) re-dispatches fast enough to
# burn 7 attempts before the replacement agent even registers
LOST_INPUT_BACKOFF_S = 0.25


def pipeline_depth_from_env(explicit: Optional[int] = None) -> int:
    if explicit is not None:
        return max(1, int(explicit))
    return max(1, int(os.environ.get("RJAX_PIPELINE_DEPTH",
                                     DEFAULT_PIPELINE_DEPTH)))


def _walk(obj: Any, fn: Callable[[Any], Any]) -> Any:
    """Structure-preserving map over (lists, tuples, dicts); applies ``fn``
    to leaves.  Used both to collect Future deps and to substitute values."""
    if isinstance(obj, Future):
        return fn(obj)
    if isinstance(obj, (list, tuple)):
        mapped = [_walk(o, fn) for o in obj]
        if isinstance(obj, tuple):
            # namedtuples (e.g. optimizer states) take positional fields
            return type(obj)(*mapped) if hasattr(obj, "_fields") else tuple(mapped)
        return mapped
    if isinstance(obj, dict):
        return {k: _walk(v, fn) for k, v in obj.items()}
    return obj


def _nbytes(v: Any) -> int:
    if isinstance(v, np.ndarray):
        return v.nbytes
    if hasattr(v, "nbytes"):
        try:
            return int(v.nbytes)
        except Exception:
            return 0
    return 0


class TaskExecution:
    """One claimed task with resolved inputs — the unit a pipelined
    backend keeps in flight between ``begin_task`` and completion."""

    __slots__ = ("t", "args", "kwargs", "input_keys", "t0", "t_run",
                 "worker", "node_id")

    def __init__(self, t: TaskNode, args: tuple, kwargs: dict,
                 input_keys: Dict[int, Tuple[int, int]], t0: float,
                 worker: int, node_id: int, t_run: Optional[float] = None):
        self.t = t
        self.args = args
        self.kwargs = kwargs
        self.input_keys = input_keys
        self.t0 = t0
        # inputs resolved, body about to run: t_run - t0 is the
        # fetch/stall gap the telemetry plane surfaces (DESIGN.md §17)
        self.t_run = t_run
        self.worker = worker
        self.node_id = node_id


class _InputsNotReady(Exception):
    """Internal: ``_resolve_inputs(block=False)`` found an input that is
    not immediately in the store."""


class InputsPending(Exception):
    """A ``begin_task(..., block_inputs=False)`` claim whose input
    resolution would block (DESIGN.md §18).  Carries everything
    ``Runtime.resume_begin`` needs to finish the claim off the loop."""

    def __init__(self, t, worker: int, node_id: int, t0: float):
        super().__init__(getattr(t, "name", None))
        self.t = t
        self.worker = worker
        self.node_id = node_id
        self.t0 = t0


class Runtime:
    def __init__(
        self,
        n_workers: int = 4,
        workers_per_node: Optional[int] = None,
        policy: str = "fifo",
        tracing: bool = True,
        retry: RetryPolicy = RetryPolicy(),
        speculation: SpeculationConfig = SpeculationConfig(),
        name: str = "rjax",
        backend: str = "thread",
        cluster: Any = None,
        n_agents: Optional[int] = None,
        memory_budget: Any = None,
        spill_dir: Optional[str] = None,
        pipeline_depth: Optional[int] = None,
        telemetry: Optional[bool] = None,
        dashboard_port: Optional[int] = None,
        control_plane: Optional[str] = None,
        inline_max: Optional[int] = None,
        heartbeat_s: Optional[float] = None,
        p2p: Optional[bool] = None,
        liveness: Optional[bool] = None,
        suspicion_s: Optional[float] = None,
        deadline_s: Optional[float] = None,
        resolve_timeout_s: Optional[float] = None,
        reconnect_grace_s: Optional[float] = None,
        replication: Optional[int] = None,
    ):
        # memory governance (DESIGN.md §13): explicit knob beats
        # RJAX_MEMORY_BUDGET; None/0 = unbounded.  The budget applies
        # per address-space domain: the scheduler-side store, each
        # process-backend plane, each cluster node agent.
        self.memory_budget = budget_from_env(memory_budget)
        self.spill_dir = spill_dir
        # dispatch pipelining (DESIGN.md §14): explicit knob beats
        # RJAX_PIPELINE_DEPTH; depth 1 = stop-and-wait
        self.pipeline_depth = pipeline_depth_from_env(pipeline_depth)
        # fault-tolerance knobs (DESIGN.md §19): how long a dispatch may
        # wait for an input datum, and the default per-task deadline
        # (per-call submit(deadline_s=) overrides)
        self.resolve_timeout_s = resolve_knob(
            resolve_timeout_s, "RJAX_RESOLVE_TIMEOUT_S", None, 30.0, float)
        self.default_deadline_s = resolve_knob(
            deadline_s, "RJAX_DEADLINE_S", None, None, float)
        backend_opts = {}
        if backend == "process" and self.memory_budget:
            backend_opts["memory_budget"] = self.memory_budget
        if backend in ("process", "cluster"):
            backend_opts["pipeline_depth"] = self.pipeline_depth
        if backend == "cluster":
            # geometry comes from the cluster harness: n_agents real node
            # agents × workers_per_node worker processes on each
            if cluster is None:
                from repro.cluster import LocalCluster
                cluster = LocalCluster(n_agents=n_agents or 2,
                                       workers_per_node=workers_per_node or 2)
            n_workers = cluster.n_agents * cluster.workers_per_node
            workers_per_node = cluster.workers_per_node
            backend_opts["cluster"] = cluster
            if control_plane is not None:
                backend_opts["control_plane"] = control_plane
            if p2p is not None:
                backend_opts["p2p"] = p2p
            # liveness failure detector (DESIGN.md §19): resolved inside
            # ClusterExecutor (explicit > env > default), like p2p
            if liveness is not None:
                backend_opts["liveness"] = liveness
            if suspicion_s is not None:
                backend_opts["suspicion_s"] = suspicion_s
            # bounded recovery (DESIGN.md §20): session-resumption grace
            # window and async k-way replication, resolved inside
            # ClusterExecutor like the liveness knobs
            if reconnect_grace_s is not None:
                backend_opts["reconnect_grace_s"] = reconnect_grace_s
            if replication is not None:
                backend_opts["replication"] = replication
            # agents learn the budget from the welcome handshake (their
            # own --memory-budget flag wins; see repro.cluster.agent)
            if self.memory_budget and getattr(cluster, "memory_budget", None) is None:
                cluster.memory_budget = self.memory_budget
            # likewise the inline threshold and heartbeat cadence: an
            # explicit runtime_start knob seeds the welcome defaults
            # (each agent's own flag/env still wins locally — one
            # precedence rule, see core/config.py)
            if inline_max is not None and getattr(cluster, "inline_max", None) is None:
                cluster.inline_max = int(inline_max)
            if heartbeat_s is not None and getattr(cluster, "heartbeat_s", None) is None:
                cluster.heartbeat_s = float(heartbeat_s)
        self.n_workers = int(n_workers)
        self.backend = backend
        self.cluster = cluster
        # live telemetry plane (DESIGN.md §17): ring hooks follow the
        # tracing flag unless asked for explicitly; a dashboard implies
        # telemetry.  RJAX_DASHBOARD=<port> enables the dashboard from
        # the environment (0 = ephemeral port).
        if dashboard_port is None:
            env_dash = os.environ.get("RJAX_DASHBOARD", "")
            dashboard_port = int(env_dash) if env_dash != "" else None
        telemetry_on = bool(tracing) if telemetry is None else bool(telemetry)
        if dashboard_port is not None:
            telemetry_on = True
        # sampler threads only when someone is watching (dashboard) or
        # telemetry was requested explicitly — plain traced runs keep
        # their thread count unchanged
        self._want_sampler = bool(telemetry) or dashboard_port is not None
        try:
            self._init_rest(workers_per_node, policy, tracing, retry,
                            speculation, name, backend, backend_opts,
                            telemetry_on, dashboard_port)
        except BaseException:
            # a half-built cluster runtime must not leak agent processes
            # (GC of the listener is not guaranteed, e.g. in a REPL)
            if cluster is not None:
                try:
                    cluster.shutdown()
                except Exception:
                    pass
            raise

    def _init_rest(self, workers_per_node, policy, tracing, retry,
                   speculation, name, backend, backend_opts,
                   telemetry_on: bool = False,
                   dashboard_port: Optional[int] = None) -> None:
        if workers_per_node is None:
            # each worker process is its own address space => its own
            # locality domain; threads all share one
            workers_per_node = 1 if backend == "process" else self.n_workers
        self.workers_per_node = workers_per_node
        self.store = ObjectStore()
        self.store.configure_memory(self.memory_budget, spill_dir=self.spill_dir)
        self.graph = TaskGraph()
        self.scheduler = Scheduler(
            self.graph, self.store, policy=policy,
            workers_per_node=self.workers_per_node,
            node_budget=self.memory_budget,
        )
        self.tracer = Tracer(enabled=tracing)
        # created before the executor starts: cluster agent heartbeats
        # can arrive the moment the channels are installed
        self.telemetry = TelemetryHub(enabled=telemetry_on)
        self.dashboard = None
        self.retry = retry
        self.speculation = speculation
        self.name = name

        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._inflight_cond = threading.Condition(self._inflight_lock)
        self._logical_done: Dict[int, bool] = {}   # speculation once-flags
        self._logical_lock = threading.Lock()
        # datum keys whose producer is being re-executed after node loss
        # (DESIGN.md §15): a consumer's resolve timeout on one of these
        # is an input loss, not the consumer's own fault — it inherits
        # the lost-input retry allowance.  Cleared on (re-)publication
        self._recovering: set = set()
        self._recover_lock = threading.Lock()
        self._idle_workers = self.n_workers
        self._stopped = False

        self.executor = make_executor(backend, self.n_workers, label=name,
                                      **backend_opts)
        self.executor.start(self)

        if dashboard_port is not None:
            from .dashboard import DashboardServer
            self.dashboard = DashboardServer(self, port=dashboard_port)
        if (self.telemetry.enabled and self._want_sampler
                and backend != "cluster"):
            # thread/process backends have no agents to heartbeat: an
            # in-process sampler synthesizes the per-node view instead
            self.telemetry.start_sampler(self)

        self._monitor: Optional[threading.Thread] = None
        if self.speculation.enabled:
            self._monitor = threading.Thread(target=self._speculation_loop, daemon=True,
                                             name=f"{name}-spec")
            self._monitor.start()

    # ----------------------------------------------------------- worker hooks
    def locality_domain(self, worker: int) -> int:
        """The address-space/NUMA domain of ``worker`` for locality scoring."""
        return worker // self.workers_per_node

    def _note_worker_busy(self) -> None:
        with self._inflight_lock:
            self._idle_workers -= 1

    def _note_worker_idle(self) -> None:
        with self._inflight_lock:
            self._idle_workers += 1

    # ------------------------------------------------------------- submission
    def submit(
        self,
        fn: Callable,
        args: tuple = (),
        kwargs: Optional[dict] = None,
        *,
        name: Optional[str] = None,
        returns: int = 1,
        max_retries: Optional[int] = None,
        priority: int = 0,
        speculatable: bool = True,
        inout: Sequence[Future] = (),
        placement_hint: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ):
        """Submit one asynchronous task; returns ``returns`` Future(s).

        ``deadline_s`` bounds the task body's running time (DESIGN.md
        §19): an attempt running longer has its worker killed and fails
        retryable.  Defaults to ``runtime_start(deadline_s=)`` /
        ``RJAX_DEADLINE_S``; ``None`` = unbounded.

        ``inout`` lists argument Futures the task semantically *updates*: the
        runtime bumps their datum version (COMPSs renaming) so later readers
        depend on this task's output — the Future objects are re-pointed at
        the new version and the task's extra return values (beyond
        ``returns``) provide the new contents, in ``inout`` order.

        ``placement_hint`` names the node the task would prefer to run on
        (collectives pin merges where the larger child lives, DESIGN.md
        §16); only the ``locality`` policy acts on it.
        """
        if self._stopped:
            raise RuntimeError("runtime is stopped")
        kwargs = kwargs or {}
        tid = self.graph.next_task_id()
        tname = name or getattr(fn, "__name__", "task")

        dep_keys = set()

        def _collect(f: Future):
            dep_keys.add(f.key)
            # snapshot: INOUT renaming mutates the caller's handle later;
            # the task must keep reading the version it was submitted with
            return Future(f.data_id, f.version, f.producer_task, self.store)

        args = _walk(args, _collect)
        kwargs = _walk(kwargs, _collect)

        out_futures: List[Future] = []
        out_keys: List[Tuple[int, int]] = []
        for _ in range(returns):
            did = self.store.new_data_id()
            f = Future(did, 1, tid, self.store)
            out_futures.append(f)
            out_keys.append(f.key)
        # INOUT renaming: new version of an existing datum
        for f in inout:
            if f.key not in dep_keys:
                raise ValueError("inout future must also be passed as an argument")
            new_v = f.version + 1
            out_keys.append((f.data_id, new_v))
            # re-point the caller's handle at the new version; tasks already
            # submitted captured the old (data_id, version) key.
            f.version = new_v
            f.producer_task = tid

        node = TaskNode(
            task_id=tid, name=tname, fn=fn, args=args, kwargs=kwargs,
            dep_keys=dep_keys, out_keys=out_keys,
            max_retries=self.retry.max_retries if max_retries is None else max_retries,
            priority=priority, speculatable=speculatable,
            deadline_s=(self.default_deadline_s if deadline_s is None
                        else float(deadline_s)),
        )
        with self._inflight_cond:
            self._inflight += 1
        # hint before add_task: the task may be immediately ready and taken
        # by a dispatcher the instant push_many releases it
        if placement_hint is not None:
            self.scheduler.set_hint(tid, placement_hint)
        if self.telemetry.enabled:
            self.telemetry.note_submit([{"task": tid, "name": tname}])
        ready = self.graph.add_task(node)
        self.scheduler.push_many(ready)
        if returns == 1 and not inout:
            return out_futures[0]
        return tuple(out_futures) if returns > 1 else out_futures[0] if out_futures else None

    def submit_many(
        self,
        fn: Callable,
        args_list: Sequence[tuple],
        *,
        name: Optional[str] = None,
        returns: int = 1,
        max_retries: Optional[int] = None,
        priority: int = 0,
        speculatable: bool = True,
        deadline_s: Optional[float] = None,
    ) -> List[Any]:
        """Fan-out submission: one task per entry of ``args_list`` (each a
        tuple of positional arguments), amortizing the per-task graph,
        store and in-flight locking over the whole batch (DESIGN.md §14).
        Returns one Future (or tuple of Futures when ``returns > 1``) per
        entry, in order.  Semantically identical to calling :meth:`submit`
        in a loop; INOUT parameters are not supported here."""
        if self._stopped:
            raise RuntimeError("runtime is stopped")
        args_list = list(args_list)
        if not args_list:
            return []
        tname = name or getattr(fn, "__name__", "task")
        n = len(args_list)
        tids = self.graph.next_task_ids(n)
        dids = iter(self.store.new_data_ids(n * returns))
        max_r = self.retry.max_retries if max_retries is None else max_retries
        dl = self.default_deadline_s if deadline_s is None else float(deadline_s)

        nodes: List[TaskNode] = []
        futures_out: List[Any] = []
        for tid, raw_args in zip(tids, args_list):
            dep_keys: set = set()

            def _collect(f: Future, _deps=dep_keys):
                _deps.add(f.key)
                return Future(f.data_id, f.version, f.producer_task, self.store)

            args = _walk(tuple(raw_args), _collect)
            out_futures = [Future(next(dids), 1, tid, self.store)
                           for _ in range(returns)]
            nodes.append(TaskNode(
                task_id=tid, name=tname, fn=fn, args=args, kwargs={},
                dep_keys=dep_keys,
                out_keys=[f.key for f in out_futures],
                max_retries=max_r, priority=priority,
                speculatable=speculatable, deadline_s=dl,
            ))
            futures_out.append(out_futures[0] if returns == 1
                               else tuple(out_futures))
        with self._inflight_cond:
            self._inflight += n
        if self.telemetry.enabled:
            self.telemetry.note_submit(
                [{"task": nd.task_id, "name": tname} for nd in nodes])
        ready = self.graph.add_tasks(nodes)
        self.scheduler.push_many(ready)
        return futures_out

    # ------------------------------------------------------- input resolution
    def _resolve_inputs(self, t: TaskNode, node_id: int, block: bool = True
                        ) -> Tuple[tuple, dict, Dict[int, Tuple[int, int]]]:
        nbytes_in = 0
        input_keys: Dict[int, Tuple[int, int]] = {}
        # a backend that understands RemoteValue placeholders (the cluster
        # executor) gets them verbatim — the bytes move node↔node, never
        # through this process (DESIGN.md §15)
        materialize = not getattr(self.executor, "remote_values_ok", False)

        def _fetch(f: Future):
            nonlocal nbytes_in
            try:
                v = self.store.get_nowait(f.key, materialize=materialize)
            except KeyError:
                # value arrived concurrently (or is being re-executed
                # after its home node died); block briefly — unless the
                # caller is the event-loop pump (DESIGN.md §18), which
                # must never wait: it re-enters via ``resume_begin`` on
                # a recovery thread instead
                if not block:
                    raise _InputsNotReady()
                try:
                    v = self.store.get(f.key, timeout=self.resolve_timeout_s,
                                       materialize=materialize)
                except TimeoutError as terr:
                    with self._recover_lock:
                        recovering = f.key in self._recovering
                    if recovering:
                        # lineage re-execution is slower than the resolve
                        # window: this is an input loss, not this task's
                        # failure — grant the lost-input retry allowance
                        terr.lost_input = True
                    raise
            except BaseException as err:
                raise PoisonedInputError(f.producer_task, err) from err
            nbytes_in += _nbytes(v)
            self.store.note_location(f.key, node_id)
            input_keys[id(v)] = f.key
            return v

        args = _walk(t.args, _fetch)
        kwargs = _walk(t.kwargs, _fetch)
        t.nbytes_in = nbytes_in
        return args, kwargs, input_keys

    # --------------------------------------------------------- task lifecycle
    def begin_task(self, tid: int, worker: int, node_id: int,
                   block_inputs: bool = True) -> Optional[TaskExecution]:
        """Claim ``tid`` and resolve its inputs.  Returns ``None`` when the
        task was cancelled before start (lost speculation race) or input
        resolution already completed it (poisoned input / resolve error) —
        in both cases no completion call must follow.

        With ``block_inputs=False`` (the async control plane's pump), an
        input that is not immediately in the store raises
        :class:`InputsPending` instead of waiting; finish the claim off
        the loop with :meth:`resume_begin`."""
        t = self.graph.claim_running(tid, worker, node_id)
        if t is None:
            return None  # cancelled before start (lost speculation race)
        t0 = time.perf_counter()
        if self.telemetry.enabled:
            self.telemetry.note_dispatch(t.task_id, t.name, worker,
                                         node_id, t0)
        return self._begin_resolve(t, worker, node_id, t0,
                                   block=block_inputs)

    def _begin_resolve(self, t: TaskNode, worker: int, node_id: int,
                       t0: float, block: bool = True
                       ) -> Optional[TaskExecution]:
        try:
            args, kwargs, input_keys = self._resolve_inputs(t, node_id,
                                                            block=block)
        except _InputsNotReady:
            raise InputsPending(t, worker, node_id, t0)
        except PoisonedInputError as err:
            self._finish_failure(t, err, retryable=False)
            self._trace_task(t, worker, node_id, t0, ok=False)
            return None
        except BaseException as err:
            self._handle_task_error(t, err, worker, node_id, t0)
            return None
        return TaskExecution(t, args, kwargs, input_keys, t0, worker, node_id,
                             t_run=time.perf_counter())

    def resume_begin(self, pend: "InputsPending") -> Optional[TaskExecution]:
        """Blocking tail of a ``begin_task(..., block_inputs=False)``
        that raised :class:`InputsPending` — same contract as
        ``begin_task`` (the claim is already made; errors are handled
        internally, never raised)."""
        return self._begin_resolve(pend.t, pend.worker, pend.node_id,
                                   pend.t0, block=True)

    def complete_task(self, ex: TaskExecution, result: Any) -> None:
        """Successful body execution: publish outputs, release children."""
        self._finish_success(ex.t, result, ex.node_id)
        self._trace_task(ex.t, ex.worker, ex.node_id, ex.t0, ok=True,
                         t_run=ex.t_run)

    def fail_task(self, ex: TaskExecution, err: BaseException) -> None:
        """Body execution raised: apply the retry policy or fail."""
        if isinstance(err, PoisonedInputError):
            self._finish_failure(ex.t, err, retryable=False)
            self._trace_task(ex.t, ex.worker, ex.node_id, ex.t0, ok=False,
                             t_run=ex.t_run)
            return
        self._handle_task_error(ex.t, err, ex.worker, ex.node_id, ex.t0,
                                t_run=ex.t_run)

    def _handle_task_error(self, t: TaskNode, err: BaseException,
                           worker: int, node_id: int, t0: float,
                           t_run: Optional[float] = None) -> None:
        allowed = t.max_retries
        lost = bool(getattr(err, "lost_input", False))
        if lost:
            allowed += LOST_INPUT_RETRIES
        if self.retry.should_retry(t.attempts, allowed, err):
            # one unified backoff policy (DESIGN.md §19): exponential in
            # the attempt number with bounded jitter, folded with the
            # lost-input pacing — the datum only reappears once lineage
            # recovery has re-executed its producer
            backoff = self.retry.delay_for(
                t.attempts, lost_input=lost,
                lost_input_pace=LOST_INPUT_BACKOFF_S)
            if backoff:
                # completions run on shared threads (the pool collector, a
                # channel reader) — a blocking sleep there would stall
                # every worker's completions, so backoff is a timer
                timer = threading.Timer(backoff,
                                        self._requeue_retry, args=(t.task_id,))
                timer.daemon = True
                timer.start()
            else:
                self._requeue_retry(t.task_id)
            self._trace_task(t, worker, node_id, t0, ok=False, retried=True,
                             t_run=t_run)
            return
        self._finish_failure(t, err, retryable=True)
        self._trace_task(t, worker, node_id, t0, ok=False, t_run=t_run)

    def _requeue_retry(self, task_id: int) -> None:
        self.graph.requeue_for_retry(task_id)
        self.scheduler.push(task_id)

    def _execute(self, tid: int, worker: int, node_id: int) -> None:
        """Synchronous task lifecycle — the non-pipelined (thread) path."""
        ex = self.begin_task(tid, worker, node_id)
        if ex is None:
            return
        try:
            result = self.executor.invoke(worker, ex.t.fn, ex.args, ex.kwargs,
                                          input_keys=ex.input_keys)
        except BaseException as err:
            self.fail_task(ex, err)
            return
        self.complete_task(ex, result)

    def _trace_task(self, t: TaskNode, worker: int, node_id: int, t0: float,
                    ok: bool, retried: bool = False,
                    t_run: Optional[float] = None) -> None:
        t1 = time.perf_counter()
        self.tracer.record(TraceEvent(
            kind="task", name=t.name, worker=worker, node=node_id,
            t0=t0, t1=t1, task_id=t.task_id,
            meta={"ok": ok, "retried": retried, "attempt": t.attempts,
                  "speculative_of": t.speculative_of},
        ))
        if self.telemetry.enabled:
            self.telemetry.note_task(t.task_id, t.name, worker, node_id,
                                     t0, t_run, t1, ok, retried)

    # ------------------------------------------------------- completion paths
    def _logical_id(self, t: TaskNode) -> int:
        return t.speculative_of if t.speculative_of is not None else t.task_id

    def _claim_completion(self, t: TaskNode) -> bool:
        lid = self._logical_id(t)
        with self._logical_lock:
            if self._logical_done.get(lid):
                return False
            self._logical_done[lid] = True
            return True

    def _put_output(self, key: Tuple[int, int], value: Any, node_id: int) -> None:
        self.store.put(key, value, node=node_id)
        if self._recovering:   # bare read: cheap miss on the hot path
            with self._recover_lock:
                self._recovering.discard(key)
        self.executor.publish(key, value)

    def _finish_success(self, t: TaskNode, result: Any, node_id: int) -> None:
        try:
            primary = self.graph.get(self._logical_id(t))
        except KeyError:
            # the logical task was pruned long after completion (graph
            # retention) — this can only be a very late clone: discard
            self.graph.mark_cancelled(t.task_id)
            self._dec_inflight(t)
            return
        if not self._claim_completion(t):
            # lost the speculation race — discard
            self.graph.mark_cancelled(t.task_id)
            self._dec_inflight(t)
            return
        out_keys = primary.out_keys
        if len(out_keys) == 0:
            pass
        elif len(out_keys) == 1:
            self._put_output(out_keys[0], result, node_id)
        else:
            if not isinstance(result, (tuple, list)) or len(result) != len(out_keys):
                err = TypeError(
                    f"task {primary.name} declared {len(out_keys)} outputs but "
                    f"returned {type(result).__name__}"
                )
                self._publish_failure(primary, err)
                if t.task_id != primary.task_id:
                    self.graph.mark_cancelled(t.task_id)
                self._dec_inflight(t)
                return
            for key, val in zip(out_keys, result):
                self._put_output(key, val, node_id)
        if out_keys:
            # observed output footprint feeds memory-aware placement
            self.scheduler.note_output_bytes(
                primary.name, sum(self.store.nbytes(k) for k in out_keys))
        ready = self.graph.mark_done(primary.task_id)
        if t.task_id != primary.task_id:
            # speculative clone won: record clone done too
            self.graph.mark_done(t.task_id)
        self.scheduler.push_many(ready)
        self._dec_inflight(t)

    def _publish_failure(self, primary: TaskNode, err: BaseException) -> None:
        wrapped = TaskFailedError(primary.name, primary.task_id, err)
        for key in primary.out_keys:
            self.store.put_error(key, wrapped)
            if self._recovering:
                with self._recover_lock:
                    self._recovering.discard(key)
        ready = self.graph.mark_failed(primary.task_id, err)
        self.scheduler.push_many(ready)

    def _finish_failure(self, t: TaskNode, err: BaseException, retryable: bool) -> None:
        try:
            primary = self.graph.get(self._logical_id(t))
        except KeyError:
            self.graph.mark_cancelled(t.task_id)
            self._dec_inflight(t)
            return
        if not self._claim_completion(t):
            self.graph.mark_cancelled(t.task_id)
            self._dec_inflight(t)
            return
        self._publish_failure(primary, err)
        if t.task_id != primary.task_id:
            self.graph.mark_cancelled(t.task_id)
        self._dec_inflight(t)

    def _dec_inflight(self, t: TaskNode) -> None:
        with self._inflight_cond:
            self._inflight -= 1
            if self._inflight <= 0:
                self._inflight_cond.notify_all()

    # ------------------------------------------- lineage recovery (§15)
    def recover_lost_node(self, node_id: int) -> List[Tuple[int, int]]:
        """A node died holding the only copy of node-resident results:
        invalidate their placeholders (readers block instead of fetching
        from a corpse) and re-execute the producers from graph lineage.
        Returns the lost keys so the executor can strike them from every
        agent's residency ledger.  Called by the cluster executor's
        restart path, right after ``store.forget_node``."""
        lost = self.store.invalidate_lost(node_id)
        self.relaunch_lost(lost, node_id)
        return lost

    def relaunch_lost(self, keys: List[Tuple[int, int]],
                      node_id: Optional[int] = None) -> None:
        """Resurrect the producer of each lost datum.  Transitive losses
        on the same node converge naturally: a resurrected producer whose
        own input was also lost blocks in ``_resolve_inputs`` until that
        input's producer (resurrected in the same sweep) re-publishes.
        A producer pruned from the graph (``RJAX_GRAPH_RETAIN``) is
        unrecoverable — its consumers fail fast with a retryable error
        instead of hanging."""
        if not keys:
            return
        from .executors import WorkerCrashedError
        with self._recover_lock:
            self._recovering.update(tuple(k) for k in keys)
        producers: Dict[int, None] = {}
        for key in keys:
            tid = self.graph.producer_of(key)
            if tid is None:
                self.store.put_error(key, WorkerCrashedError(
                    f"datum d{key[0]}v{key[1]} was lost with node "
                    f"{node_id} and its producer is no longer in the "
                    f"graph (pruned by retention)"))
                with self._recover_lock:
                    self._recovering.discard(tuple(key))
            else:
                producers[tid] = None
        for tid in producers:
            self._resurrect(tid)

    def _resurrect(self, tid: int) -> None:
        """Re-run a completed task: flip it back to READY, clear its
        completion once-flag, and requeue.  No-op unless the task is DONE
        — a concurrent sweep may already have resurrected it.  The flag
        clears AFTER the state flip (the task cannot be dispatched until
        the push below, so nothing races the fresh flag) and only for
        genuinely resurrected tasks — clearing it for a FAILED task could
        let a late speculative clone double-publish."""
        if not self.graph.resurrect(tid):
            return
        with self._logical_lock:
            self._logical_done.pop(tid, None)
        with self._inflight_cond:
            self._inflight += 1
        self.scheduler.push(tid)

    # ------------------------------------------------------------ speculation
    def _speculation_loop(self) -> None:
        cfg = self.speculation
        while not self._stopped:
            time.sleep(cfg.poll_interval)
            if self.scheduler.queue_len() > 0:
                continue
            # indexed scans (DESIGN.md §14): the running set and the
            # bounded per-name duration history replace the full-graph walk
            running = self.graph.running_nodes()
            if not running:
                continue
            # idle capacity = workers with NOTHING in flight.  (The
            # _idle_workers counter decrements once per in-flight task, so
            # under pipeline_depth > 1 it goes negative while half the
            # pool sits idle — it cannot gate speculation.)
            busy_workers = {n.worker for n in running}
            if len(busy_workers) >= self.n_workers:
                continue
            now = time.perf_counter()
            for n in running:
                if not n.speculatable or n.speculative_of is not None:
                    continue
                ds = self.graph.done_durations(n.name)
                if len(ds) < cfg.min_samples:
                    continue
                med = statistics.median(ds)
                run_t = now - n.start_t
                if run_t < cfg.min_seconds or run_t < cfg.factor * med:
                    continue
                with self._logical_lock:
                    if self._logical_done.get(n.task_id):
                        continue
                    already = getattr(n, "_speculated", False)
                if already:
                    continue
                n._speculated = True  # type: ignore[attr-defined]
                clone_id = self.graph.next_task_id()
                clone = TaskNode(
                    task_id=clone_id, name=n.name + "(spec)", fn=n.fn,
                    args=n.args, kwargs=n.kwargs, dep_keys=set(n.dep_keys),
                    out_keys=[], speculative_of=n.task_id, speculatable=False,
                )
                with self._inflight_cond:
                    self._inflight += 1
                ready = self.graph.add_task(clone)
                self.scheduler.push_many(ready)

    # --------------------------------------------------------- sync primitives
    def barrier(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted task reached a terminal state
        (paper's ``compss_barrier``)."""
        with self._inflight_cond:
            if not self._inflight_cond.wait_for(lambda: self._inflight <= 0,
                                                timeout=timeout):
                raise TimeoutError(f"barrier timed out with {self._inflight} tasks inflight")

    def wait_on(self, obj: Any, timeout: Optional[float] = None) -> Any:
        """Synchronize: resolve Future(s) (paper's ``compss_wait_on``).
        Accepts a Future or any nesting of lists/tuples/dicts of Futures."""
        return _walk(obj, lambda f: f.result(timeout=timeout))

    def stop(self, wait: bool = True) -> None:
        """``compss_stop``: optionally drain, then shut the pool down.
        Idempotent — a second call (e.g. explicit ``runtime_stop``
        followed by the context manager's exit) is a no-op."""
        if self._stopped:
            return
        if wait:
            self.barrier()
        self._stopped = True
        if self.dashboard is not None:
            self.dashboard.close()
        self.telemetry.close()
        self.scheduler.close()
        self.executor.shutdown(wait=wait)
        self.tracer.stop()
        self.store.dispose_spills()

    # ---------------------------------------------------------- with-statement
    def __enter__(self) -> "Runtime":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Guaranteed teardown for ``with runtime_start(...) as rt:`` —
        drain on the clean path, tear down immediately (no barrier) when
        the body raised.  Also clears the module-level current runtime
        if this instance is still it."""
        from . import api
        api._release_runtime(self, wait=exc_type is None)

    # --------------------------------------------------------------- metrics
    def stats(self) -> dict:
        c = self.graph.counters()   # O(1): incrementally maintained
        raw_ex = self.executor.stats()
        # uniform schema across backends (DESIGN.md §17): every canonical
        # executor counter present, 0 where the backend has no such concept
        ex_stats = normalize_executor_stats(raw_ex)
        data_plane = self.store.transfer_detail()
        # wire-level truth wins where the executor measures its own link
        # (the cluster backend counts actual Put payloads out + result
        # frames back); other backends fall back to the store's
        # cross-domain ledger — judged on the *raw* stats, since the
        # normalized schema always carries a (zero) relay_bytes key
        relay = raw_ex.get("relay_bytes",
                           data_plane["scheduler_relay_bytes"])
        return {
            "tasks_submitted": c["submitted"],
            "tasks_done": c["done"],
            "tasks_failed": c["failed"],
            "tasks_cancelled": c["cancelled"],
            "retries": c["retries"],
            "speculative": c["speculative"],
            "total_work_s": c["total_work_s"],
            "critical_path_s": self.graph.critical_path_seconds(),
            "wallclock_s": self.tracer.wallclock(),
            "utilization": self.tracer.utilization(self.n_workers),
            "scheduler_relay_bytes": relay,
            "p2p_bytes": data_plane["p2p_bytes"],
            "data_plane": data_plane,
            "executor": ex_stats,
            "memory": self.store.memory_stats(),
        }
