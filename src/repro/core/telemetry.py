"""Live telemetry plane (DESIGN.md §17).

Production traffic is undebuggable from end-of-run aggregates alone, so
the runtime keeps a *live* view of itself:

* **Heartbeats** — every cluster node agent posts a periodic ``hb``
  message on its existing scheduler channel (cadence settled by the
  welcome handshake / ``RJAX_HEARTBEAT_S``; 0 disables) carrying its
  node-plane bytes/spill/fault ledger, pool occupancy, and p2p fetch
  counters.  The thread/process backends have no wire to ride, so an
  in-process sampler thread synthesizes the equivalent snapshot from
  ``executor.stats()`` + the store's memory ledger at the same cadence.
* **Task stream** — a bounded ring of lifecycle events
  (:class:`~repro.core.tracing.TaskStream`), fed from ``Runtime.submit``
  / ``begin_task`` / the completion paths.
* **Snapshots** — the JSON payloads behind the dashboard endpoints
  (:mod:`repro.core.dashboard`): ``/api/status``, ``/api/tasks``,
  ``/api/transfers``.

The hub itself is backend-agnostic: the cluster executor routes real
agent heartbeats into :meth:`TelemetryHub.note_heartbeat`; the sampler
calls the same method with a synthetic payload.  Everything here is
counters and dict snapshots — no third-party dependencies.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, List, Optional

from .tracing import TaskStream

# default heartbeat/sampler cadence, seconds; 0 disables
HEARTBEAT_DEFAULT_S = 1.0


def heartbeat_interval(welcome_value: Any = None) -> float:
    """Resolve the heartbeat cadence: the local ``RJAX_HEARTBEAT_S``
    wins (an operator pinning one node), then the scheduler's
    welcome-carried value, then the default.  ``0`` disables."""
    env = os.environ.get("RJAX_HEARTBEAT_S")
    for raw in (env, welcome_value):
        if raw is None or raw == "":
            continue
        try:
            return max(0.0, float(raw))
        except (TypeError, ValueError):
            continue
    return HEARTBEAT_DEFAULT_S


# canonical executor-stats schema: the union of every backend's numeric
# counters, so ``runtime_stats()["executor"]`` exposes the same keys on
# thread/process/cluster alike (absent concepts read 0, not KeyError)
EXECUTOR_STAT_KEYS = (
    # shared
    "pipeline_depth",
    # process backend
    "worker_restarts", "descriptor_sends", "batched_sends",
    "segments", "bytes_planed", "refs_shipped", "deadline_kills",
    # cluster backend
    "n_agents", "workers_per_node", "agent_restarts", "liveness_kills",
    "reconnects", "replica_bytes", "replica_hits",
    "broadcasts",
    "puts", "refs", "fetches", "fetch_bytes", "bytes_shipped",
    "relay_result_bytes", "remote_results", "deferred_result_bytes",
    "relay_bytes",
)


def normalize_executor_stats(stats: dict) -> dict:
    """Uniform executor-stats schema: every canonical key present (0 when
    the backend has no such concept), backend-specific extras preserved."""
    out = {k: 0 for k in EXECUTOR_STAT_KEYS}
    out["p2p"] = False
    out.update(stats)
    return out


class TelemetryHub:
    """Scheduler-side aggregation point for the live telemetry plane.

    Holds the bounded task-lifecycle ring, the latest heartbeat per node
    (real agent heartbeats or sampler snapshots), and a per-node in-flight
    counter maintained from the dispatch/completion hooks.  All methods
    are thread-safe; the hot-path hooks (``note_dispatch``/``note_task``)
    are a guard check plus one ring append and one dict bump."""

    def __init__(self, enabled: bool = True,
                 ring_capacity: Optional[int] = None):
        self.enabled = bool(enabled)
        self.stream = TaskStream(ring_capacity)
        self._lock = threading.Lock()
        self._nodes: Dict[Any, dict] = {}      # node -> latest heartbeat
        self._inflight: Dict[int, int] = {}    # node -> dispatched, not done
        self.t_started = time.time()
        self._sampler: Optional[threading.Thread] = None
        self._sampler_stop = threading.Event()

    # ------------------------------------------------------------ heartbeats
    def note_heartbeat(self, node: Any, payload: dict) -> None:
        """An agent heartbeat (or sampler snapshot) arrived for ``node``."""
        now = time.time()
        with self._lock:
            ent = self._nodes.get(node)
            if ent is None:
                ent = self._nodes[node] = {"count": 0}
            ent["count"] += 1
            ent["t"] = now
            ent["payload"] = payload

    def nodes(self) -> Dict[Any, dict]:
        """Latest heartbeat per node: ``{node: {count, t, payload}}``."""
        with self._lock:
            return {n: dict(e) for n, e in self._nodes.items()}

    # ------------------------------------------------- task lifecycle hooks
    def note_submit(self, rows: List[dict]) -> None:
        """Tasks entered the graph; each row carries ``task``/``name``."""
        t = time.perf_counter()
        for r in rows:
            r["t"] = t
        self.stream.extend("submit", rows)

    def note_dispatch(self, tid: int, name: str, worker: int, node: int,
                      t0: float) -> None:
        """A dispatcher claimed the task (begin_task): input resolution
        starts now; the matching completion event's ``t_run`` - ``t0``
        gap is the fetch/stall time."""
        self.stream.append("dispatch", task=tid, name=name, worker=worker,
                           node=node, t=t0)
        with self._lock:
            self._inflight[node] = self._inflight.get(node, 0) + 1

    def note_task(self, tid: int, name: str, worker: int, node: int,
                  t0: float, t_run: Optional[float], t1: float,
                  ok: bool, retried: bool) -> None:
        """The attempt reached a terminal state (done/fail/retry)."""
        kind = "done" if ok else ("retry" if retried else "fail")
        self.stream.append(kind, task=tid, name=name, worker=worker,
                           node=node, t0=t0, t_run=t_run, t1=t1)
        with self._lock:
            left = self._inflight.get(node, 0) - 1
            if left > 0:
                self._inflight[node] = left
            else:
                self._inflight.pop(node, None)

    def inflight(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._inflight)

    # --------------------------------------------------- in-process sampler
    def start_sampler(self, runtime, interval: Optional[float] = None) -> None:
        """Thread/process-backend equivalent of agent heartbeats: sample
        ``executor.stats()`` + the store's memory ledger every
        ``interval`` seconds into a single ``local`` pseudo-node entry
        (one address-space plane ⇒ one gauge)."""
        if self._sampler is not None:
            return
        interval = heartbeat_interval(None) if interval is None else interval
        if interval <= 0:
            return

        def loop():
            # sample immediately: a dashboard opened right after start
            # should show the node, not a blank first interval
            while True:
                try:
                    self.sample_local(runtime)
                except Exception:
                    pass   # a torn-down runtime mid-sample is not an error
                if self._sampler_stop.wait(interval):
                    return

        self._sampler = threading.Thread(
            target=loop, daemon=True, name=f"{runtime.name}-telemetry")
        self._sampler.start()

    def sample_local(self, runtime) -> None:
        payload = {"t": time.time(), "sampled": True}
        payload.update(runtime.executor.stats())
        for k, v in runtime.store.memory_stats().items():
            payload[f"store_{k}"] = v
        self.note_heartbeat("local", payload)

    def close(self) -> None:
        self._sampler_stop.set()

    # ------------------------------------------------------------ snapshots
    def snapshot_status(self, runtime) -> dict:
        """The ``/api/status`` payload: runtime identity, task counters,
        and the per-node heartbeat view (memory/occupancy gauges)."""
        counters = runtime.graph.counters()
        now = time.time()
        inflight = self.inflight()
        nodes = {}
        for nid, ent in self.nodes().items():
            entry = {"heartbeats": ent["count"],
                     "age_s": round(now - ent["t"], 3),
                     "inflight": inflight.get(nid, 0)}
            entry.update(ent.get("payload") or {})
            nodes[str(nid)] = entry
        # failure-detector verdicts (DESIGN.md §19): merged per node so the
        # dashboard shows exactly what liveness decisions are based on —
        # including nodes that have never beaten (install is a synthetic
        # beat, so they still appear, aging towards suspect/dead)
        for nid, view in self._executor_liveness(runtime).items():
            entry = nodes.setdefault(
                str(nid), {"heartbeats": 0, "age_s": None,
                           "inflight": inflight.get(nid, 0)})
            entry["state"] = view.get("state")
            entry["beat_age_s"] = view.get("beat_age_s")
            # replicated intermediates resident on this node (§20)
            entry["replicas"] = view.get("replicas", 0)
        return {
            "name": runtime.name,
            "backend": runtime.backend,
            "n_workers": runtime.n_workers,
            "workers_per_node": runtime.workers_per_node,
            "uptime_s": round(now - self.t_started, 3),
            "telemetry_enabled": self.enabled,
            "queue_len": runtime.scheduler.queue_len(),
            "tasks": counters,
            "inflight": {str(k): v for k, v in inflight.items()},
            "ring": {"seq": self.stream.last_seq, "size": len(self.stream),
                     "capacity": self.stream.capacity,
                     "dropped": self.stream.dropped},
            "nodes": nodes,
        }

    @staticmethod
    def _executor_liveness(runtime) -> dict:
        """The cluster executor's failure-detector snapshot (``{}`` for
        backends without one)."""
        live = getattr(getattr(runtime, "executor", None), "liveness", None)
        if not callable(live):
            return {}
        try:
            return live() or {}
        except Exception:
            return {}

    def snapshot_tasks(self, runtime, since: int = 0,
                       limit: Optional[int] = None) -> dict:
        """The ``/api/tasks`` payload: lifecycle events newer than
        ``since`` plus the clock anchor the client needs to place them."""
        return {
            "now": time.perf_counter(),
            "t_start": runtime.tracer.t_start,
            "last_seq": self.stream.last_seq,
            "dropped": self.stream.dropped,
            "events": self.stream.since(since, limit=limit),
        }

    def snapshot_transfers(self, runtime) -> dict:
        """The ``/api/transfers`` payload: the node×node byte matrix from
        the §15 ledger (source ``-1`` = the scheduler's own link) plus
        the aggregate split it must stay consistent with."""
        detail = runtime.store.transfer_detail()
        return {
            "matrix": detail.get("matrix", []),
            "scheduler_relay_bytes": detail["scheduler_relay_bytes"],
            "p2p_bytes": detail["p2p_bytes"],
            "p2p_by_source": {str(k): v
                              for k, v in detail["p2p_by_source"].items()},
            "transfers": detail["transfers"],
            "transfer_bytes": detail["transfer_bytes"],
        }
