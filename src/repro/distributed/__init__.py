from .sharding import (  # noqa: F401
    ShardingRules,
    batch_pspecs,
    cache_pspecs,
    default_rules,
    param_pspecs,
    tree_map_axes,
)
from .steps import make_decode_step, make_prefill_step, make_train_step  # noqa: F401
