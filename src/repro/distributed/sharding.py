"""Logical-axis sharding rules → PartitionSpecs (MaxText-style).

Every parameter tree has a parallel *axes tree* (same structure, leaves are
tuples of logical names — see ``models.lm.param_axes``).  A ``ShardingRules``
table maps logical names to mesh axes; ``param_pspecs`` applies the table
with divisibility checks (a dim is only sharded if its size divides evenly —
e.g. MQA's single KV head falls back to replication automatically).

Default placement (single-pod ``(data, model)``, multi-pod ``(pod, data,
model)``):

* batch over (pod, data) — DP
* ``heads/mlp/vocab/expert/ssm_*/rnn`` over model — TP/EP
* ``embed`` (weights' d_model dim) over data — FSDP/ZeRO-3 storage
* ``expert_mlp`` (per-expert d_ff) over data — FSDP storage, gathered
  per-layer inside the MoE shard_map
* optimizer state inherits the parameter specs (moments are same-shaped)

These tables are the primary §Perf hillclimb lever: rules are plain data.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


def tree_map_axes(fn, *trees):
    """tree.map treating tuples-of-names as leaves."""
    return jax.tree.map(fn, *trees, is_leaf=lambda x: isinstance(x, tuple))


@dataclass(frozen=True)
class ShardingRules:
    table: Dict[str, MeshAxes] = field(default_factory=dict)

    def lookup(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        return self.table.get(name)

    def override(self, **kw) -> "ShardingRules":
        t = dict(self.table)
        t.update(kw)
        return ShardingRules(t)


def default_rules(mesh: Mesh) -> ShardingRules:
    dp: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp = "data" if "data" in mesh.axis_names else None
    return ShardingRules({
        "batch": dp,
        "vocab": "model",
        "embed": fsdp,          # FSDP storage of the d_model dim
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "expert": "model",      # EP
        "expert_mlp": fsdp,     # FSDP storage; gathered inside MoE shard_map
        "expert_router": None,
        "ssm_inproj": "model",
        "ssm_conv": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "rnn": "model",
        "layers": None,
        "seq": None,
    })


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for(shape: Tuple[int, ...], logical: Tuple[Optional[str], ...],
             rules: ShardingRules, mesh: Mesh) -> P:
    """PartitionSpec for one array; respects divisibility and never assigns
    the same mesh axis twice."""
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    dims = []
    for size, name in zip(shape, logical):
        axes = rules.lookup(name)
        if axes is None:
            dims.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        ax_tuple = tuple(a for a in ax_tuple if a in mesh.axis_names and a not in used)
        if not ax_tuple or size % _axis_size(mesh, ax_tuple) != 0:
            dims.append(None)
            continue
        used.update(ax_tuple)
        dims.append(ax_tuple[0] if len(ax_tuple) == 1 else ax_tuple)
    return P(*dims)


def param_pspecs(axes_tree, shapes_tree, rules: ShardingRules, mesh: Mesh):
    """axes_tree: tuples-of-names leaves; shapes_tree: ShapeDtypeStructs (or
    arrays) with matching structure."""
    flat_axes = jax.tree.leaves(axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    flat_shapes, treedef = jax.tree.flatten(shapes_tree)
    assert len(flat_axes) == len(flat_shapes), "axes/param tree mismatch"
    specs = [spec_for(tuple(s.shape), ax, rules, mesh)
             for s, ax in zip(flat_shapes, flat_axes)]
    return jax.tree.unflatten(treedef, specs)


def batch_pspecs(batch_tree, rules: ShardingRules, mesh: Mesh):
    """Shard dim 0 (batch) of every input over the DP axes; replicate rest."""
    dp = rules.lookup("batch")

    def one(x):
        if x.ndim == 0:
            return P()
        if x.shape[0] % _axis_size(mesh, dp) == 0 and _axis_size(mesh, dp) > 1:
            return P(dp if not isinstance(dp, tuple) or len(dp) > 1 else dp[0],
                     *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return jax.tree.map(one, batch_tree)


def cache_pspecs(cache_tree, rules: ShardingRules, mesh: Mesh):
    """Decode/prefill caches: keyed by leaf name (k/v/pos_map/conv/state/h)."""
    dp = rules.lookup("batch")
    model = "model" if "model" in mesh.axis_names else None

    def shard_dim(size, axes):
        if axes is None:
            return None
        if size % _axis_size(mesh, axes) != 0 or _axis_size(mesh, axes) == 1:
            return None
        if isinstance(axes, tuple) and len(axes) == 1:
            return axes[0]
        return axes

    def one(path, x):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        if name in ("k", "v"):           # (B, Sc, K, hd) [+ leading layers]
            b, kvh, hd = x.ndim - 4, x.ndim - 2, x.ndim - 1
            dims = [None] * x.ndim
            dims[b] = shard_dim(x.shape[b], dp)
            dims[kvh] = shard_dim(x.shape[kvh], model)
            if dims[kvh] is None:
                # MQA / few KV heads: shard head_dim instead (memory parity;
                # GSPMD reduces the contraction with a psum)
                dims[hd] = shard_dim(x.shape[hd], model)
            return P(*dims)
        if name == "pos_map":
            return P(*([None] * x.ndim))
        if name == "conv":               # (B, W, C)
            b, c = x.ndim - 3, x.ndim - 1
            dims = [None] * x.ndim
            dims[b] = shard_dim(x.shape[b], dp)
            dims[c] = shard_dim(x.shape[c], model)
            return P(*dims)
        if name == "state":              # (B, H, P, N)
            b, h = x.ndim - 4, x.ndim - 3
            dims = [None] * x.ndim
            dims[b] = shard_dim(x.shape[b], dp)
            dims[h] = shard_dim(x.shape[h], model)
            return P(*dims)
        if name == "h":                  # (B, R)
            dims = [None] * x.ndim
            dims[x.ndim - 2] = shard_dim(x.shape[x.ndim - 2], dp)
            dims[x.ndim - 1] = shard_dim(x.shape[x.ndim - 1], model)
            return P(*dims)
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
