"""Roofline analysis from compiled dry-run artifacts (assignment spec).

Three terms per (arch × shape × mesh):

    compute    = HLO_FLOPs / (chips × 197e12)
    memory     = HLO_bytes / (chips × 819e9)
    collective = collective_bytes / (chips × 50e9)

``cost_analysis()`` provides FLOPs/bytes; collective bytes are parsed from
the post-SPMD HLO text by summing operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) gives the useful-compute
ratio (catches remat/redundancy waste).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict

from ..launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from ..models.lm import LMConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape token like  bf16[8,128,2048]{2,1,0}  or f32[]
_SHAPE_RE = re.compile(r"(\w+?)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"                    # result shape (or tuple)
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(tok: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(tok):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    total_bytes: int = 0
    by_kind: Dict[str, int] = field(default_factory=dict)
    count: int = 0


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in (post-SPMD) HLO text.

    We take operand bytes = result bytes for all-reduce/permute, and operand
    bytes from the result for gather/scatter style ops via their semantics:
    the *operand* of an all-gather is result/group smaller, but the
    assignment asks for operand sizes summed — for simplicity and
    consistency we count the bytes that cross the wire per device:
    result bytes for all-gather / all-to-all / permute, operand (=result)
    bytes for all-reduce (×2 for the reduce+broadcast halves),
    operand bytes for reduce-scatter (= result × group).
    """
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        shape_tok, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_tok)
        if nbytes == 0:
            continue
        if kind == "all-reduce":
            wire = 2 * nbytes
        elif kind == "reduce-scatter":
            wire = nbytes  # result was already scattered; operand crossed once
        else:
            wire = nbytes
        stats.total_bytes += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0) + wire
        stats.count += 1
    return stats


def model_flops(cfg: LMConfig, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward, using
    *active* params for MoE.  D = tokens processed (decode: one new token
    per sequence; the cache-attention reads are memory traffic, not model
    FLOPs)."""
    n_active = active_params(cfg)
    tokens = batch if kind == "decode" else batch * seq
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg: LMConfig) -> float:
    """Parameter count with MoE experts scaled by top_k/n_experts (plus
    shared experts fully)."""
    import jax

    from ..models.lm import init_params
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = 0.0

    def visit(path, leaf):
        nonlocal total
        n = math.prod(leaf.shape)
        keys = [p.key for p in path if hasattr(p, "key")]
        if any(k in ("w_gate", "w_up", "w_down") for k in keys) and "moe" in keys:
            n = n * (cfg.top_k / max(cfg.n_experts, 1))
        total += n

    import jax.tree_util as jtu
    jtu.tree_map_with_path(visit, shapes)
    return total


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops_total: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * ICI_BW)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops_total / self.hlo_flops
                             if self.hlo_flops else 0.0)
        return self

    def as_dict(self) -> dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh, chips=self.chips,
            hlo_flops=self.hlo_flops, hlo_bytes=self.hlo_bytes,
            collective_bytes=self.collective_bytes,
            model_flops=self.model_flops_total,
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, bottleneck=self.bottleneck,
            useful_ratio=self.useful_ratio,
        )
