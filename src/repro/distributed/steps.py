"""Distributed train / prefill / decode steps (pjit + GSPMD + the MoE
shard_map region), with sharding-aware microbatched gradient accumulation.

``make_*`` returns ``(fn, in_shardings, out_shardings, donate_argnums)``
ready for ``jax.jit`` — the dry-run lowers these against ShapeDtypeStructs,
the real drivers call them on data.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.lm import LMConfig, forward, init_caches, init_params, loss_fn, param_axes
from ..optim.adamw import Optimizer
from .sharding import (
    ShardingRules,
    batch_pspecs,
    cache_pspecs,
    default_rules,
    param_pspecs,
    to_shardings,
)


def _dp_size(mesh: Mesh, rules: ShardingRules) -> int:
    dp = rules.lookup("batch")
    if dp is None:
        return 1
    axes = (dp,) if isinstance(dp, str) else dp
    n = 1
    for a in axes:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _constraint(tree, spec_fn, mesh):
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec_fn(x))), tree)


def make_train_step(
    cfg: LMConfig,
    mesh: Mesh,
    optimizer: Optimizer,
    *,
    rules: Optional[ShardingRules] = None,
    microbatches: int = 1,
    sample_batch: Any = None,
    grad_compress: Optional[str] = None,
    accum_unroll: bool = False,
):
    """Returns (train_step, in_shardings, out_shardings, donate_argnums).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    rules = rules or default_rules(mesh)
    dp = rules.lookup("batch")
    dp_size = _dp_size(mesh, rules)

    def loss_w(p, b):
        return loss_fn(cfg, p, b, mesh=mesh)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_w, has_aux=True)(
                params, batch)
        else:
            def split(x):
                B = x.shape[0]
                bs = B // dp_size
                mb = bs // microbatches
                assert mb * microbatches == bs, (
                    f"per-shard batch {bs} not divisible by {microbatches} microbatches")
                x4 = x.reshape((dp_size, microbatches, mb) + x.shape[1:])
                x4 = jax.lax.with_sharding_constraint(
                    x4, NamedSharding(mesh, P(dp, None, *([None] * (x.ndim - 1)))))
                xt = jnp.moveaxis(x4, 1, 0)
                return jax.lax.with_sharding_constraint(
                    xt, NamedSharding(mesh, P(None, dp, *([None] * (x.ndim - 1)))))

            mbs = jax.tree.map(split, batch)
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                gsum, lsum, asum = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:]),
                        NamedSharding(mesh, P(dp, *([None] * (x.ndim - 2))))), mb)
                (loss, metrics), g = jax.value_and_grad(loss_w, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + metrics["loss"], asum + metrics["aux"]), None

            init = (zero_g, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
            if accum_unroll:
                carry = init
                for i in range(microbatches):
                    mb_i = jax.tree.map(lambda x: x[i], mbs)
                    carry, _ = body(carry, mb_i)
                gsum, lsum, asum = carry
            else:
                (gsum, lsum, asum), _ = jax.lax.scan(body, init, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"loss": loss, "aux": asum / microbatches}

        if grad_compress and grad_compress != "none":
            from ..optim.compress import compressed_gradients
            grads, _ = compressed_gradients(grads, None, codec=grad_compress)
        new_params, new_opt, gnorm = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_params, new_opt, metrics

    # sharding trees
    pshapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(param_axes(cfg), pshapes, rules, mesh)
    oshapes = jax.eval_shape(optimizer.init, pshapes)
    ospecs = type(oshapes)(P(), pspecs, pspecs)
    p_sh = to_shardings(pspecs, mesh)
    o_sh = to_shardings(ospecs, mesh)

    def batch_sh(batch_like):
        return to_shardings(batch_pspecs(batch_like, rules, mesh), mesh)

    in_sh = (p_sh, o_sh, batch_sh(sample_batch) if sample_batch is not None else None)
    out_sh = (p_sh, o_sh, None)
    return train_step, in_sh, out_sh, (0, 1)


def make_prefill_step(cfg: LMConfig, mesh: Mesh, *, cache_len: int,
                      rules: Optional[ShardingRules] = None,
                      sample_batch: Any = None):
    """prefill(params, batch) -> (last_logits, caches)"""
    rules = rules or default_rules(mesh)

    def prefill(params, batch):
        logits, caches, _ = forward(cfg, params, batch,
                                    make_cache_len=cache_len, mesh=mesh,
                                    remat="none", last_only=True)
        return logits, caches

    pshapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(param_axes(cfg), pshapes, rules, mesh)
    p_sh = to_shardings(pspecs, mesh)
    b_sh = (to_shardings(batch_pspecs(sample_batch, rules, mesh), mesh)
            if sample_batch is not None else None)
    batch_size = (jax.tree.leaves(sample_batch)[0].shape[0]
                  if sample_batch is not None else None)
    cache_sh = None
    if batch_size is not None:
        cshapes = jax.eval_shape(lambda: init_caches(cfg, batch_size, cache_len))
        cache_sh = to_shardings(cache_pspecs(cshapes, rules, mesh), mesh)
    in_sh = (p_sh, b_sh)
    out_sh = (None, cache_sh)
    return prefill, in_sh, out_sh, ()


def make_decode_step(cfg: LMConfig, mesh: Mesh, *,
                     rules: Optional[ShardingRules] = None,
                     sample_batch: Any = None, sample_caches: Any = None):
    """decode(params, batch, caches, pos) -> (logits, new_caches)"""
    rules = rules or default_rules(mesh)

    def decode(params, batch, caches, pos):
        logits, new_caches, _ = forward(cfg, params, batch, caches=caches,
                                        pos_offset=pos, mesh=mesh, remat="none")
        return logits, new_caches

    pshapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(param_axes(cfg), pshapes, rules, mesh)
    p_sh = to_shardings(pspecs, mesh)
    b_sh = (to_shardings(batch_pspecs(sample_batch, rules, mesh), mesh)
            if sample_batch is not None else None)
    cache_sh = (to_shardings(cache_pspecs(sample_caches, rules, mesh), mesh)
                if sample_caches is not None else None)
    in_sh = (p_sh, b_sh, cache_sh, NamedSharding(mesh, P()))
    out_sh = (None, cache_sh)
    return decode, in_sh, out_sh, (2,)
