"""Paper §4 — the three benchmarking applications, task-parallel on the
RJAX runtime: KNN classification, K-means clustering, linear regression with
prediction.  Each module ships: the task functions, a sequential-style
driver (the code a user writes), a single-shot numpy oracle, a DAG generator
for the discrete-event simulator, and cost-model calibration."""
from . import kmeans, knn, linreg  # noqa: F401
from .common import tree_reduce  # noqa: F401
