"""Task-parallel linear regression with prediction (paper §4.3, Fig. 5).

Nine task types, mirroring the paper's DAG: ``LR_fill_fragment`` generates
(X, y) fragments; ``partial_ztz`` computes each fragment's Gram contribution
X'X (intercept column included); ``partial_zty`` computes X'y; two merge
trees combine them; ``compute_model_parameters`` solves the normal
equations; ``LR_genpred`` generates prediction inputs; ``compute_prediction``
applies the model; the final sync closes the pipeline.  This is the
deepest-dependency algorithm of the three — the paper uses it to show how
dependency depth erodes parallel efficiency.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from ..core import api, collectives
from ..core.simulator import CostModel, SimTask
from .common import calibrate_cost

# default k-ary width of the collective merge trees (DESIGN.md §16): one
# k-ary tree node is ONE task folding k partials, so the reduction costs
# (n-1)/(k-1) dispatches over ceil(log_k n) levels instead of n-1 over
# ceil(log2 n) — the dispatch overhead is what erodes linreg's scaling
MERGE_ARITY = 8

# --------------------------------------------------------------------- tasks
def lr_fill_fragment(seed: int, n: int, p: int, beta_seed: int = 1234,
                     noise: float = 0.1):
    """Synthetic (X, y) with a hidden ground-truth beta (shared seed)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    beta_rng = np.random.default_rng(beta_seed)
    beta = beta_rng.standard_normal(p + 1)
    y = beta[0] + X @ beta[1:] + noise * rng.standard_normal(n)
    return X.astype(np.float64), y.astype(np.float64)


def _with_intercept(X: np.ndarray) -> np.ndarray:
    return np.concatenate([np.ones((X.shape[0], 1)), X], axis=1)


def partial_ztz(frag) -> np.ndarray:
    X, _ = frag
    Z = _with_intercept(X)
    return Z.T @ Z            # the paper's GEMM hot-spot (×4 GEMM tasks)


def partial_zty(frag) -> np.ndarray:
    X, y = frag
    Z = _with_intercept(X)
    return Z.T @ y


def merge_add(a, b):
    return a + b


def compute_model_parameters(ztz: np.ndarray, zty: np.ndarray,
                             ridge: float = 0.0) -> np.ndarray:
    A = ztz
    if ridge > 0.0:
        A = A + ridge * np.eye(A.shape[0])
    return np.linalg.solve(A, zty)


def lr_genpred(seed: int, m: int, p: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, p))


def compute_prediction(X_pred: np.ndarray, beta: np.ndarray) -> np.ndarray:
    return _with_intercept(X_pred) @ beta


# -------------------------------------------------------------------- driver
@dataclass
class LinRegResult:
    beta: np.ndarray
    predictions: np.ndarray
    n_tasks: int


def run_linreg(
    n_rows: int = 20_000,
    p: int = 100,
    n_pred: int = 4_000,
    fragments: int = 4,
    pred_blocks: int = 2,
    ridge: float = 0.0,
    merge_arity: int = MERGE_ARITY,
    seed: int = 0,
) -> LinRegResult:
    """Sequential-style RCOMPSs program (requires a started runtime)."""
    fill_t = api.task(lr_fill_fragment, name="LR_fill_fragment")
    ztz_t = api.task(partial_ztz, name="partial_ztz")
    zty_t = api.task(partial_zty, name="partial_zty")
    merge_t = api.task(merge_add, name="merge")
    fit_t = api.task(compute_model_parameters, name="compute_model_parameters")
    genpred_t = api.task(lr_genpred, name="LR_genpred")
    pred_t = api.task(compute_prediction, name="compute_prediction")

    frag_n = [n_rows // fragments] * fragments
    frag_n[-1] += n_rows - sum(frag_n)
    # fragment fan-outs use batched submission (DESIGN.md §14)
    frags = api.map_tasks(fill_t, [(seed + i, frag_n[i], p)
                                   for i in range(fragments)])

    ztzs = api.map_tasks(ztz_t, [(f,) for f in frags])
    ztys = api.map_tasks(zty_t, [(f,) for f in frags])
    # runtime collective: balanced k-ary merge trees with locality-pinned
    # placement (DESIGN.md §16) instead of client-side pairwise folds
    ztz = collectives.tree_reduce(ztzs, merge_t, arity=merge_arity)
    zty = collectives.tree_reduce(ztys, merge_t, arity=merge_arity)
    beta = fit_t(ztz, zty, ridge)

    blk_m = [n_pred // pred_blocks] * pred_blocks
    blk_m[-1] += n_pred - sum(blk_m)
    Xps = api.map_tasks(genpred_t, [(50_000 + seed + b, blk_m[b], p)
                                    for b in range(pred_blocks)])
    preds = api.map_tasks(pred_t, [(Xp, beta) for Xp in Xps])
    beta_v = api.wait_on(beta)
    preds_v = api.wait_on(preds)
    n_merges = len(collectives.reduce_spec(fragments, arity=merge_arity))
    n_tasks = fragments * 3 + 2 * n_merges + 1 + 2 * pred_blocks
    return LinRegResult(beta_v, np.concatenate(preds_v), n_tasks)


# -------------------------------------------------------------------- oracle
def reference_linreg(n_rows, p, n_pred, fragments, pred_blocks, ridge=0.0, seed=0):
    frag_n = [n_rows // fragments] * fragments
    frag_n[-1] += n_rows - sum(frag_n)
    frags = [lr_fill_fragment(seed + i, frag_n[i], p) for i in range(fragments)]
    X = np.concatenate([f[0] for f in frags])
    y = np.concatenate([f[1] for f in frags])
    ztz = partial_ztz((X, y))
    zty = partial_zty((X, y))
    beta = compute_model_parameters(ztz, zty, ridge)
    blk_m = [n_pred // pred_blocks] * pred_blocks
    blk_m[-1] += n_pred - sum(blk_m)
    preds = [compute_prediction(lr_genpred(50_000 + seed + b, blk_m[b], p), beta)
             for b in range(pred_blocks)]
    return beta, np.concatenate(preds)


# --------------------------------------------------- simulator DAG generation
@dataclass
class LinRegCosts:
    fill: CostModel
    ztz: CostModel
    zty: CostModel
    merge: CostModel
    fit: CostModel
    genpred: CostModel
    predict: CostModel


def calibrate(p: int = 100, units=(1000, 4000, 8000)) -> LinRegCosts:
    def fill_u(u):
        return lambda: lr_fill_fragment(1, int(u), p)

    def ztz_u(u):
        f = lr_fill_fragment(2, int(u), p)
        return lambda: partial_ztz(f)

    def zty_u(u):
        f = lr_fill_fragment(3, int(u), p)
        return lambda: partial_zty(f)

    def merge_u(u):
        a = np.ones((p + 1, p + 1))
        return lambda: merge_add(a, a)

    def fit_u(u):
        f = lr_fill_fragment(4, max(int(u), p + 8), p)
        A, b = partial_ztz(f), partial_zty(f)
        return lambda: compute_model_parameters(A, b, 1e-6)

    def genpred_u(u):
        return lambda: lr_genpred(5, int(u), p)

    def pred_u(u):
        f = lr_fill_fragment(6, max(int(u), p + 8), p)
        A, b = partial_ztz(f), partial_zty(f)
        beta = compute_model_parameters(A, b, 1e-6)
        Xp = lr_genpred(7, int(u), p)
        return lambda: compute_prediction(Xp, beta)

    return LinRegCosts(
        fill=calibrate_cost(fill_u, units, "LR_fill_fragment"),
        ztz=calibrate_cost(ztz_u, units, "partial_ztz"),
        zty=calibrate_cost(zty_u, units, "partial_zty"),
        merge=calibrate_cost(merge_u, (1,), "merge"),
        fit=calibrate_cost(fit_u, (1,), "compute_model_parameters"),
        genpred=calibrate_cost(genpred_u, units, "LR_genpred"),
        predict=calibrate_cost(pred_u, units, "compute_prediction"),
    )


def dag_spec(
    costs: LinRegCosts,
    n_rows: int,
    p: int,
    n_pred: int,
    fragments: int,
    pred_blocks: int,
    merge_arity: int = MERGE_ARITY,
) -> List[SimTask]:
    tasks: List[SimTask] = []
    tid = 0
    rows = n_rows // fragments
    fbytes = rows * (p + 1) * 8
    gbytes = (p + 1) * (p + 1) * 8
    fill_ids = []
    for _ in range(fragments):
        tasks.append(SimTask(tid, "LR_fill_fragment", costs.fill(rows), (),
                             out_bytes=fbytes))
        fill_ids.append(tid)
        tid += 1

    def emit_tree(leaf_parent_ids: List[int], leaf_name: str, leaf_cost: float,
                  leaf_bytes: int) -> int:
        nonlocal tid
        leaf_ids = []
        for pid in leaf_parent_ids:
            tasks.append(SimTask(tid, leaf_name, leaf_cost, (pid,), out_bytes=leaf_bytes))
            leaf_ids.append(tid)
            tid += 1
        # same k-ary collective shape the live runtime builds (§16): one
        # SimTask per tree node folding k children, cost (k-1) pair-merges
        merges = collectives.reduce_spec(len(leaf_ids), arity=merge_arity)
        merge_ids: List[int] = []
        for _, children in merges:
            deps = tuple(
                leaf_ids[c] if c < len(leaf_ids) else merge_ids[c - len(leaf_ids)]
                for c in children)
            name = "merge" if len(deps) == 2 else f"mergex{len(deps)}"
            tasks.append(SimTask(tid, name, costs.merge(1) * (len(deps) - 1),
                                 deps, out_bytes=leaf_bytes))
            merge_ids.append(tid)
            tid += 1
        return merge_ids[-1] if merge_ids else leaf_ids[-1]

    ztz_root = emit_tree(fill_ids, "partial_ztz", costs.ztz(rows), gbytes)
    zty_root = emit_tree(fill_ids, "partial_zty", costs.zty(rows), (p + 1) * 8)
    tasks.append(SimTask(tid, "compute_model_parameters", costs.fit(1),
                         (ztz_root, zty_root), out_bytes=(p + 1) * 8))
    fit_id = tid
    tid += 1
    mrows = n_pred // pred_blocks
    for _ in range(pred_blocks):
        tasks.append(SimTask(tid, "LR_genpred", costs.genpred(mrows), (),
                             out_bytes=mrows * p * 8))
        gen_id = tid
        tid += 1
        tasks.append(SimTask(tid, "compute_prediction", costs.predict(mrows),
                             (gen_id, fit_id), out_bytes=mrows * 8))
        tid += 1
    return tasks
