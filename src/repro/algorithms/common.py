"""Shared helpers for the task-parallel algorithms."""
from __future__ import annotations

import time
from typing import Callable, List, Sequence, Tuple

import numpy as np

from ..core.simulator import CostModel


def tree_reduce(items: Sequence, merge_task: Callable, arity: int = 2):
    """Hierarchical reduction through ``merge_task`` calls — the paper's
    ``*_merge`` task trees (Figs. 3-5).  Works on Futures (submits merge
    tasks) or on plain values (if ``merge_task`` is a plain function).

    The reduction executes exactly the schedule :func:`tree_reduce_spec`
    emits, so the live DAG and the simulator's shape are isomorphic by
    construction: every arity group merges as a balanced sub-tree and the
    whole reduction has depth ⌈log_arity(n)⌉ groups deep."""
    items = list(items)
    if not items:
        raise ValueError("tree_reduce of empty sequence")
    if arity < 2:
        raise ValueError(f"tree_reduce arity must be >= 2, got {arity}")
    vals = list(items)
    for _, (a, b) in tree_reduce_spec(len(items), arity):
        vals.append(merge_task(vals[a], vals[b]))
    return vals[-1]


def tree_reduce_spec(n_leaves: int, arity: int = 2) -> List[Tuple[int, Tuple[int, ...]]]:
    """Shape-only version for DAG generation: returns merge nodes as
    (merge_index, (child_a, child_b)) where children < n_leaves are leaves and
    children >= n_leaves refer to merge node ``child - n_leaves``.

    Merges are emitted in dependency order: a merge only references leaves
    or merges that appear earlier in the list.  Each arity group reduces by
    repeated pairwise halving (a balanced binary sub-tree), never by a
    serial left fold, so the critical path through a group of g leaves is
    ⌈log2(g)⌉ merges rather than g-1."""
    if arity < 2:
        raise ValueError(f"tree_reduce arity must be >= 2, got {arity}")
    ids = list(range(n_leaves))
    merges: List[Tuple[int, Tuple[int, ...]]] = []
    next_id = n_leaves
    while len(ids) > 1:
        nxt = []
        for i in range(0, len(ids), arity):
            group = ids[i : i + arity]
            while len(group) > 1:
                paired = []
                for j in range(0, len(group) - 1, 2):
                    merges.append((next_id - n_leaves, (group[j], group[j + 1])))
                    paired.append(next_id)
                    next_id += 1
                if len(group) % 2:
                    paired.append(group[-1])
                group = paired
            nxt.append(group[0])
        ids = nxt
    return merges


def make_blobs(seed: int, n: int, d: int, n_classes: int, spread: float = 4.0):
    """Synthetic labelled clusters (the paper generates data on the fly in
    ``*_fill_fragment`` tasks rather than reading files)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, size=n)
    centers = rng.standard_normal((n_classes, d)) * spread
    X = centers[y] + rng.standard_normal((n, d))
    return X.astype(np.float64), y.astype(np.int64)


def timeit_median(fn: Callable, repeats: int = 3) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def calibrate_cost(fn_of_units: Callable[[int], Callable], units: Sequence[int],
                   name: str = "", repeats: int = 3) -> CostModel:
    """Measure ``fn_of_units(u)()`` for each u and fit an affine CostModel —
    the bridge between real execution and the scaling simulator."""
    samples = []
    for u in units:
        call = fn_of_units(u)
        samples.append((float(u), timeit_median(call, repeats=repeats)))
    return CostModel.fit(samples, name=name)
