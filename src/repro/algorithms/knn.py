"""Task-parallel K-Nearest-Neighbors classification (paper §4.1, Fig. 3).

DAG shape (faithful to the paper): ``KNN_fill_fragment`` tasks generate the
training fragments, ``KNN_frag`` tasks compute distances between a test
block and one training fragment and keep the local top-k, a tree of
``KNN_merge`` tasks combines the per-fragment candidate sets, and
``KNN_classify`` performs the majority vote.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from ..core import api, collectives
from ..core.simulator import CostModel, SimTask
from .common import calibrate_cost, make_blobs, tree_reduce_spec

# --------------------------------------------------------------------- tasks
def knn_fill_fragment(seed: int, n: int, d: int, n_classes: int):
    """Generate one labelled training fragment (paper generates on the fly)."""
    return make_blobs(seed, n, d, n_classes)


def knn_gen_test(seed: int, n: int, d: int, n_classes: int):
    X, _ = make_blobs(seed, n, d, n_classes)
    return X


def knn_frag(frag, test_X: np.ndarray, k: int):
    """Local k-NN of ``test_X`` against one training fragment.

    Returns (dists, labels): the k smallest distances per test point within
    this fragment, plus the labels of those neighbours.
    """
    train_X, train_y = frag
    # pairwise squared euclidean: |a|^2 - 2ab + |b|^2 (BLAS-friendly, the
    # paper's hot GEMM; the Pallas twin lives in kernels/knn_topk)
    d2 = (
        np.sum(test_X * test_X, axis=1)[:, None]
        - 2.0 * (test_X @ train_X.T)
        + np.sum(train_X * train_X, axis=1)[None, :]
    )
    kk = min(k, train_X.shape[0])
    idx = np.argpartition(d2, kk - 1, axis=1)[:, :kk]
    rows = np.arange(test_X.shape[0])[:, None]
    dists = d2[rows, idx]
    labels = train_y[idx]
    order = np.argsort(dists, axis=1, kind="stable")
    return dists[rows, order], labels[rows, order]


def knn_merge(a, b):
    """Merge two candidate sets, keeping the k best (k = width of inputs)."""
    da, la = a
    db, lb = b
    k = max(da.shape[1], db.shape[1])
    d = np.concatenate([da, db], axis=1)
    lab = np.concatenate([la, lb], axis=1)
    kk = min(k, d.shape[1])
    idx = np.argpartition(d, kk - 1, axis=1)[:, :kk]
    rows = np.arange(d.shape[0])[:, None]
    dd, ll = d[rows, idx], lab[rows, idx]
    order = np.argsort(dd, axis=1, kind="stable")
    return dd[rows, order], ll[rows, order]


def knn_classify(merged, n_classes: int):
    """Majority vote over the merged k nearest labels (ties -> smallest id)."""
    _, labels = merged
    counts = np.apply_along_axis(np.bincount, 1, labels, minlength=n_classes)
    return np.argmax(counts, axis=1)


# -------------------------------------------------------------------- driver
@dataclass
class KNNResult:
    predictions: np.ndarray
    n_tasks: int


def run_knn(
    n_train: int = 2000,
    n_test: int = 2000,
    d: int = 50,
    k: int = 5,
    n_classes: int = 4,
    train_fragments: int = 4,
    test_blocks: int = 1,
    merge_arity: int = 2,
    seed: int = 0,
) -> KNNResult:
    """Sequential-style RCOMPSs program (requires a started runtime)."""
    fill_t = api.task(knn_fill_fragment, name="KNN_fill_fragment")
    gen_test_t = api.task(knn_gen_test, name="KNN_gen_test")
    frag_t = api.task(knn_frag, name="KNN_frag")
    merge_t = api.task(knn_merge, name="KNN_merge")
    classify_t = api.task(knn_classify, name="KNN_classify")

    frag_n = [n_train // train_fragments] * train_fragments
    frag_n[-1] += n_train - sum(frag_n)
    # fragment fan-outs use batched submission (DESIGN.md §14)
    frags = api.map_tasks(fill_t, [(seed + i, frag_n[i], d, n_classes)
                                   for i in range(train_fragments)])

    blk_n = [n_test // test_blocks] * test_blocks
    blk_n[-1] += n_test - sum(blk_n)
    preds = []
    n_tasks = train_fragments
    for b in range(test_blocks):
        test_b = gen_test_t(10_000 + seed + b, blk_n[b], d, n_classes)
        locals_ = api.map_tasks(frag_t, [(f, test_b, k) for f in frags])
        merged = collectives.tree_reduce(locals_, merge_t, arity=merge_arity)
        preds.append(classify_t(merged, n_classes))
        n_merges = len(collectives.reduce_spec(train_fragments, arity=merge_arity))
        n_tasks += 1 + train_fragments + n_merges + 1
    out = api.wait_on(preds)
    return KNNResult(np.concatenate(out), n_tasks)


# -------------------------------------------------------------------- oracle
def reference_knn(n_train, n_test, d, k, n_classes, train_fragments, test_blocks,
                  seed=0, merge_arity: int = 2):
    """Single-shot numpy oracle computing the same result as ``run_knn``
    (same fragment seeds => identical data => identical predictions)."""
    frag_n = [n_train // train_fragments] * train_fragments
    frag_n[-1] += n_train - sum(frag_n)
    frags = [knn_fill_fragment(seed + i, frag_n[i], d, n_classes)
             for i in range(train_fragments)]
    X = np.concatenate([f[0] for f in frags])
    y = np.concatenate([f[1] for f in frags])

    blk_n = [n_test // test_blocks] * test_blocks
    blk_n[-1] += n_test - sum(blk_n)
    preds = []
    for b in range(test_blocks):
        test_b = knn_gen_test(10_000 + seed + b, blk_n[b], d, n_classes)
        local = knn_frag((X, y), test_b, k)
        preds.append(knn_classify(local, n_classes))
    return np.concatenate(preds)


# --------------------------------------------------- simulator DAG generation
@dataclass
class KNNCosts:
    fill: CostModel
    frag: CostModel
    merge: CostModel
    classify: CostModel


def calibrate(d: int = 50, k: int = 5, n_classes: int = 4,
              units=(500, 1000, 2000), n_train_frag: int = 1000) -> KNNCosts:
    """Fit per-task cost models by timing the real task functions."""
    frag = knn_fill_fragment(0, n_train_frag, d, n_classes)

    def fill_u(u):
        return lambda: knn_fill_fragment(1, int(u), d, n_classes)

    def frag_u(u):
        test = knn_gen_test(2, int(u), d, n_classes)
        return lambda: knn_frag(frag, test, k)

    def merge_u(u):
        test = knn_gen_test(3, int(u), d, n_classes)
        a = knn_frag(frag, test, k)
        return lambda: knn_merge(a, a)

    def classify_u(u):
        test = knn_gen_test(4, int(u), d, n_classes)
        a = knn_frag(frag, test, k)
        return lambda: knn_classify(a, n_classes)

    return KNNCosts(
        fill=calibrate_cost(fill_u, units, "KNN_fill_fragment"),
        frag=calibrate_cost(frag_u, units, "KNN_frag"),
        merge=calibrate_cost(merge_u, units, "KNN_merge"),
        classify=calibrate_cost(classify_u, units, "KNN_classify"),
    )


def dag_spec(
    costs: KNNCosts,
    n_train: int,
    n_test: int,
    d: int,
    k: int,
    train_fragments: int,
    test_blocks: int,
    merge_arity: int = 2,
    calib_frag_rows: int = 1000,
) -> List[SimTask]:
    """Build the KNN DAG as SimTasks with calibrated durations.

    ``KNN_frag`` cost scales with (test rows × train-fragment rows) — the
    distance GEMM — normalized to the ``calib_frag_rows`` used during
    calibration; ``merge``/``classify`` scale with test-block rows; ``fill``
    with fragment rows.
    """
    tasks: List[SimTask] = []
    tid = 0
    frag_rows = n_train // train_fragments
    blk_rows = n_test // test_blocks
    frag_units = blk_rows * frag_rows / max(calib_frag_rows, 1)
    fbytes = frag_rows * d * 8
    fill_ids = []
    for _ in range(train_fragments):
        tasks.append(SimTask(tid, "KNN_fill_fragment", costs.fill(frag_rows), (),
                             out_bytes=fbytes))
        fill_ids.append(tid)
        tid += 1
    for _ in range(test_blocks):
        gen_id = tid
        tasks.append(SimTask(tid, "KNN_gen_test", costs.fill(blk_rows), (),
                             out_bytes=blk_rows * d * 8))
        tid += 1
        frag_ids = []
        for f in fill_ids:
            tasks.append(SimTask(tid, "KNN_frag", costs.frag(frag_units), (f, gen_id),
                                 out_bytes=blk_rows * k * 16))
            frag_ids.append(tid)
            tid += 1
        merges = tree_reduce_spec(len(frag_ids), arity=merge_arity)
        merge_ids = []
        for _, (a, b) in merges:
            da = frag_ids[a] if a < len(frag_ids) else merge_ids[a - len(frag_ids)]
            db = frag_ids[b] if b < len(frag_ids) else merge_ids[b - len(frag_ids)]
            tasks.append(SimTask(tid, "KNN_merge", costs.merge(blk_rows), (da, db),
                                 out_bytes=blk_rows * k * 16))
            merge_ids.append(tid)
            tid += 1
        last = merge_ids[-1] if merge_ids else frag_ids[-1]
        tasks.append(SimTask(tid, "KNN_classify", costs.classify(blk_rows), (last,),
                             out_bytes=blk_rows * 8))
        tid += 1
    return tasks
