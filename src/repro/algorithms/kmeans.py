"""Task-parallel K-means clustering (paper §4.2, Fig. 4).

Per iteration: ``partial_sum`` tasks assign each fragment's points to the
nearest centroid and emit (per-cluster sums, counts); a hierarchical
``merge`` tree combines them; ``update_centroids`` produces the new
centroids; the master checks convergence (the paper's ``converged``
function) — one synchronization per iteration, exactly as in Fig. 4.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from ..core import api, collectives
from ..core.simulator import CostModel, SimTask
from .common import calibrate_cost, tree_reduce_spec

# --------------------------------------------------------------------- tasks
def fill_fragment(seed: int, n: int, d: int, n_centers: int = 8, spread: float = 5.0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_centers, d)) * spread
    which = rng.integers(0, n_centers, size=n)
    return (centers[which] + rng.standard_normal((n, d))).astype(np.float64)


def partial_sum(X: np.ndarray, centroids: np.ndarray):
    """Assign points to nearest centroid; return (sums, counts, sse)."""
    d2 = (
        np.sum(X * X, axis=1)[:, None]
        - 2.0 * (X @ centroids.T)
        + np.sum(centroids * centroids, axis=1)[None, :]
    )
    assign = np.argmin(d2, axis=1)
    k = centroids.shape[0]
    counts = np.bincount(assign, minlength=k).astype(np.int64)
    sums = np.zeros_like(centroids)
    np.add.at(sums, assign, X)
    sse = float(np.sum(d2[np.arange(X.shape[0]), assign]))
    return sums, counts, sse


def merge(a, b):
    return a[0] + b[0], a[1] + b[1], a[2] + b[2]


def update_centroids(acc, old_centroids: np.ndarray):
    sums, counts, sse = acc
    safe = np.maximum(counts, 1)[:, None]
    new = sums / safe
    empty = counts == 0
    new[empty] = old_centroids[empty]  # keep empty clusters in place
    shift = float(np.max(np.linalg.norm(new - old_centroids, axis=1)))
    return new, shift, sse


# -------------------------------------------------------------------- driver
@dataclass
class KMeansResult:
    centroids: np.ndarray
    iterations: int
    sse: float
    shifts: List[float]


def run_kmeans(
    n_points: int = 20_000,
    d: int = 10,
    k: int = 8,
    fragments: int = 4,
    max_iters: int = 10,
    tol: float = 1e-4,
    merge_arity: int = 2,
    seed: int = 0,
) -> KMeansResult:
    """Sequential-style RCOMPSs program (requires a started runtime)."""
    fill_t = api.task(fill_fragment, name="fill_fragment")
    psum_t = api.task(partial_sum, name="partial_sum")
    merge_t = api.task(merge, name="merge")
    upd_t = api.task(update_centroids, name="update_centroids")

    frag_n = [n_points // fragments] * fragments
    frag_n[-1] += n_points - sum(frag_n)
    # fan-out loops go through map_tasks: one batched submission instead
    # of per-task graph/inflight locking (DESIGN.md §14)
    frags = api.map_tasks(fill_t, [(seed + i, frag_n[i], d)
                                   for i in range(fragments)])

    rng = np.random.default_rng(seed)
    centroids = rng.standard_normal((k, d)) * 5.0
    shifts: List[float] = []
    sse = float("inf")
    it = 0
    for it in range(1, max_iters + 1):
        partials = api.map_tasks(psum_t, [(f, centroids) for f in frags])
        acc = collectives.tree_reduce(partials, merge_t, arity=merge_arity)
        res = upd_t(acc, centroids)
        centroids, shift, sse = api.wait_on(res)  # per-iteration sync (Fig. 4)
        shifts.append(shift)
        if shift < tol:  # the paper's `converged` check
            break
    return KMeansResult(centroids, it, sse, shifts)


# -------------------------------------------------------------------- oracle
def reference_kmeans(n_points, d, k, fragments, max_iters, tol, seed=0):
    """Single-shot numpy oracle: same fragments, same centroid init, same
    update rule — must match ``run_kmeans`` bit-for-bit (modulo fp reduction
    order across the merge tree; tests use modest tolerance)."""
    frag_n = [n_points // fragments] * fragments
    frag_n[-1] += n_points - sum(frag_n)
    X = np.concatenate([fill_fragment(seed + i, frag_n[i], d) for i in range(fragments)])
    rng = np.random.default_rng(seed)
    centroids = rng.standard_normal((k, d)) * 5.0
    it = 0
    sse = float("inf")
    for it in range(1, max_iters + 1):
        acc = partial_sum(X, centroids)
        centroids, shift, sse = update_centroids(acc, centroids)
        if shift < tol:
            break
    return centroids, it, sse


# --------------------------------------------------- simulator DAG generation
@dataclass
class KMeansCosts:
    fill: CostModel
    psum: CostModel
    merge: CostModel
    update: CostModel


def calibrate(d: int = 50, k: int = 8, units=(2000, 8000, 16000)) -> KMeansCosts:
    rng = np.random.default_rng(0)
    cents = rng.standard_normal((k, d))

    def fill_u(u):
        return lambda: fill_fragment(1, int(u), d)

    def psum_u(u):
        X = fill_fragment(2, int(u), d)
        return lambda: partial_sum(X, cents)

    def merge_u(u):
        X = fill_fragment(3, max(int(u) // 8, 64), d)
        a = partial_sum(X, cents)
        return lambda: merge(a, a)

    def update_u(u):
        X = fill_fragment(4, max(int(u) // 8, 64), d)
        a = partial_sum(X, cents)
        return lambda: update_centroids(a, cents)

    return KMeansCosts(
        fill=calibrate_cost(fill_u, units, "fill_fragment"),
        psum=calibrate_cost(psum_u, units, "partial_sum"),
        merge=calibrate_cost(merge_u, units, "merge"),
        update=calibrate_cost(update_u, units, "update_centroids"),
    )


def dag_spec(
    costs: KMeansCosts,
    n_points: int,
    d: int,
    k: int,
    fragments: int,
    iterations: int,
    merge_arity: int = 2,
) -> List[SimTask]:
    tasks: List[SimTask] = []
    tid = 0
    rows = n_points // fragments
    fbytes = rows * d * 8
    cbytes = k * d * 8 + k * 8
    fill_ids = []
    for _ in range(fragments):
        tasks.append(SimTask(tid, "fill_fragment", costs.fill(rows), (), out_bytes=fbytes))
        fill_ids.append(tid)
        tid += 1
    prev_update = None
    for _ in range(iterations):
        psum_ids = []
        for f in fill_ids:
            deps = (f,) if prev_update is None else (f, prev_update)
            tasks.append(SimTask(tid, "partial_sum", costs.psum(rows), deps,
                                 out_bytes=cbytes))
            psum_ids.append(tid)
            tid += 1
        merges = tree_reduce_spec(len(psum_ids), arity=merge_arity)
        merge_ids = []
        for _, (a, b) in merges:
            da = psum_ids[a] if a < len(psum_ids) else merge_ids[a - len(psum_ids)]
            db = psum_ids[b] if b < len(psum_ids) else merge_ids[b - len(psum_ids)]
            tasks.append(SimTask(tid, "merge", costs.merge(rows), (da, db),
                                 out_bytes=cbytes))
            merge_ids.append(tid)
            tid += 1
        last = merge_ids[-1] if merge_ids else psum_ids[-1]
        tasks.append(SimTask(tid, "update_centroids", costs.update(rows), (last,),
                             out_bytes=cbytes))
        prev_update = tid
        tid += 1
    return tasks
