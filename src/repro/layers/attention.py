"""Grouped-query attention with RoPE, optional qk-norm, sliding window, and
KV caches (full or ring-buffer), plus two compute paths:

* ``dense``   — materialized scores; fine for short sequences.
* ``chunked`` — flash-style streaming softmax over KV chunks via
  ``lax.scan`` (O(S·chunk) memory).  This is the pure-JAX twin of the Pallas
  ``flash_attention`` kernel (kernels/flash_attention.py); the CPU dry-run
  lowers this path, on-TPU runs select the Pallas kernel.

Cache layout: ``{"k": (B, Sc, K, hd), "v": ..., "pos_map": (Sc,) int32}``.
``pos_map[slot]`` holds the absolute position stored in that slot
(``INVALID_POS`` when empty).  A full cache uses ``slot == position``; a ring
cache (sliding-window attention, ``Sc == window``) uses
``slot == position % Sc`` — this is what keeps RecurrentGemma's 500k-token
decode at O(window) memory.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .norms import rmsnorm
from .rope import apply_rope, rope_angles

NEG_INF = -1e30
INVALID_POS = 1 << 30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qk_norm: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model))
               * (1.0 / math.sqrt(n_heads * head_dim))).astype(dtype),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((head_dim,), dtype=dtype)}
        p["k_norm"] = {"scale": jnp.ones((head_dim,), dtype=dtype)}
    return p


def attention_axes(qk_norm: bool = False):
    ax = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if qk_norm:
        ax["q_norm"] = {"scale": ("head_dim",)}
        ax["k_norm"] = {"scale": ("head_dim",)}
    return ax


def _mask(q_pos, kv_pos, window: Optional[int]):
    """(Sq, Skv) boolean validity: causal + optional sliding window.
    Invalid cache slots carry ``INVALID_POS`` and fail the causal test."""
    m = kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - kv_pos[None, :]) < window
    return m


def _dense_attn(q, k, v, q_pos, kv_pos, window):
    """q: (B,Sq,K,G,hd); k,v: (B,Skv,K,hd) -> (B,Sq,K,G,hd) fp32."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = _mask(q_pos, kv_pos, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o


def _chunked_attn(q, k, v, q_pos, kv_pos, window, chunk: int = 1024,
                  unroll: bool = False, scores_dtype=jnp.float32):
    """Streaming (online-softmax) attention over KV chunks.

    ``scores_dtype=bfloat16`` stores the (B,K,G,Sq,chunk) score/probability
    tensors in bf16 (running max/denominator stay fp32) — the flash-kernel
    convention; halves the dominant HBM traffic of the jnp twin."""
    B, Sq, K, G, hd = q.shape
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=INVALID_POS)
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, chunk)
    scale = 1.0 / math.sqrt(hd)
    sd = scores_dtype
    qf = q.astype(sd)

    def step(carry, inp):
        m, lse, acc = carry
        k_i, v_i, pos_i = inp
        s = (jnp.einsum("bqkgh,bskh->bkgqs", qf, k_i.astype(sd)) * scale
             ).astype(sd)
        msk = _mask(q_pos, pos_i, window)
        s = jnp.where(msk[None, None, None], s, jnp.asarray(NEG_INF, sd))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]).astype(sd)
        l_new = lse * alpha + jnp.sum(p.astype(jnp.float32), axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, v_i.astype(sd)).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), dtype=jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hd), dtype=jnp.float32)
    (m, lse, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc),
                                  unroll=n_chunks if unroll else 1)
    o = acc / jnp.maximum(lse, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4)  # (B,Sq,K,G,hd)


def init_kv_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype=dtype),
        "pos_map": jnp.full((cache_len,), INVALID_POS, dtype=jnp.int32),
    }


def _build_cache(k, v, positions, cache_len: int, dtype):
    """Construct a cache from freshly computed prefill K/V (no scatter:
    deterministic gather of the slot-owning positions)."""
    B, S, K, hd = k.shape
    if cache_len >= S:
        pad = cache_len - S
        ck = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_map = jnp.concatenate([
            positions.astype(jnp.int32),
            jnp.full((pad,), INVALID_POS, dtype=jnp.int32),
        ])
        return {"k": ck, "v": cv, "pos_map": pos_map}
    # ring: slot s holds the latest position p < S with p % cache_len == s
    slots = jnp.arange(cache_len, dtype=jnp.int32)
    owner = (S - 1) - ((S - 1 - slots) % cache_len)  # index into current block
    ck = jnp.take(k, owner, axis=1).astype(dtype)
    cv = jnp.take(v, owner, axis=1).astype(dtype)
    pos_map = jnp.take(positions, owner).astype(jnp.int32)
    return {"k": ck, "v": cv, "pos_map": pos_map}


def attn_forward(
    params,
    x,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10_000.0,
    qk_norm: bool = False,
    window: Optional[int] = None,
    pos_offset=0,
    cache: Optional[dict] = None,
    make_cache_len: Optional[int] = None,
    cache_dtype=jnp.bfloat16,
    impl: str = "auto",
    chunk: int = 1024,
    unroll: bool = False,
    scores_dtype=jnp.float32,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Returns (output, new_cache).

    * training: ``cache=None, make_cache_len=None`` — block-local attention.
    * prefill:  ``make_cache_len=Sc`` — same attention, plus a cache built
      from the computed K/V (ring-truncated if ``Sc < S``).
    * decode:   ``cache=...`` — new K/V written at
      ``slot = position % Sc``; attention over the whole cache.
    """
    B, S, D = x.shape
    K, G = n_kv_heads, n_heads // n_kv_heads
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(B, S, K, head_dim)
    v = (x @ params["wv"]).reshape(B, S, K, head_dim)
    if qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    positions = pos_offset + jnp.arange(S, dtype=jnp.int32)
    cos, sin = rope_angles(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        Sc = cache["k"].shape[1]
        slots = positions % Sc
        ck = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
        pos_map = cache["pos_map"].at[slots].set(positions)
        new_cache = {"k": ck, "v": cv, "pos_map": pos_map}
        k_all, v_all, kv_pos = ck, cv, pos_map
    else:
        k_all, v_all, kv_pos = k, v, positions
        if make_cache_len is not None:
            new_cache = _build_cache(k, v, positions, make_cache_len, cache_dtype)

    qg = q.reshape(B, S, K, G, head_dim)
    use_chunked = impl == "chunked" or (impl == "auto" and k_all.shape[1] > 2048)
    if use_chunked:
        o = _chunked_attn(qg, k_all, v_all, positions, kv_pos, window,
                          chunk=chunk, unroll=unroll, scores_dtype=scores_dtype)
    else:
        o = _dense_attn(qg, k_all, v_all, positions, kv_pos, window)
    o = o.astype(x.dtype).reshape(B, S, n_heads * head_dim)
    return o @ params["wo"], new_cache
