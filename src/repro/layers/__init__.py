"""Neural-network layers for the assigned architectures (pure JAX, functional).

Every layer module exposes ``init_*`` (returns a dict-of-arrays param tree)
and a matching ``*_axes`` (same tree structure, leaves are tuples of logical
axis names used by ``repro.distributed.sharding`` to derive PartitionSpecs).
"""
from . import attention, mlp, moe, norms, rglru, rope, ssd  # noqa: F401
