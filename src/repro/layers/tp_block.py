"""Explicit tensor-parallel transformer sub-blocks via shard_map.

§Perf hillclimb lever (EXPERIMENTS.md): the GSPMD baseline mis-shards the
5-D GQA score tensors (XLA's SPMD partitioner logs "involuntary full
rematerialization" and replicates them over the ``model`` axis).  This
module pins the Megatron-style layout explicitly:

* q/o projections column/row-sharded over heads (``model`` axis),
* for MQA/small-K archs the K/V projections are *replicated* (K·hd is tiny;
  recomputing K/V per shard costs nothing and removes all resharding),
* for K % tp == 0 the K/V heads shard alongside the q-head groups,
* one ``psum`` per sub-layer (attention out-proj, MLP down-proj) — exactly
  Megatron's two all-reduces per block, nothing else.

Weights arrive FSDP-sharded over ``data`` on the d_model dim; the shard_map
boundary's resharding is the standard per-layer FSDP all-gather.

Training path only (no KV cache) — prefill/decode stay on the GSPMD path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .attention import _chunked_attn, _dense_attn
from .norms import rmsnorm
from .rope import apply_rope, rope_angles


def _attn_param_specs(qk_norm: bool, shard_kv: bool):
    kv = P(None, "model") if shard_kv else P(None, None)
    sp = {
        "wq": P(None, "model"),
        "wk": kv,
        "wv": kv,
        "wo": P("model", None),
    }
    if qk_norm:
        sp["q_norm"] = {"scale": P(None)}
        sp["k_norm"] = {"scale": P(None)}
    return sp


def _mlp_param_specs(gated: bool):
    sp = {
        "w_up": P(None, "model"),
        "w_down": P("model", None),
    }
    if gated:
        sp["w_gate"] = P(None, "model")
    return sp


def tp_attn_sublayer(p_ln, p_attn, x, *, cfg, mesh, window: Optional[int],
                     pos_offset, data_axes: Tuple[str, ...]):
    """x + Wo·Attn(norm(x)) with explicit TP.  x: (B, S, D) sharded over
    data axes, replicated over model."""
    tp = mesh.shape["model"]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    shard_kv = K % tp == 0 and K >= tp
    H_l = H // tp
    K_l = K // tp if shard_kv else K
    sd = jnp.bfloat16 if cfg.attn_scores_bf16 else jnp.float32

    def local(x_l, ln_s, pa):
        B, S, D = x_l.shape
        h = rmsnorm(ln_s, x_l)
        q = (h @ pa["wq"]).reshape(B, S, H_l, hd)
        k = (h @ pa["wk"]).reshape(B, S, K_l, hd)
        v = (h @ pa["wv"]).reshape(B, S, K_l, hd)
        if cfg.qk_norm:
            q = rmsnorm(pa["q_norm"], q)
            k = rmsnorm(pa["k_norm"], k)
        positions = pos_offset + jnp.arange(S, dtype=jnp.int32)
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if shard_kv:
            # kv heads shard alongside their q-head groups (layouts align)
            qg = q.reshape(B, S, K_l, H_l // K_l, hd)
        else:
            # replicated K/V: local q heads are a contiguous *global* slice;
            # gather each one's kv group (global_head // G)
            G = H // K
            gidx = (jax.lax.axis_index("model") * H_l
                    + jnp.arange(H_l)) // G
            k = jnp.take(k, gidx, axis=2)
            v = jnp.take(v, gidx, axis=2)
            qg = q.reshape(B, S, H_l, 1, hd)
        if S > 2048 or cfg.attn_impl == "chunked":
            o = _chunked_attn(qg, k, v, positions, positions, window,
                              chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
                              scores_dtype=sd)
        else:
            o = _dense_attn(qg, k, v, positions, positions, window)
        o = o.astype(x_l.dtype).reshape(B, S, H_l * hd)
        out = o @ pa["wo"]                       # partial over model
        out = jax.lax.psum(out, "model")
        return x_l + out

    xspec = P(data_axes, None, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(xspec, {"scale": P(None)},
                  _attn_param_specs(cfg.qk_norm, shard_kv)),
        out_specs=xspec, check_rep=False,
    )(x, p_ln, p_attn)


def tp_rglru_sublayer(p_ln, p_rec, x, *, cfg, mesh,
                      data_axes: Tuple[str, ...]):
    """x + RG-LRU-block(norm(x)) with explicit TP: the rnn width R is
    column-sharded; every recurrence/gate op is elementwise over R, so the
    only communication is the out-projection psum — one all-reduce per
    block, vs. the GSPMD baseline's per-op reshards of (B,S,R) tensors."""
    from .rglru import _causal_conv, rglru_scan

    def local(x_l, ln_s, pr):
        h = rmsnorm(ln_s, x_l)
        u = h @ pr["w_x"]                       # (B, S, R_l)
        gate = jax.nn.gelu(h @ pr["w_gate"])
        u = _causal_conv(u, pr["conv_w"], pr["conv_b"])
        y, _ = rglru_scan(pr, u)                # per-channel: fully local
        out = (y * gate) @ pr["w_out"]          # partial over model
        out = jax.lax.psum(out, "model")
        return x_l + out

    rspec = {
        "w_x": P(None, "model"), "w_gate": P(None, "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "lam": P("model"), "w_r": P("model"), "b_r": P("model"),
        "w_i": P("model"), "b_i": P("model"),
        "w_out": P("model", None),
    }
    xspec = P(data_axes, None, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(xspec, {"scale": P(None)}, rspec),
        out_specs=xspec, check_rep=False,
    )(x, p_ln, p_rec)


def tp_mlp_sublayer(p_ln, p_mlp, x, *, cfg, mesh,
                    data_axes: Tuple[str, ...]):
    """x + W2·act(W1·norm(x)) with explicit TP."""
    gated = "w_gate" in p_mlp

    def local(x_l, ln_s, pm):
        h = rmsnorm(ln_s, x_l)
        if gated:
            a = jax.nn.silu(h @ pm["w_gate"]) * (h @ pm["w_up"])
        else:
            a = jax.nn.gelu(h @ pm["w_up"])
        out = a @ pm["w_down"]                   # partial over model
        out = jax.lax.psum(out, "model")
        return x_l + out

    xspec = P(data_axes, None, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(xspec, {"scale": P(None)}, _mlp_param_specs(gated)),
        out_specs=xspec, check_rep=False,
    )(x, p_ln, p_mlp)
