"""RMSNorm (fused Pallas twin in kernels/rmsnorm.py)."""
from __future__ import annotations

import jax.numpy as jnp


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_axes():
    return {"scale": ("embed",)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (1.0 / jnp.sqrt(var + eps))
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_plain(x, eps: float = 1e-6):
    """Scale-free RMS normalization (qk-norm without learned gain)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * (1.0 / jnp.sqrt(var + eps))).astype(x.dtype)
