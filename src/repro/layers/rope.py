"""Rotary positional embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions, head_dim: int, theta: float = 10_000.0):
    """positions: (...,) int32 -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos_b = cos[None, :, None, :]
        sin_b = sin[None, :, None, :]
    else:  # (B, S, half)
        cos_b = cos[:, :, None, :]
        sin_b = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos_b - x2f * sin_b, x2f * cos_b + x1f * sin_b], axis=-1)
    return out.astype(x.dtype)
