"""MLP: gated (SwiGLU) or classic two-matrix GELU."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32, gated: bool = True):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": (jax.random.normal(ks[1], (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (d_ff, d_model)) * s_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp_axes(gated: bool = True):
    ax = {
        "w_up": ("embed", "mlp"),
        "w_down": ("mlp", "embed"),
    }
    if gated:
        ax["w_gate"] = ("embed", "mlp")
    return ax


def mlp_forward(params, x):
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]
