"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Temporal-mixing block: ``x -> [W_x -> causal conv -> RG-LRU]`` gated by a
GeLU branch, then an output projection.  The RG-LRU recurrence

    r_t = sigmoid(w_r ⊙ u_t + b_r)          (recurrence gate, per-channel)
    i_t = sigmoid(w_i ⊙ u_t + b_i)          (input gate, per-channel)
    log a_t = -c * softplus(Λ) * r_t        (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

is evaluated with ``jax.lax.associative_scan`` in prefill/training (the
pure-JAX twin of the Pallas ``rglru_scan`` kernel) and as a single step in
decode.  Gates are per-channel (diagonal) — a documented simplification of
Griffin's block-diagonal gate matrices that keeps every op elementwise and
therefore cleanly tensor-parallel (DESIGN.md §10).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_C = 8.0


def init_rglru(key, d_model: int, rnn_width: int, *, conv_width: int = 4,
               dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    R = rnn_width
    return {
        "w_x": (jax.random.normal(ks[0], (d_model, R)) * s).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (d_model, R)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (conv_width, R)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((R,), dtype=dtype),
        "lam": jnp.full((R,), 2.0, dtype=jnp.float32),   # Λ: a ≈ 0.98^c at init
        "w_r": (jax.random.normal(ks[3], (R,)) * 0.5).astype(jnp.float32),
        "b_r": jnp.zeros((R,), dtype=jnp.float32),
        "w_i": (jax.random.normal(ks[4], (R,)) * 0.5).astype(jnp.float32),
        "b_i": jnp.ones((R,), dtype=jnp.float32),
        "w_out": (jax.random.normal(jax.random.fold_in(key, 9), (R, d_model))
                  * (1.0 / math.sqrt(R))).astype(dtype),
    }


def rglru_axes():
    return {
        "w_x": ("embed", "rnn"),
        "w_gate": ("embed", "rnn"),
        "conv_w": (None, "rnn"),
        "conv_b": ("rnn",),
        "lam": ("rnn",),
        "w_r": ("rnn",),
        "b_r": ("rnn",),
        "w_i": ("rnn",),
        "b_i": ("rnn",),
        "w_out": ("rnn", "embed"),
    }


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * params["w_r"] + params["b_r"])
    i = jax.nn.sigmoid(uf * params["w_i"] + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1: 1 - exp(2 log a)
    beta = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    b = beta * (i * uf)
    return a, b


def rglru_scan(params, u, h0: Optional[jnp.ndarray] = None):
    """Associative-scan evaluation. u: (B, S, R) -> (y (B,S,R), h_S (B,R))."""
    a, b = _gates(params, u)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h0 + b_1
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1, :]


def rglru_step(params, u_t, h_prev):
    """Single decode step. u_t: (B, R); h_prev: (B, R) fp32."""
    a, b = _gates(params, u_t[:, None, :])
    h = a[:, 0] * h_prev + b[:, 0]
    return h.astype(u_t.dtype), h


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def rglru_forward(params, x, *, cache: Optional[dict] = None,
                  make_cache: bool = False) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full temporal-mixing block. x: (B, S, D).

    cache = {"conv": (B, K-1, R), "h": (B, R) fp32} for decode (S == 1);
    ``make_cache=True`` builds it from a prefill pass.
    """
    u = x @ params["w_x"]
    gate = jax.nn.gelu(x @ params["w_gate"])
    K = params["conv_w"].shape[0]
    if cache is None:
        u_raw = u
        u = _causal_conv(u, params["conv_w"], params["conv_b"])
        y, h_last = rglru_scan(params, u)
        new_cache = None
        if make_cache:
            S = u_raw.shape[1]
            hist = u_raw[:, -(K - 1):, :]
            if S < K - 1:
                hist = jnp.pad(hist, ((0, 0), (K - 1 - S, 0), (0, 0)))
            new_cache = {"conv": hist, "h": h_last}
    else:
        hist = jnp.concatenate([cache["conv"], u], axis=1)
        S = u.shape[1]
        u = sum(hist[:, i: i + S, :] * params["conv_w"][i] for i in range(K))
        u = u + params["conv_b"]
        y_t, h = rglru_step(params, u[:, 0, :], cache["h"])
        y = y_t[:, None, :]
        new_cache = {"conv": hist[:, -(K - 1):, :], "h": h}
    return (y * gate) @ params["w_out"], new_cache


def init_rglru_cache(batch: int, rnn_width: int, *, conv_width: int = 4,
                     dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, conv_width - 1, rnn_width), dtype=dtype),
        "h": jnp.zeros((batch, rnn_width), dtype=jnp.float32),
    }


def rglru_reference(params, u, h0: Optional[jnp.ndarray] = None):
    """Per-step loop oracle for tests."""
    B, S, R = u.shape
    a, b = _gates(params, u)
    h = jnp.zeros((B, R), dtype=jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        ys.append(h)
    return jnp.stack(ys, axis=1).astype(u.dtype), h
