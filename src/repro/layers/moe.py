"""Mixture-of-Experts layer with TPU-native expert parallelism.

Design (DESIGN.md §6): activations enter a block replicated over the
``model`` mesh axis and sharded over the data axes.  The routed-expert
computation runs inside ``shard_map``:

* expert weights are sharded **experts over `model`** (EP) and
  **d_ff over `data`** (FSDP storage); the local function all-gathers the
  d_ff shards (one layer at a time — the same per-layer gather FSDP pays),
* each device routes its local tokens, keeps the assignments that fall into
  its expert slice, and packs them into a static ``(E_local, C, D)`` buffer
  via an argsort over expert ids (sort-based capacity dispatch — no GShard
  one-hot blow-up),
* expert FFNs run as dense einsums over the packed buffer (MXU-friendly),
* results scatter back to token order and a ``psum`` over ``model`` combines
  the contributions of experts living on other shards (each token's top-k
  experts are spread across the EP shards).

Tokens overflowing an expert's capacity ``C = ceil(N·k·cf / E)`` are dropped
(pass through the residual only) — standard capacity-based semantics.

``moe_apply_local`` is the same algorithm without collectives (model-axis
size 1); it doubles as the test oracle target and the single-device path.
``moe_reference`` is the exact dense loop used to validate the dispatch.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def init_moe(key, d_model: int, d_ff_expert: int, n_experts: int,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff_expert)
    return {
        "w_router": (jax.random.normal(ks[0], (d_model, n_experts)) * s_in
                     ).astype(jnp.float32),  # router kept fp32 (standard)
        "w_gate": (jax.random.normal(ks[1], (n_experts, d_model, d_ff_expert)) * s_in
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d_model, d_ff_expert)) * s_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, d_ff_expert, d_model)) * s_out
                   ).astype(dtype),
    }


def moe_axes():
    return {
        "w_router": ("embed", "expert_router"),
        "w_gate": ("expert", "embed", "expert_mlp"),
        "w_up": ("expert", "embed", "expert_mlp"),
        "w_down": ("expert", "expert_mlp", "embed"),
    }


def _route(xf, w_router, top_k: int, renormalize: bool = True):
    """xf: (N, D) -> (weights (N,k) fp32, ids (N,k) int32, aux_loss scalar)."""
    logits = xf.astype(jnp.float32) @ w_router
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)
    if renormalize:
        topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * mean_e(frac_tokens_e * mean_prob_e)
    E = w_router.shape[1]
    me = jnp.mean(probs, axis=0)
    onehot = jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot, axis=0)
    aux = E * jnp.sum(me * ce)
    return topw, topi.astype(jnp.int32), aux


def _dispatch_compute(xf, topw, topi, w_gate, w_up, w_down,
                      expert_offset, n_experts_total: int, capacity: int):
    """Sort-based capacity dispatch for the local expert slice.

    xf: (N, D); topw/topi: (N, k); w_*: (E_l, D, F)/(E_l, F, D).
    Returns (N, D) contribution of the local experts (zeros elsewhere).
    """
    N, D = xf.shape
    k = topi.shape[1]
    E_l = w_gate.shape[0]
    Nk = N * k

    flat_e = topi.reshape(Nk)
    flat_w = topw.reshape(Nk)
    flat_tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    local = (flat_e >= expert_offset) & (flat_e < expert_offset + E_l)
    le = jnp.where(local, flat_e - expert_offset, E_l)  # E_l == overflow bucket

    order = jnp.argsort(le, stable=True)
    s_le = le[order]
    s_tok = flat_tok[order]
    s_w = flat_w[order]
    # position within the expert's segment
    first = jnp.searchsorted(s_le, s_le, side="left")
    pos = jnp.arange(Nk, dtype=jnp.int32) - first.astype(jnp.int32)
    valid = (s_le < E_l) & (pos < capacity)

    # pack into (E_l + 1, C, D); invalid slots land in the overflow row
    be = jnp.where(valid, s_le, E_l).astype(jnp.int32)
    bp = jnp.where(valid, pos, 0).astype(jnp.int32)
    buf = jnp.zeros((E_l + 1, capacity, D), dtype=xf.dtype)
    buf = buf.at[be, bp].set(jnp.where(valid[:, None], xf[s_tok], 0.0))
    buf = buf[:E_l]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(xf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(xf.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xf.dtype))

    # scatter back to token order, weighted
    y_rows = y[jnp.minimum(s_le, E_l - 1), bp]  # (Nk, D); garbage where invalid
    contrib = jnp.where(valid, s_w, 0.0)[:, None].astype(xf.dtype) * y_rows
    out = jnp.zeros((N, D), dtype=xf.dtype).at[s_tok].add(contrib)
    return out


def moe_capacity(n_tokens: int, top_k: int, n_experts: int,
                 capacity_factor: float, min_capacity: int = 4) -> int:
    return max(min_capacity, int(math.ceil(n_tokens * top_k * capacity_factor / n_experts)))


def moe_apply_local(params, x, *, top_k: int, capacity_factor: float = 1.25,
                    min_capacity: int = 4, renormalize: bool = True,
                    expert_offset=0, n_experts_total: Optional[int] = None,
                    capacity: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-shard routed-MoE: x (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    E_total = n_experts_total or params["w_gate"].shape[0]
    topw, topi, aux = _route(xf, params["w_router"], top_k, renormalize)
    C = capacity if capacity is not None else moe_capacity(
        B * S, top_k, E_total, capacity_factor, min_capacity)
    out = _dispatch_compute(xf, topw.astype(xf.dtype), topi,
                            params["w_gate"], params["w_up"], params["w_down"],
                            expert_offset, E_total, C)
    return out.reshape(B, S, D), aux


def moe_apply_sharded(params, x, *, mesh, top_k: int,
                      data_axes=("data",), model_axis: str = "model",
                      ff_shard_axis: Optional[str] = "data",
                      capacity_factor: float = 1.25, min_capacity: int = 4,
                      renormalize: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE over ``mesh`` (see module docstring).

    x is sharded (batch over data_axes, replicated over model_axis); expert
    weights are sharded experts-over-model and d_ff-over-``ff_shard_axis``.
    """
    n_experts = params["w_gate"].shape[0]
    ep = mesh.shape[model_axis]
    if n_experts % ep != 0:
        raise ValueError(f"{n_experts} experts not divisible by EP={ep}")
    E_l = n_experts // ep
    batch_spec = P(tuple(data_axes), None, None)
    ff_axis = ff_shard_axis if ff_shard_axis in mesh.axis_names else None
    gate_spec = P(model_axis, None, ff_axis)
    down_spec = P(model_axis, ff_axis, None)

    # static capacity from local token count
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    B, S, D = x.shape
    n_local = (B // dp) * S
    C = moe_capacity(n_local, top_k, n_experts, capacity_factor, min_capacity)

    def local_fn(x_l, w_router, w_gate, w_up, w_down):
        if ff_axis is not None:
            w_gate = jax.lax.all_gather(w_gate, ff_axis, axis=2, tiled=True)
            w_up = jax.lax.all_gather(w_up, ff_axis, axis=2, tiled=True)
            w_down = jax.lax.all_gather(w_down, ff_axis, axis=1, tiled=True)
        Bl, Sl, Dl = x_l.shape
        xf = x_l.reshape(Bl * Sl, Dl)
        topw, topi, aux = _route(xf, w_router, top_k, renormalize)
        off = jax.lax.axis_index(model_axis) * E_l
        out = _dispatch_compute(xf, topw.astype(xf.dtype), topi,
                                w_gate, w_up, w_down, off, n_experts, C)
        out = jax.lax.psum(out, model_axis)
        aux = jax.lax.pmean(aux, tuple(data_axes))
        return out.reshape(Bl, Sl, Dl), aux

    from jax.experimental.shard_map import shard_map

    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(batch_spec, P(None, None), gate_spec, gate_spec, down_spec),
        out_specs=(batch_spec, P()),
        check_rep=False,
    )(x, params["w_router"], params["w_gate"], params["w_up"], params["w_down"])
    return out, aux


def moe_reference(params, x, *, top_k: int, renormalize: bool = True):
    """Exact dense oracle: every expert computed for every token, masked by
    the router's top-k choice.  O(E) cost — tests only."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    topw, topi, aux = _route(xf, params["w_router"], top_k, renormalize)
    E = params["w_gate"].shape[0]
    out = jnp.zeros_like(xf)
    for e in range(E):
        w_e = jnp.sum(jnp.where(topi == e, topw, 0.0), axis=1)  # (N,)
        h = jax.nn.silu(xf @ params["w_gate"][e]) * (xf @ params["w_up"][e])
        y = h @ params["w_down"][e]
        out = out + w_e[:, None].astype(xf.dtype) * y
    return out.reshape(B, S, D), aux
