"""Mamba-2 SSD (state-space duality) block.

Prefill/train uses the *chunked* SSD algorithm (intra-chunk dense
quadratic-in-chunk compute + inter-chunk linear state recurrence) — the
pure-JAX twin of the Pallas ``ssd_scan`` kernel.  Decode is the O(1)
single-step recurrence on the carried ``(H, P, N)`` state.

Shapes follow the Mamba-2 reference: ``d_inner = expand * d_model``,
``H = d_inner / headdim`` heads, state size ``N = ssm_state``, a single
B/C group (``G = 1``), depthwise causal conv of width ``conv_width`` over
the ``x``/``B``/``C`` channels.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .norms import rmsnorm


def init_ssd(key, d_model: int, *, expand: int = 2, headdim: int = 64,
             d_state: int = 128, conv_width: int = 4, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_ch = d_inner + 2 * d_state
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    return {
        "w_in": (jax.random.normal(ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads))
                 * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), dtype=jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), dtype=dtype)},
        "w_out": (jax.random.normal(ks[2], (d_inner, d_model))
                  * (1.0 / math.sqrt(d_inner))).astype(dtype),
    }


def ssd_axes():
    return {
        "w_in": ("embed", "ssm_inproj"),
        "conv_w": (None, "ssm_conv"),
        "conv_b": ("ssm_conv",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": {"scale": ("ssm_inner",)},
        "w_out": ("ssm_inner", "embed"),
    }


def _split_in(proj, d_inner: int, d_state: int, n_heads: int):
    z = proj[..., :d_inner]
    xc = proj[..., d_inner: 2 * d_inner]
    B = proj[..., 2 * d_inner: 2 * d_inner + d_state]
    C = proj[..., 2 * d_inner + d_state: 2 * d_inner + 2 * d_state]
    dt = proj[..., 2 * d_inner + 2 * d_state:]
    return z, xc, B, C, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B,S,C), w (K,C), b (C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i: i + x.shape[1], :] * w[i]
    return out + b


def _segsum(dA):
    """dA: (..., Q) -> (..., Q, Q) lower-tri cumulative sums:
    out[i, j] = sum_{k=j+1..i} dA_k for i >= j, -inf above diagonal."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, B, C, chunk: int, initial_state=None,
                unroll: bool = False):
    """Chunked SSD scan.

    xh: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) (negative);
    B, C: (b, s, n)  [single group broadcast over heads].
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).
    """
    b, s, h, p = xh.shape
    n = B.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, "sequence must be divisible by chunk"
    xc = xh.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)

    dA = dtc * A  # (b, c, q, h)
    dA_cs = jnp.cumsum(dA, axis=2)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (b, c, h, q, q)
    Y_diag = jnp.einsum("bcqn,bckn,bchqk,bckh,bckhp->bcqhp",
                        Cc, Bc, L, dtc, xc)

    # 2) per-chunk final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b, c, q, h)
    states = jnp.einsum("bckn,bckh,bckh,bckhp->bchpn", Bc, decay_states, dtc, xc)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b, c, h)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), dtype=jnp.float32)

    def scan_fn(carry, inp):
        st_c, dec_c = inp  # (b,h,p,n), (b,h)
        new = carry * dec_c[..., None, None] + st_c
        return new, carry  # emit the state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        scan_fn, initial_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=nc if unroll else 1)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, c, h, p, n)

    # 4) contribution of incoming state to each position
    state_decay = jnp.exp(dA_cs)  # (b, c, q, h)
    Y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, state_decay, prev_states)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, final_state


def ssd_forward(params, x, *, expand: int, headdim: int, d_state: int,
                conv_width: int, chunk: int = 256,
                cache: Optional[dict] = None,
                make_cache: bool = False,
                unroll: bool = False) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full Mamba-2 block. x: (B, S, D).

    Without ``cache``: chunked prefill/training path; ``make_cache=True``
    additionally returns the decode cache (final SSD state + conv history).
    With ``cache`` (decode, S == 1): single-step recurrence; returns
    (out, new_cache) where cache = {"conv": (B, K-1, Cch), "state": (B,H,P,N)}.
    """
    Bsz, S, D = x.shape
    d_inner = expand * D
    n_heads = d_inner // headdim
    proj = x @ params["w_in"]
    z, xc, Bm, Cm, dt = _split_in(proj, d_inner, d_state, n_heads)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)

    if cache is None:
        conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"], params["conv_b"]))
        new_cache = None
        if make_cache:
            K = params["conv_w"].shape[0]
            hist = conv_in[:, -(K - 1):, :]
            if S < K - 1:
                hist = jnp.pad(hist, ((0, 0), (K - 1 - S, 0), (0, 0)))
            new_cache = {"conv": hist}
    else:
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B, K-1+S, C)
        K = params["conv_w"].shape[0]
        acc = params["conv_b"]
        pieces = [hist[:, i: i + S, :] * params["conv_w"][i] for i in range(K)]
        conv_out = jax.nn.silu(sum(pieces) + acc)
        new_conv = hist[:, -(K - 1):, :]
        new_cache = {"conv": new_conv}

    xs = conv_out[..., :d_inner]
    Bs = conv_out[..., d_inner: d_inner + d_state]
    Cs = conv_out[..., d_inner + d_state:]
    xh = xs.reshape(Bsz, S, n_heads, headdim)
    A = -jnp.exp(params["A_log"])  # (h,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    if cache is None:
        # pad to a chunk multiple with dt == 0 (decay 1, contribution 0)
        q = min(chunk, S)
        pad = (-S) % q
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bs_p = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
            Cs_p = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, Bs_p, Cs_p = xh, dt, Bs, Cs
        y, final_state = ssd_chunked(xh_p, dt_p, A, Bs_p, Cs_p, chunk=q,
                                      unroll=unroll)
        y = y[:, :S]
        if make_cache:
            new_cache["state"] = final_state
    else:
        # decode: state' = exp(dt*A) * state + dt * (B ⊗ x); y = C · state' + D x
        st = cache["state"]  # (B, H, P, N) fp32
        dA = jnp.exp(dt[:, 0, :] * A)  # (B, H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0, :], Bs[:, 0, :].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        st_new = st * dA[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cs[:, 0, :].astype(jnp.float32), st_new)
        y = y[:, None]  # (B, 1, H, P)
        new_cache["state"] = st_new

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y)
    return y @ params["w_out"], new_cache


def init_ssd_cache(batch: int, d_model: int, *, expand: int, headdim: int,
                   d_state: int, conv_width: int, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_ch = d_inner + 2 * d_state
    return {
        "conv": jnp.zeros((batch, conv_width - 1, conv_ch), dtype=dtype),
        "state": jnp.zeros((batch, n_heads, headdim, d_state), dtype=jnp.float32),
    }


def ssd_reference(xh, dt, A, B, C, initial_state=None):
    """Naive per-step recurrence oracle for tests."""
    b, s, h, p = xh.shape
    n = B.shape[-1]
    st = (jnp.zeros((b, h, p, n), dtype=jnp.float32)
          if initial_state is None else initial_state)
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t, :] * A)  # (b, h)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t, :],
                         B[:, t].astype(jnp.float32), xh[:, t].astype(jnp.float32))
        st = st * dA[..., None, None] + dBx
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t].astype(jnp.float32), st))
    return jnp.stack(ys, axis=1), st
