"""internvl2-26b — VLM: InternViT frontend (STUB per assignment —
``input_specs`` provides precomputed patch embeddings) + InternLM2-20B-style
decoder backbone. [arXiv:2404.16821; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

PATCH_PREFIX = 1024  # ViT patch tokens provided as embeddings

FULL = LMConfig(
    name="internvl2-26b",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    input_mode="prefix_embeds", prefix_len=PATCH_PREFIX,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="internvl2-26b-reduced",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    input_mode="prefix_embeds", prefix_len=8,
)
