"""musicgen-medium — decoder-only transformer over EnCodec tokens; MHA
(kv=24).  The EnCodec frontend is a STUB per assignment — ``input_specs``
provides precomputed frame embeddings; the head predicts codebook tokens
(vocab 2048). [arXiv:2306.05284; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    mlp_gated=False,
    input_mode="embeds",
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="musicgen-medium-reduced",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=8,
    d_ff=256, vocab_size=128,
    input_mode="embeds",
)
