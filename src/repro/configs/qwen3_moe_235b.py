"""qwen3-moe-235b-a22b — 128 routed experts, top-8, expert d_ff=1536,
GQA kv=4, qk-norm. [hf:Qwen/Qwen3-30B-A3B; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=0, d_ff_expert=1536, vocab_size=151936, qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=("moe",), n_experts=128, top_k=8,
    moe_capacity_factor=1.25,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="qwen3-moe-235b-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=0, d_ff_expert=64, vocab_size=512, qk_norm=True,
    block_pattern=("moe",), n_experts=8, top_k=2,
    moe_capacity_factor=2.0,
)
