"""granite-3-2b — dense GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="granite-3-2b",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=49155,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="granite-3-2b-reduced",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=515,
)
