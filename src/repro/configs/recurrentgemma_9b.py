"""recurrentgemma-9b — Griffin hybrid: RG-LRU temporal mixing + local
attention in a 1:2 pattern (2 recurrent blocks per local-attention block),
MQA (kv=1), window 2048. [arXiv:2402.19427; unverified]

38 layers = 12 × (rglru, rglru, local_attn) + 2 tail rglru layers.
Sub-quadratic: runs the ``long_500k`` shape (O(window) attention memory,
O(1) recurrent state).
"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="recurrentgemma-9b",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    rnn_width=4096, local_window=2048,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="recurrentgemma-9b-reduced",
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=1,
    d_ff=256, vocab_size=512,
    block_pattern=("rglru", "rglru", "local_attn"),
    rnn_width=128, local_window=16,
)
