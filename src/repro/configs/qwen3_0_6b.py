"""qwen3-0.6b — dense GQA with per-head qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="qwen3-0.6b",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab_size=151936, qk_norm=True,
    rope_theta=1_000_000.0,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="qwen3-0.6b-reduced",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=4,
    d_ff=256, vocab_size=512, qk_norm=True,
)
