"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts,
top-6, expert d_ff=1408, MHA (kv=16). [arXiv:2401.06066; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, d_ff_expert=1408, vocab_size=102400,
    block_pattern=("moe",), n_experts=64, top_k=6, n_shared_experts=2,
    moe_capacity_factor=1.25,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="deepseek-moe-16b-reduced",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=0, d_ff_expert=64, vocab_size=512,
    block_pattern=("moe",), n_experts=8, top_k=3, n_shared_experts=2,
    moe_capacity_factor=2.0,
)
