"""Architecture registry: the 10 assigned architectures (``--arch <id>``),
each with its exact published configuration (FULL) and a smoke-test
REDUCED variant, plus the shape sets and ShapeDtypeStruct input specs."""
from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from ..models.lm import LMConfig
from .shapes import (  # noqa: F401
    SHAPES,
    SMOKE_SHAPES,
    ShapeSpec,
    cache_specs,
    input_specs,
    make_batch,
    shape_applicable,
)

_MODULES: Dict[str, str] = {
    "granite-20b": "granite_20b",
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-3-2b": "granite_3_2b",
    "internlm2-1.8b": "internlm2_1_8b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "mamba2-780m": "mamba2_780m",
    "internvl2-26b": "internvl2_26b",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str, reduced: bool = False) -> LMConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch '{arch}'; available: {ARCH_IDS}")
    mod = import_module(f".{_MODULES[arch]}", __package__)
    return mod.REDUCED if reduced else mod.FULL
