"""Assigned input-shape sets and ``input_specs()``.

Every architecture pairs with four shapes (assignment):

* ``train_4k``    — seq 4096,   global batch 256  (lowers ``train_step``)
* ``prefill_32k`` — seq 32768,  global batch 32   (lowers ``prefill``)
* ``decode_32k``  — seq 32768,  global batch 128  (lowers ``serve_step``:
                    one new token against a KV cache of 32768)
* ``long_500k``   — seq 524288, global batch 1    (``serve_step``; only for
                    sub-quadratic archs — SSM / hybrid; full-attention archs
                    are skipped per assignment, see DESIGN.md §5)

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins (no allocation) —
the multi-pod dry-run lowers against these.  ``make_batch`` materializes
small concrete batches for smoke tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import LMConfig, init_caches


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SMOKE_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 32, 2),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 48, 2),
    "decode_32k": ShapeSpec("decode_32k", "decode", 48, 2),
    "long_500k": ShapeSpec("long_500k", "decode", 64, 1),
}


def shape_applicable(cfg: LMConfig, shape_name: str) -> bool:
    """Assignment rule: ``long_500k`` only for sub-quadratic archs."""
    if shape_name == "long_500k":
        return cfg.sub_quadratic
    return True


def _token_batch_specs(cfg: LMConfig, batch: int, seq: int, with_loss: bool):
    i32 = jnp.int32
    cd = cfg.compute_dtype
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.input_mode == "tokens":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
    elif cfg.input_mode == "embeds":
        specs["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cd)
    elif cfg.input_mode == "prefix_embeds":
        p = min(cfg.prefix_len, max(seq // 4, 1)) if seq <= 64 else cfg.prefix_len
        specs["prefix_embeds"] = jax.ShapeDtypeStruct((batch, p, cfg.d_model), cd)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq - p), i32)
    if with_loss:
        specs["targets"] = jax.ShapeDtypeStruct((batch, seq), i32)
        specs["loss_mask"] = jax.ShapeDtypeStruct((batch, seq), jnp.float32)
    return specs


def cache_specs(cfg: LMConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, cache_len))


def input_specs(cfg: LMConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    train:    {"batch": {...}}
    prefill:  {"batch": {...}}                       (no loss tensors)
    decode:   {"batch": one-token, "caches": ..., "pos": scalar}
    """
    if shape.kind == "train":
        return {"batch": _token_batch_specs(cfg, shape.batch, shape.seq, True)}
    if shape.kind == "prefill":
        return {"batch": _token_batch_specs(cfg, shape.batch, shape.seq, False)}
    if shape.kind == "decode":
        if cfg.input_mode == "embeds":
            tok = {"embeds": jax.ShapeDtypeStruct((shape.batch, 1, cfg.d_model),
                                                  cfg.compute_dtype)}
        else:
            tok = {"tokens": jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)}
        return {
            "batch": tok,
            "caches": cache_specs(cfg, shape.batch, shape.seq),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


def make_batch(cfg: LMConfig, shape: ShapeSpec, seed: int = 0) -> Dict:
    """Concrete batch for smoke tests (small shapes only)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def concretize(s: jax.ShapeDtypeStruct):
        if np.issubdtype(s.dtype, np.integer):
            hi = cfg.vocab_size if s.shape[-1:] != () else shape.seq
            return jnp.asarray(rng.integers(0, max(2, min(hi, cfg.vocab_size)),
                                            size=s.shape), dtype=s.dtype)
        if s.shape == ():
            return jnp.asarray(0, dtype=s.dtype)
        return jnp.asarray(rng.standard_normal(s.shape), dtype=s.dtype)

    out = jax.tree.map(concretize, specs)
    if "batch" in out and "loss_mask" in out["batch"]:
        out["batch"]["loss_mask"] = jnp.ones_like(out["batch"]["loss_mask"])
    if "caches" in out:
        # decode smoke: a real (zero) cache is semantically valid
        out["caches"] = init_caches(cfg, shape.batch, shape.seq)
        out["pos"] = jnp.asarray(min(4, shape.seq - 1), jnp.int32)
    return out
