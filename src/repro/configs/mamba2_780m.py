"""mamba2-780m — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="mamba2-780m",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    block_pattern=("ssd",), ssm_state=128, ssm_headdim=64, ssm_expand=2,
    ssm_chunk=256,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="mamba2-780m-reduced",
    n_layers=3, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=512,
    block_pattern=("ssd",), ssm_state=16, ssm_headdim=16, ssm_expand=2,
    ssm_chunk=8,
)
