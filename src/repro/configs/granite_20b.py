"""granite-20b — dense llama-arch code model, MQA (GQA kv=1).
[arXiv:2405.04324; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="granite-20b",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    mlp_gated=False,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="granite-20b-reduced",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=1,
    d_ff=256, vocab_size=512,
)
