"""internlm2-1.8b — dense GQA. [arXiv:2403.17297; hf]"""
import jax.numpy as jnp

from ..models.lm import LMConfig

FULL = LMConfig(
    name="internlm2-1.8b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92544,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
)

REDUCED = LMConfig(
    name="internlm2-1.8b-reduced",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=4,
    d_ff=256, vocab_size=512,
)
