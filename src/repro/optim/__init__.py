from .adamw import adamw, clip_by_global_norm, cosine_schedule  # noqa: F401
from .compress import compressed_gradients  # noqa: F401
