"""AdamW with dtype-configurable moments (bf16 moments let the 235B MoE's
optimizer state fit HBM — DESIGN.md §5), cosine LR schedule, global-norm
clipping.  Functional, optax-style interface without the dependency."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step?) -> (new_params, new_state)


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip((step - warmup_steps) /
                        jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          moment_dtype=jnp.float32, max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, dtype=moment_dtype)
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state: AdamWState, params):
        gnorm = jnp.zeros((), jnp.float32)
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        count = state.count + 1
        t = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        step_lr = lr_fn(count)

        def upd(p, g, mu, nu):
            gf = g.astype(jnp.float32)
            mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
            nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mu_n / bc1
            vhat = nu_n / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - step_lr * delta
            return (new_p.astype(p.dtype), mu_n.astype(moment_dtype),
                    nu_n.astype(moment_dtype))

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t3: t3[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t3: t3[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t3: t3[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(count, new_mu, new_nu), gnorm

    return Optimizer(init=init, update=update)
