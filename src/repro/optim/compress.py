"""Gradient compression with error feedback (beyond-paper distributed-
optimization trick; DESIGN.md §4).

Two codecs, both with EF-SGD-style residual accumulation so compression
error is re-injected next step (keeps convergence):

* ``int8``  — per-tensor symmetric quantization of the gradient to int8
              before the cross-pod all-reduce (8× traffic cut on the slow
              inter-pod hops; DP all-reduce inside a pod stays full-precision
              on ICI).
* ``topk``  — keep the largest-|g| fraction per tensor (sparsity mask),
              residual carries the rest.

Usage: wrap the gradient tree between backward and optimizer::

    grads, ef_state = compressed_gradients(grads, ef_state, codec="int8")
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _quant_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _topk_mask(x, frac: float):
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_gradients(grads, ef_state: Optional[Any] = None, *,
                         codec: str = "int8", topk_frac: float = 0.01
                         ) -> Tuple[Any, Any]:
    """Returns (decompressed-after-compression grads, new error feedback).

    The round trip models exactly what the wire would carry; the returned
    gradient tree is what every replica reconstructs, so training remains
    bit-identical across replicas.
    """
    if ef_state is None:
        ef_state = init_error_feedback(grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if codec == "int8":
            q, s = _quant_int8(gf)
            rec = _dequant_int8(q, s)
        elif codec == "topk":
            rec = gf * _topk_mask(gf, topk_frac)
        elif codec == "none":
            rec = gf
        else:
            raise ValueError(codec)
        return rec.astype(g.dtype), gf - rec

    out = jax.tree.map(one, grads, ef_state)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef
