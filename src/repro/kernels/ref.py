"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

Each function computes exactly what its kernel computes, in plain jax.numpy,
with no tiling — tests sweep shapes/dtypes and assert allclose.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B,H,Sq,d); k,v: (B,K,Skv,d)."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(d)
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C):
    """Sequential SSD recurrence.  x: (b,s,h,p); dt: (b,s,h); A: (h,);
    B,C: (b,s,n).  Returns (y: (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    st = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t, :].astype(jnp.float32) * A)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t, :].astype(jnp.float32),
                         B[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32))
        st = st * dA[..., None, None] + dBx
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t].astype(jnp.float32), st))
    return jnp.stack(ys, axis=1).astype(x.dtype), st


def rglru_scan_ref(log_a, b, h0=None):
    """Linear recurrence h_t = exp(log_a_t)·h_{t-1} + b_t.
    log_a, b: (B, S, R); h0: (B, R)."""
    Bsz, S, R = b.shape
    h = jnp.zeros((Bsz, R), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    a = jnp.exp(log_a.astype(jnp.float32))
    bf = b.astype(jnp.float32)
    for t in range(S):
        h = a[:, t] * h + bf[:, t]
        ys.append(h)
    return jnp.stack(ys, axis=1).astype(b.dtype), h


def knn_topk_ref(test_x, train_x, train_y, k: int):
    """Exact k smallest squared distances + labels (ties: stable by index)."""
    d2 = (jnp.sum(test_x * test_x, axis=1)[:, None]
          - 2.0 * test_x @ train_x.T
          + jnp.sum(train_x * train_x, axis=1)[None, :])
    neg_d, idx = jax.lax.top_k(-d2, k)
    return -neg_d, train_y[idx]


def kmeans_assign_ref(x, centroids):
    """Returns (sums (k,d), counts (k,), sse scalar)."""
    d2 = (jnp.sum(x * x, axis=1)[:, None]
          - 2.0 * x @ centroids.T
          + jnp.sum(centroids * centroids, axis=1)[None, :])
    assign = jnp.argmin(d2, axis=1)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)
    sums = onehot.T @ x
    counts = jnp.sum(onehot, axis=0).astype(jnp.int32)
    sse = jnp.sum(jnp.min(d2, axis=1))
    return sums, counts, sse


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * (1.0 / jnp.sqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(x.dtype)
