"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §7), each with a
pure-jnp oracle in ``ref.py`` and a jit'd wrapper in ``ops.py``:

* ``flash_attention`` — GQA causal/windowed flash attention
* ``ssd_scan``        — Mamba-2 SSD chunked scan
* ``rglru_scan``      — RG-LRU linear recurrence
* ``knn_topk``        — fused distance + running top-k (paper's KNN_frag)
* ``kmeans_assign``   — fused assign + partial sums (paper's partial_sum)
* ``rmsnorm``         — fused norm

Validated in interpret mode on CPU; TPU is the target (BlockSpec VMEM
tiling, MXU-shaped dot_generals, accumulate-in-output grid patterns).
"""
from . import ops, ref  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .kmeans_assign import kmeans_assign  # noqa: F401
from .knn_topk import knn_topk  # noqa: F401
from .rglru_scan import rglru_scan  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
from .ssd_scan import ssd_scan  # noqa: F401
