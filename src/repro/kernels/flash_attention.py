"""Flash attention (GQA, causal, optional sliding window) as a Pallas TPU
kernel.

Adaptation notes (DESIGN.md §3): the GPU flash algorithm tiles over SMs with
warp-level softmax; on TPU we tile for the MXU — one program per
(batch, q-head, q-block), the (padded) K/V panel for the owning KV head
resident in VMEM, and an online-softmax ``fori_loop`` over K/V blocks.
Scores never touch HBM — that is the entire point vs. the pure-JAX twin
(``layers.attention._chunked_attn``), whose score tensors dominate the
dry-run memory roofline.

Layouts: q (B, H, Sq, d), k/v (B, K, Skv, d), H = K·G.  fp32 accumulation.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, block_k: int,
            causal: bool, window: Optional[int], seq_kv: int):
    bq, d = q_ref.shape[2], q_ref.shape[3]
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_k = k_ref.shape[2] // block_k  # padded panel; tail masked by seq_kv
    if causal:
        # blocks entirely above the diagonal contribute nothing
        n_k_eff = jnp.minimum(n_k, ((iq + 1) * bq + block_k - 1) // block_k)
    else:
        n_k_eff = n_k

    def body(j, carry):
        m, lse, acc = carry
        k = k_ref[0, 0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        kv_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = kv_pos < seq_kv
        if causal:
            mask &= kv_pos <= q_pos
        if window is not None:
            mask &= (q_pos - kv_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = lse * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    m, lse, acc = jax.lax.fori_loop(0, n_k_eff, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(lse, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, d); k, v: (B, K, Skv, d); returns (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k

    grid = (B, H, Sq_p // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k,
                          causal=causal, window=window, seq_kv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv_p, d), lambda b, h, i, G=G: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Skv_p, d), lambda b, h, i, G=G: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
