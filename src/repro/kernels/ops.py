"""jit'd public wrappers for the Pallas kernels.

Each op auto-selects ``interpret=True`` off-TPU (this container is
CPU-only; interpret mode executes the kernel body faithfully for
correctness validation) and exposes the model-layer calling conventions.
``flash_attention_op`` additionally carries a custom_vjp whose backward
recomputes through the jnp reference — the kernel accelerates the forward
path while training remains differentiable.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention
from .kmeans_assign import kmeans_assign
from .knn_topk import knn_topk
from .rglru_scan import rglru_scan
from .rmsnorm import rmsnorm
from .ssd_scan import ssd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ flash attention
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_op(q, k, v, causal: bool = True,
                       window: Optional[int] = None):
    """q: (B,H,Sq,d); k,v: (B,K,Skv,d) — kernel forward, reference backward."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=_interpret())


def _fa_fwd(q, k, v, causal, window):
    return flash_attention_op(q, k, v, causal, window), (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=causal,
                                                window=window), q, k, v)
    return vjp(g)


flash_attention_op.defvjp(_fa_fwd, _fa_bwd)


# --------------------------------------------------------------------- others
def ssd_scan_op(x, dt, A, Bm, Cm, *, chunk: int = 128):
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=_interpret())


def rglru_scan_op(log_a, b, h0=None):
    return rglru_scan(log_a, b, h0, interpret=_interpret())


def knn_topk_op(test_x, train_x, train_y, *, k: int = 5):
    return knn_topk(test_x, train_x, train_y, k=k, interpret=_interpret())


def kmeans_assign_op(x, centroids):
    return kmeans_assign(x, centroids, interpret=_interpret())


def rmsnorm_op(x, scale, *, eps: float = 1e-6):
    return rmsnorm(x, scale, eps=eps, interpret=_interpret())
