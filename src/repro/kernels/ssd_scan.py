"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

One program per (batch, head); the full sequence panel for that head lives
in VMEM and a ``fori_loop`` walks the chunks: the intra-chunk part is dense
MXU work ((Q,Q) decay-masked score matmul), the inter-chunk part carries the
(headdim, d_state) state — the classic SSD decomposition, tiled for
VMEM/MXU instead of CUDA shared memory (DESIGN.md §3).

Layouts: x (B, S, H, P), dt (B, S, H) post-softplus, A (H,) negative,
Bm/Cm (B, S, N) single group.  fp32 state & accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, A_ref, b_ref, c_ref, y_ref, *, chunk: int):
    S, P = x_ref.shape[1], x_ref.shape[3]
    N = b_ref.shape[2]
    n_chunks = S // chunk
    A = A_ref[0].astype(jnp.float32)  # scalar for this head

    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))

    def body(ci, state):
        sl = pl.ds(ci * chunk, chunk)
        x = x_ref[0, sl, 0, :].astype(jnp.float32)        # (Q, P)
        dt = dt_ref[0, sl, 0].astype(jnp.float32)         # (Q,)
        Bm = b_ref[0, sl, :].astype(jnp.float32)          # (Q, N)
        Cm = c_ref[0, sl, :].astype(jnp.float32)          # (Q, N)
        dA = dt * A                                       # (Q,)
        cs = jnp.cumsum(dA)                               # (Q,)
        # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
        L = jnp.where(tri, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
        scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q,Q)
        M = scores * L * dt[None, :]
        y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())))         # (Q,P)
        # inter-chunk: contribution of incoming state, then update it
        y = y + jnp.exp(cs)[:, None] * jax.lax.dot_general(
            Cm, state, (((1,), (1,)), ((), ())))                        # (Q,P)
        decay = jnp.exp(cs[-1] - cs)                                    # (Q,)
        upd = jax.lax.dot_general(x, Bm * (decay * dt)[:, None],
                                  (((0,), (0,)), ((), ())))             # (P,N)
        state = state * jnp.exp(cs[-1]) + upd
        y_ref[0, sl, 0, :] = y.astype(y_ref.dtype)
        return state

    state0 = jnp.zeros((P, N), jnp.float32)
    jax.lax.fori_loop(0, n_chunks, body, state0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm: (B,S,N) -> y (B,S,H,P)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad

    grid = (B, H)
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Sp, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, Sp, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1,), lambda b, h: (h,)),
            pl.BlockSpec((1, Sp, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, Sp, N), lambda b, h: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Sp, 1, P), lambda b, h: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, P), x.dtype),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y[:, :S]
