"""RG-LRU linear recurrence (h_t = a_t ⊙ h_{t-1} + b_t) as a Pallas TPU
kernel.

The recurrence is elementwise over channels, so the kernel tiles channels
into VPU-width panels — one program per (batch, channel-block) — and walks
time sequentially in a ``fori_loop`` with the (block,) state vector in
registers/VMEM.  A diagonal linear scan has no matrix structure to feed the
MXU; the win vs. the XLA associative_scan is keeping h entirely on-chip
(the log-depth assoc-scan materializes O(S log S) intermediates in HBM).
Gates are precomputed outside (they are dense matmuls that XLA already
fuses well); the kernel takes log_a and b directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(loga_ref, b_ref, h0_ref, y_ref, hT_ref):
    S, R = loga_ref.shape[1], loga_ref.shape[2]

    def body(t, h):
        a = jnp.exp(loga_ref[0, t, :].astype(jnp.float32))
        h = a * h + b_ref[0, t, :].astype(jnp.float32)
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, S, body, h0_ref[0].astype(jnp.float32))
    hT_ref[0] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def rglru_scan(log_a, b, h0=None, *, block_r: int = 512,
               interpret: bool = False):
    """log_a, b: (B, S, R); h0: (B, R) or None.
    Returns (y (B,S,R), h_final (B,R))."""
    B, S, R = b.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)
    block_r = min(block_r, R)
    pad = (-R) % block_r
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad)))
    Rp = R + pad

    grid = (B, Rp // block_r)
    y, hT = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, block_r), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, S, block_r), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_r), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_r), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_r), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Rp), b.dtype),
            jax.ShapeDtypeStruct((B, Rp), jnp.float32),
        ],
        interpret=interpret,
    )(log_a, b, h0)
    return y[:, :, :R], hT[:, :R]
