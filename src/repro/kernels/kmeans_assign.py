"""Fused K-means assignment + partial sums (the paper's ``partial_sum``
task) as a Pallas TPU kernel.

Grid over point blocks (sequential); outputs (sums (k,d), counts (k,),
sse (1,1)) are revisited/accumulated across the grid.  The assignment
matmul feeds the MXU; the one-hot assignment matrix immediately contracts
into the per-cluster sums (a second MXU matmul) so neither distances nor
assignments ever reach HBM — the kernel emits exactly the paper's partial
results (k·d + k + 1 floats) per fragment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, csq_ref, sums_ref, counts_ref, sse_ref,
            *, n_points: int, block_m: int):
    i = pl.program_id(0)
    kc = c_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        sse_ref[...] = jnp.zeros_like(sse_ref)

    x = x_ref[...].astype(jnp.float32)                        # (m, d)
    c = c_ref[...].astype(jnp.float32)                        # (k, d)
    # distance without |x|^2 (constant per row for argmin); add it for sse
    half = (jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())))
            - 0.5 * csq_ref[...][None, :])                    # (m, k)
    assign = jnp.argmax(half, axis=1).astype(jnp.int32)       # (m,)
    row = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], kc), 1)
    valid = (i * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], 1), 0)) < n_points
    onehot = ((row == assign[:, None]) & valid).astype(jnp.float32)  # (m, k)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ()))).astype(sums_ref.dtype)
    counts_ref[...] += jnp.sum(onehot, axis=0).astype(counts_ref.dtype)
    best = jnp.max(half, axis=1)
    xsq = jnp.sum(x * x, axis=1)
    sse_blk = jnp.sum(jnp.where(valid[:, 0], xsq - 2.0 * best, 0.0))
    sse_ref[0, 0] += sse_blk.astype(sse_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def kmeans_assign(x, centroids, *, block_m: int = 1024,
                  interpret: bool = False):
    """x: (n, d); centroids: (k, d).
    Returns (sums (k,d) f32, counts (k,) i32, sse scalar f32)."""
    n, d = x.shape
    k = centroids.shape[0]
    block_m = min(block_m, n)
    pad = (-n) % block_m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    np_ = n + pad
    csq = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)

    grid = (np_ // block_m,)
    sums, counts, sse = pl.pallas_call(
        functools.partial(_kernel, n_points=n, block_m=block_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, centroids, csq)
    return sums, counts, sse[0, 0]
