"""Fused RMSNorm (bandwidth-bound; one read + one write per element)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...][None, :].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(x, scale, *, block_rows: int = 256, eps: float = 1e-6,
            interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((n + pad) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:n].reshape(orig_shape)
