"""Fused distance + running top-k for the paper's ``KNN_frag`` hot loop.

Grid: (test-blocks, train-blocks); the train axis is the innermost
(sequential) dimension, and the output blocks — the running (m, k) best
distances/labels for one test block — are *revisited* across it (the
standard TPU accumulate-in-output pattern).  Per step: one MXU matmul for
the -2·X·Yᵀ term, then k selection passes implemented with argmin + one-hot
(Pallas TPU has no dynamic gather; the one-hot trick keeps everything
vectorized).

Adaptation (DESIGN.md §3): the paper's R implementation leans on BLAS GEMM
+ R ``order()``; here distance and selection fuse in VMEM so candidate
distances never round-trip to HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30  # plain python float: jnp constants would be captured as consts


def _kernel(xsq_ref, x_ref, y_ref, ysq_ref, lab_ref, outd_ref, outl_ref,
            *, k: int, n_train: int, block_n: int):
    j = pl.program_id(1)
    m = x_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        outd_ref[...] = jnp.full((m, k), BIG, outd_ref.dtype)
        outl_ref[...] = jnp.zeros((m, k), outl_ref.dtype)

    x = x_ref[...].astype(jnp.float32)          # (m, d)
    y = y_ref[...].astype(jnp.float32)          # (bn, d)
    d2 = (xsq_ref[...][:, None]
          - 2.0 * jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())))
          + ysq_ref[...][None, :])              # (m, bn)
    base = j * block_n
    col = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    d2 = jnp.where(base + col < n_train, d2, BIG)
    labs = lab_ref[...][None, :] * jnp.ones((m, 1), jnp.int32)  # (m, bn)

    cand_d = jnp.concatenate([outd_ref[...].astype(jnp.float32), d2], axis=1)
    cand_l = jnp.concatenate([outl_ref[...], labs], axis=1)
    nc = cand_d.shape[1]
    idx_row = jax.lax.broadcasted_iota(jnp.int32, (m, nc), 1)
    new_d = jnp.zeros((m, k), jnp.float32)
    new_l = jnp.zeros((m, k), jnp.int32)
    for i in range(k):                          # k selection passes
        best = jnp.min(cand_d, axis=1)          # (m,)
        arg = jnp.argmin(cand_d, axis=1).astype(jnp.int32)
        onehot = idx_row == arg[:, None]        # (m, nc)
        lab = jnp.sum(jnp.where(onehot, cand_l, 0), axis=1)
        new_d = new_d.at[:, i].set(best)
        new_l = new_l.at[:, i].set(lab)
        cand_d = jnp.where(onehot, BIG, cand_d)
    outd_ref[...] = new_d.astype(outd_ref.dtype)
    outl_ref[...] = new_l


@functools.partial(jax.jit, static_argnames=("k", "block_m", "block_n",
                                             "interpret"))
def knn_topk(test_x, train_x, train_y, *, k: int = 5, block_m: int = 128,
             block_n: int = 512, interpret: bool = False):
    """test_x: (m, d); train_x: (n, d); train_y: (n,) int32.
    Returns (dists (m, k) ascending, labels (m, k))."""
    m, d = test_x.shape
    n = train_x.shape[0]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    pad_m = (-m) % block_m
    pad_n = (-n) % block_n
    if pad_m:
        test_x = jnp.pad(test_x, ((0, pad_m), (0, 0)))
    if pad_n:
        train_x = jnp.pad(train_x, ((0, pad_n), (0, 0)))
        train_y = jnp.pad(train_y, (0, pad_n))
    xsq = jnp.sum(test_x.astype(jnp.float32) ** 2, axis=1)
    ysq = jnp.sum(train_x.astype(jnp.float32) ** 2, axis=1)
    mp, np_ = m + pad_m, n + pad_n

    grid = (mp // block_m, np_ // block_n)
    outd, outl = pl.pallas_call(
        functools.partial(_kernel, k=k, n_train=n, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), jnp.float32),
            jax.ShapeDtypeStruct((mp, k), jnp.int32),
        ],
        interpret=interpret,
    )(xsq, test_x, train_x, ysq, train_y.astype(jnp.int32))
    return outd[:m], outl[:m]
