"""Checkpoint/restart with elastic resharding.

Storage is mesh-independent: one raw binary per pytree leaf (the runtime's
``raw`` codec — the serialization layer the paper benchmarks in Table 1)
plus a JSON manifest of tree paths/shapes/dtypes.  Restore places leaves
onto *whatever mesh/sharding the relaunch provides* — restart with fewer or
more pods re-shards transparently (DESIGN.md §3 fault-tolerance row).

Saves are atomic (tmp dir + rename) and can run asynchronously as RCOMPSs
tasks (``CheckpointManager.save_async``) so checkpoint I/O overlaps the
next training step — checkpointing is itself a node in the task DAG.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_BF16 = "bfloat16"


def _leaf_files(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        name = re.sub(r"[^A-Za-z0-9_.-]+", "_", name).strip("_") or "leaf"
        out.append((name, leaf))
    # ensure uniqueness
    seen: Dict[str, int] = {}
    uniq = []
    for name, leaf in out:
        n = seen.get(name, 0)
        seen[name] = n + 1
        uniq.append((f"{name}__{n}" if n else name, leaf))
    return uniq


def save_checkpoint(path: str, tree: Any, step: int,
                    extra: Optional[dict] = None) -> str:
    """Write ``tree`` under ``path`` atomically; returns the final dir."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    final = path / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=path, prefix=".tmp_"))
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in _leaf_files(tree):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype == _BF16:
            arr = arr.view(np.uint16)
        np.save(tmp / f"{name}.npy", arr, allow_pickle=False)
        manifest["leaves"].append({"name": name, "dtype": dtype,
                                   "shape": list(arr.shape)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def _load_leaf(dirpath: Path, meta: dict):
    arr = np.load(dirpath / f"{meta['name']}.npy", allow_pickle=False)
    if meta["dtype"] == _BF16:
        import ml_dtypes
        arr = arr.view(ml_dtypes.bfloat16)
    return arr


def restore_checkpoint(path: str, target_tree: Any, *, shardings: Any = None,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``target_tree`` (shapes must match the
    stored leaves).  ``shardings``: optional matching tree of NamedShardings
    — the elastic-resharding path (any mesh, any partitioning)."""
    root = Path(path)
    if step is None:
        cands = sorted(root.glob("step_*"))
        if not cands:
            raise FileNotFoundError(f"no checkpoints under {path}")
        final = cands[-1]
    else:
        final = root / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    by_name = {m["name"]: m for m in manifest["leaves"]}
    names = [n for n, _ in _leaf_files(target_tree)]
    if set(names) != set(by_name):
        missing = set(by_name) ^ set(names)
        raise ValueError(f"checkpoint/tree structure mismatch: {sorted(missing)[:5]}")
    arrays = [_load_leaf(final, by_name[n]) for n in names]
    flat_t, treedef = jax.tree_util.tree_flatten(target_tree)
    if shardings is not None:
        flat_s = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_s)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays), manifest["step"]


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async saves via the
    RCOMPSs runtime (the save is a task — retried on failure like any
    other)."""

    def __init__(self, path: str, keep: int = 3, use_runtime: bool = False):
        self.path = Path(path)
        self.keep = keep
        self.use_runtime = use_runtime
        self._save_task = None
        self._last_future = None
        if use_runtime:
            from ..core import api
            self._save_task = api.task(self._save_impl, name="checkpoint_save",
                                       max_retries=2)
        self._lock = threading.Lock()

    def _save_impl(self, host_tree, step: int, extra: Optional[dict]) -> str:
        out = save_checkpoint(str(self.path), host_tree, step, extra)
        self._gc()
        return out

    def _gc(self) -> None:
        with self._lock:
            cands = sorted(self.path.glob("step_*"))
            for old in cands[: max(0, len(cands) - self.keep)]:
                shutil.rmtree(old, ignore_errors=True)

    def save(self, tree: Any, step: int, extra: Optional[dict] = None,
             blocking: bool = True):
        if not self.use_runtime or blocking:
            return self._save_impl(jax.device_get(tree), step, extra)
        host_tree = jax.device_get(tree)  # snapshot before the step mutates
        self._last_future = self._save_task(host_tree, step, extra)
        return self._last_future

    def wait(self) -> None:
        if self._last_future is not None:
            from ..core import api
            api.wait_on(self._last_future)
            self._last_future = None

    def latest_step(self) -> Optional[int]:
        cands = sorted(self.path.glob("step_*"))
        if not cands:
            return None
        return int(cands[-1].name.split("_")[1])

    def restore(self, target_tree: Any, *, shardings: Any = None,
                step: Optional[int] = None):
        return restore_checkpoint(str(self.path), target_tree,
                                  shardings=shardings, step=step)
