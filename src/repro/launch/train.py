"""Production training driver.

The paper's technique is the orchestration layer here (DESIGN.md §4): data
prefetch, metric handling, and checkpoint saves run as RCOMPSs tasks on the
persistent-executor runtime, so I/O overlaps compute exactly the way the
paper hides I/O behind GEMMs (§5.3).  The compute step itself is the
pjit/GSPMD ``train_step`` from ``repro.distributed``.

Fault tolerance: checkpoint saves are retried tasks; ``--restore`` resumes
from the newest checkpoint onto *whatever mesh this launch has* (elastic
resharding).  Batches are deterministic in (seed, step), so a restored run
replays the exact data stream.

CPU-scale usage (the end-to-end example):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 20 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import json
import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..core import api
from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import DataPipeline
from ..distributed.sharding import default_rules, param_pspecs, to_shardings
from ..distributed.steps import make_train_step
from ..models.lm import LMConfig, init_params, param_axes
from ..optim.adamw import adamw, cosine_schedule
from .mesh import make_local_mesh


def train_loop(
    cfg: LMConfig,
    *,
    steps: int = 20,
    batch: int = 8,
    seq: int = 64,
    lr: float = 3e-4,
    warmup: int = 10,
    microbatches: int = 1,
    workers: int = 4,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    restore: bool = False,
    grad_compress: Optional[str] = None,
    mesh=None,
    log_every: int = 1,
    manage_runtime: bool = True,
) -> Dict[str, Any]:
    """Returns {"losses": [...], "steps_done", "restored_from", "tokens_per_s"}."""
    if manage_runtime:
        api.runtime_start(n_workers=workers, policy="fifo", max_retries=2)
    try:
        mesh = mesh or make_local_mesh(model=1, data=1)
        rules = default_rules(mesh)
        opt = adamw(cosine_schedule(lr, warmup, steps), weight_decay=0.01)
        pipeline = DataPipeline(cfg, batch, seq, seed=seed, prefetch_depth=2)

        params = init_params(cfg, jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
        pspecs = param_pspecs(param_axes(cfg), params, rules, mesh)
        p_sh = to_shardings(pspecs, mesh)

        manager = None
        start_step = 0
        restored_from = None
        if ckpt_dir:
            manager = CheckpointManager(ckpt_dir, keep=3, use_runtime=True)
            if restore and manager.latest_step() is not None:
                state = {"params": params, "opt": opt_state}
                state, start_step = manager.restore(state)
                params = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), state["params"], p_sh)
                opt_state = state["opt"]
                restored_from = start_step
        sample = pipeline.get(start_step)
        step_fn, in_sh, out_sh, donate = make_train_step(
            cfg, mesh, opt, rules=rules, microbatches=microbatches,
            sample_batch=sample, grad_compress=grad_compress)
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)

        losses: List[float] = []
        t0 = time.perf_counter()
        batch_np = sample
        for step in range(start_step, steps):
            dev_batch = jax.tree.map(jnp.asarray, batch_np)
            params, opt_state, metrics = jitted(params, opt_state, dev_batch)
            if step + 1 < steps:
                batch_np = pipeline.get(step + 1)  # prefetched task result
            loss = float(metrics["loss"])
            losses.append(loss)
            if math.isnan(loss):
                raise FloatingPointError(f"loss NaN at step {step}")
            if log_every and (step % log_every == 0 or step == steps - 1):
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
            if manager and ckpt_every and (step + 1) % ckpt_every == 0:
                manager.save({"params": params, "opt": opt_state}, step + 1,
                             blocking=False)
        wall = time.perf_counter() - t0
        if manager:
            manager.wait()
            manager.save({"params": params, "opt": opt_state}, steps)
        api.barrier()
        tokens = (steps - start_step) * batch * seq
        return {"losses": losses, "steps_done": steps - start_step,
                "restored_from": restored_from,
                "tokens_per_s": tokens / max(wall, 1e-9),
                "runtime_stats": api.current_runtime().stats()}
    finally:
        if manage_runtime:
            api.runtime_stop()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--grad-compress", default=None,
                    choices=[None, "int8", "topk"])
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=args.reduced)
    out = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     lr=args.lr, microbatches=args.microbatches,
                     workers=args.workers, seed=args.seed,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     restore=args.restore, grad_compress=args.grad_compress)
    print(json.dumps({k: v for k, v in out.items() if k != "losses"}, indent=1,
                     default=str))
    print(f"loss: {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
