"""Production meshes (assignment spec).

Defined as functions so importing this module never touches JAX device
state — ``dryrun.py`` must set XLA_FLAGS before any mesh is built.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)            # 256 chips / pod (TPU v5e)
MULTI_POD = (2, 16, 16)          # 2 pods = 512 chips

# v5e hardware constants for the roofline (assignment spec)
PEAK_FLOPS_BF16 = 197e12         # per chip
HBM_BW = 819e9                   # bytes/s per chip
ICI_BW = 50e9                    # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1):
    """Small mesh over however many devices exist (tests / CPU smoke)."""
    return jax.make_mesh((data, model), ("data", "model"))
