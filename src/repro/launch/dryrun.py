import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  This module is the ONLY place the 512 placeholder devices are
#   requested — tests and benches see the real device count.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import math          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config, input_specs, shape_applicable  # noqa: E402
from ..distributed.analysis import Roofline, model_flops, parse_collectives  # noqa: E402
from ..distributed.sharding import default_rules  # noqa: E402
from ..distributed.steps import make_decode_step, make_prefill_step, make_train_step  # noqa: E402
from ..models.lm import init_params  # noqa: E402
from ..optim.adamw import adamw  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# per-arch microbatch counts for train_4k, sized so rematted activations fit
# 16 GB/chip HBM (derivation in EXPERIMENTS.md §Dry-run)
MICROBATCHES = {
    "granite-20b": 8, "qwen3-0.6b": 2, "granite-3-2b": 4, "internlm2-1.8b": 2,
    "deepseek-moe-16b": 4, "qwen3-moe-235b-a22b": 16, "mamba2-780m": 4,
    "internvl2-26b": 8, "musicgen-medium": 4, "recurrentgemma-9b": 8,
}
# archs whose optimizer moments are kept in bf16 to fit HBM (DESIGN.md §5)
BF16_MOMENTS = {"qwen3-moe-235b-a22b"}


def _opt_for(arch: str):
    return adamw(1e-4, moment_dtype=jnp.bfloat16 if arch in BF16_MOMENTS
                 else jnp.float32)


def _lower_compile(cfg, shape, mesh, rules, *, microbatches=1,
                   accum_unroll=False):
    """Build the step for (cfg, shape), jit-lower against ShapeDtypeStructs,
    compile; returns (compiled, per-device cost dict)."""
    specs = input_specs(cfg, shape)
    pshapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    if shape.kind == "train":
        opt = _opt_for(cfg.name.split("-reduced")[0])
        fn, in_sh, out_sh, donate = make_train_step(
            cfg, mesh, opt, rules=rules, microbatches=microbatches,
            sample_batch=specs["batch"], accum_unroll=accum_unroll)
        oshapes = jax.eval_shape(opt.init, pshapes)
        args = (pshapes, oshapes, specs["batch"])
    elif shape.kind == "prefill":
        fn, in_sh, out_sh, donate = make_prefill_step(
            cfg, mesh, cache_len=shape.seq, rules=rules,
            sample_batch=specs["batch"])
        args = (pshapes, specs["batch"])
    else:
        fn, in_sh, out_sh, donate = make_decode_step(
            cfg, mesh, rules=rules, sample_batch=specs["batch"],
            sample_caches=specs["caches"])
        args = (pshapes, specs["batch"], specs["caches"], specs["pos"])
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text())
    metrics = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.total_bytes),
        "coll_by_kind": coll.by_kind,
        "n_coll": coll.count,
    }
    return compiled, metrics


def _probe_cfg(cfg, n_layers):
    return dataclasses.replace(cfg, n_layers=n_layers, scan_layers=False,
                               unroll_scans=True)


def probe_roofline(cfg, shape, mesh, rules, mb_real: int) -> dict:
    """Loop-aware HLO cost via unrolled probe compiles at reduced depth,
    extrapolated affinely to the real depth (and microbatch count for
    training).  Exact for depth-homogeneous models; see EXPERIMENTS.md."""
    p = len(cfg.block_pattern)
    t = cfg.n_tail
    L1, L2 = p + t, 2 * p + t
    L_real = cfg.n_layers

    def probe(L, mb):
        _, m = _lower_compile(_probe_cfg(cfg, L), shape, mesh, rules,
                              microbatches=mb, accum_unroll=True)
        return m

    keys = ("flops", "bytes", "coll")
    if shape.kind == "train" and mb_real > 1:
        f11, f21 = probe(L1, 1), probe(L2, 1)
        f12, f22 = probe(L1, 2), probe(L2, 2)
        out = {}
        for k in keys:
            s1 = (f21[k] - f11[k]) / (L2 - L1)
            s2 = (f22[k] - f12[k]) / (L2 - L1)
            fL1 = f11[k] + s1 * (L_real - L1)   # m = 1 at real depth
            fL2 = f12[k] + s2 * (L_real - L1)   # m = 2 at real depth
            out[k] = fL1 + (mb_real - 1) * (fL2 - fL1)
        out["probe_points"] = {"L1": L1, "L2": L2, "mb": [1, 2]}
        return out
    f1, f2 = probe(L1, 1), probe(L2, 1)
    out = {}
    for k in keys:
        slope = (f2[k] - f1[k]) / (L2 - L1)
        out[k] = f1[k] + slope * (L_real - L1)
    out["probe_points"] = {"L1": L1, "L2": L2}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             rules_override: dict | None = None, microbatches: int | None = None,
             tag: str = "", probes: bool = True,
             cfg_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
        cell_overrides = dict(cfg_overrides)
    else:
        cell_overrides = {}
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
            "cfg_overrides": cell_overrides}
    if not shape_applicable(cfg, shape_name):
        cell["status"] = "SKIP"
        cell["reason"] = ("long_500k requires sub-quadratic attention; "
                         "full-attention arch skipped per assignment")
        return cell

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    rules = default_rules(mesh)
    if rules_override:
        rules = rules.override(**rules_override)

    mb = 1
    if shape.kind == "train":
        dp = math.prod(mesh.shape[a] for a in ("pod", "data")
                       if a in mesh.axis_names)
        per_shard = shape.batch // dp
        mb = max(1, min(microbatches or MICROBATCHES.get(arch, 1), per_shard))
        cell["microbatches"] = mb

    # 1) the REAL compile (scan-stacked layers): proves the distribution
    #    config lowers + compiles; memory_analysis from here
    compiled, raw = _lower_compile(cfg, shape, mesh, rules, microbatches=mb)
    cell["compile_s"] = round(time.time() - t0, 1)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
        mem["total_per_device_gb"] = round(
            (mem.get("argument_size_in_bytes", 0) +
             mem.get("output_size_in_bytes", 0) +
             mem.get("temp_size_in_bytes", 0) -
             mem.get("alias_size_in_bytes", 0)) / 2**30, 2)
    except Exception as e:  # pragma: no cover
        mem["error"] = repr(e)
    cell["memory_analysis"] = mem
    cell["raw_cost_scan_counted_once"] = raw
    cell["chips"] = chips

    # 2) probe compiles for loop-aware cost (single-pod roofline only)
    if probes and not multi_pod:
        t1 = time.time()
        est = probe_roofline(cfg, shape, mesh, rules, mb)
        cell["probe_s"] = round(time.time() - t1, 1)
        mf = model_flops(cfg, shape.kind, shape.batch, shape.seq)
        rl = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=est["flops"] * chips, hlo_bytes=est["bytes"] * chips,
            collective_bytes=est["coll"] * chips, model_flops_total=mf,
        ).finalize()
        cell["roofline"] = rl.as_dict()
        cell["probe_points"] = est.get("probe_points")
    cell["status"] = "OK"
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch}_{shape_name}_{mesh_name}{('_' + tag) if tag else ''}.json"
    (out_dir / fname).write_text(json.dumps(cell, indent=1))
    return cell


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile "
                                 "every (arch × shape × mesh) cell")
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--rules", default=None,
                    help="comma list of logical=mesh overrides, e.g. "
                         "'embed=model,mlp=data'")
    ap.add_argument("--set", dest="cfg_set", default=None,
                    help="comma list of LMConfig overrides, e.g. "
                         "'tp_block=shard_map,attn_scores_bf16=1'")
    args = ap.parse_args()

    cfg_overrides = None
    if args.cfg_set:
        cfg_overrides = {}
        for kv in args.cfg_set.split(","):
            k, _, v = kv.partition("=")
            if v in ("0", "1"):
                cfg_overrides[k] = bool(int(v))
            elif v.isdigit():
                cfg_overrides[k] = int(v)
            else:
                cfg_overrides[k] = v

    overrides = None
    if args.rules:
        overrides = {}
        for kv in args.rules.split(","):
            k, _, v = kv.partition("=")
            overrides[k] = None if v in ("", "none", "None") else (
                tuple(v.split("+")) if "+" in v else v)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    out = Path(args.out)

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    cell = run_cell(arch, shape, mp, out, overrides,
                                    args.microbatches, args.tag,
                                    probes=not args.no_probes,
                                    cfg_overrides=cfg_overrides)
                    status = cell["status"]
                    extra = ""
                    if status == "OK":
                        extra = (f" mem={cell['memory_analysis'].get('total_per_device_gb', '?')}GB"
                                 f" compile={cell['compile_s']}s")
                        if "roofline" in cell:
                            r = cell["roofline"]
                            extra += (f" compute={r['compute_s']*1e3:.1f}ms"
                                      f" memory={r['memory_s']*1e3:.1f}ms"
                                      f" coll={r['collective_s']*1e3:.1f}ms"
                                      f" bound={r['bottleneck']}"
                                      f" useful={r['useful_ratio']:.2f}")
                except Exception:
                    status = "FAIL"
                    extra = "\n" + traceback.format_exc(limit=8)
                    cell = {"arch": arch, "shape": shape,
                            "mesh": "2x16x16" if mp else "16x16",
                            "status": "FAIL", "error": traceback.format_exc()}
                    out.mkdir(parents=True, exist_ok=True)
                    (out / f"{arch}_{shape}_{cell['mesh']}_FAIL.json").write_text(
                        json.dumps(cell, indent=1))
                results.append(cell)
                print(f"[{status}] {label}{extra}", flush=True)

    n_ok = sum(1 for c in results if c["status"] == "OK")
    n_skip = sum(1 for c in results if c["status"] == "SKIP")
    n_fail = sum(1 for c in results if c["status"] == "FAIL")
    print(f"\ndry-run: {n_ok} OK, {n_skip} SKIP (documented), {n_fail} FAIL")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
