"""Batched serving driver: prefill + greedy decode with KV/recurrent caches.

Request pre-processing (prompt synthesis / tokenization stand-in) and
response post-processing run as RCOMPSs tasks; the prefill/decode steps are
the pjit functions from ``repro.distributed`` — the same split the paper
makes between orchestration (runtime) and compute (BLAS, here the MXU).

CPU-scale usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 8 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..core import api
from ..distributed.steps import make_decode_step, make_prefill_step
from ..models.lm import LMConfig, init_params
from .mesh import make_local_mesh


def make_prompts(cfg: LMConfig, n: int, prompt_len: int, seed: int) -> Dict:
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "embeds":
        return {"embeds": rng.standard_normal(
            (n, prompt_len, cfg.d_model)).astype(np.float32)}
    if cfg.input_mode == "prefix_embeds":
        p = min(cfg.prefix_len, prompt_len // 2)
        return {
            "prefix_embeds": rng.standard_normal((n, p, cfg.d_model)).astype(np.float32),
            "tokens": rng.integers(0, cfg.vocab_size,
                                   (n, prompt_len - p)).astype(np.int32),
        }
    return {"tokens": rng.integers(0, cfg.vocab_size,
                                   (n, prompt_len)).astype(np.int32)}


def serve_batch(cfg: LMConfig, *, batch: int = 4, prompt_len: int = 32,
                gen_len: int = 16, seed: int = 0, mesh=None,
                manage_runtime: bool = True) -> Dict[str, Any]:
    if manage_runtime:
        api.runtime_start(n_workers=2)
    try:
        mesh = mesh or make_local_mesh()
        cache_len = prompt_len + gen_len
        prompt_task = api.task(make_prompts, name="make_prompts")
        prompts_f = prompt_task(cfg, batch, prompt_len, seed)

        params = init_params(cfg, jax.random.PRNGKey(seed))
        prompts = api.wait_on(prompts_f)
        prefill, pin, pout, _ = make_prefill_step(
            cfg, mesh, cache_len=cache_len, sample_batch=prompts)
        prefill_j = jax.jit(prefill, in_shardings=pin, out_shardings=pout)

        t0 = time.perf_counter()
        dev_prompts = jax.tree.map(jnp.asarray, prompts)
        logits, caches = prefill_j(params, dev_prompts)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        t_prefill = time.perf_counter() - t0

        dec_batch = ({"embeds": jnp.zeros((batch, 1, cfg.d_model))}
                     if cfg.input_mode == "embeds"
                     else {"tokens": next_tok[:, None]})
        decode, din, dout, ddon = make_decode_step(
            cfg, mesh, sample_batch=dec_batch, sample_caches=caches)
        decode_j = jax.jit(decode, in_shardings=din, out_shardings=dout,
                           donate_argnums=ddon)

        generated: List[np.ndarray] = [np.asarray(next_tok)]
        t1 = time.perf_counter()
        pos = prompt_len
        for i in range(gen_len - 1):
            if cfg.input_mode == "embeds":
                step_in = {"embeds": jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(seed), i),
                    (batch, 1, cfg.d_model))}
            else:
                step_in = {"tokens": next_tok[:, None]}
            logits, caches = decode_j(params, step_in, caches,
                                      jnp.asarray(pos, jnp.int32))
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            generated.append(np.asarray(next_tok))
            pos += 1
        t_decode = time.perf_counter() - t1

        post_task = api.task(lambda toks: np.stack(toks, axis=1),
                             name="postprocess")
        out_tokens = api.wait_on(post_task(generated))
        return {
            "tokens": out_tokens,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tokens_per_s": batch * (gen_len - 1) / max(t_decode, 1e-9),
        }
    finally:
        if manage_runtime:
            api.runtime_stop()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=args.reduced)
    out = serve_batch(cfg, batch=args.requests, prompt_len=args.prompt_len,
                      gen_len=args.gen_len)
    print(json.dumps({k: (v.shape if hasattr(v, "shape") else v)
                      for k, v in out.items()}, indent=1, default=str))


if __name__ == "__main__":
    main()
