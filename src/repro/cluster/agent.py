"""The node agent: one process per cluster node (DESIGN.md §12).

``python -m repro.cluster.agent --connect HOST:PORT --workers N``

The agent dials the scheduler, registers (hello/welcome handshake), forks
``N`` persistent worker processes (PR 1's :class:`ProcessExecutor` pool —
the same shared-memory object plane now serves as the *intra-node* tier),
and then serves the scheduler's task stream:

* ``task``  — decode the payload (``Put`` payloads are cached in the
  node-local object plane keyed by ``(data_id, version)``; ``Ref`` markers
  resolve against it — the send-once/reuse-many property), run the body on
  the requested pool slot, reply with the result (ndarrays as raw-codec
  frames, each tagged with a cache token).
* ``alias`` — promote a result token to a datum key: the scheduler posts
  this when it publishes the task's output, so later tasks scheduled here
  reference the result without it ever crossing the wire again.
* ``drop``  — discard an unpublished result token.
* ``stats`` — report pool + plane statistics.
* ``exit``  — drain nothing, shut the pool down, leave.

The agent also *pushes* without being asked: a periodic ``hb`` heartbeat
(DESIGN.md §17) rides the same scheduler connection, carrying the node's
plane/pool/p2p telemetry snapshot.  Cadence comes from
``RJAX_HEARTBEAT_S``, then the welcome handshake, then 1s; 0 disables.

Failure model: a *pool worker* crash is handled inside the agent (the
inner executor respawns it and the error travels back as a retryable
``WorkerCrashedError``); an *agent* crash surfaces scheduler-side as a
dropped connection, which the cluster executor maps to
``WorkerCrashedError`` and answers by respawning the agent — the
scheduler re-ships whatever data the replacement needs, since v1 keeps
the authoritative copy of every datum on the scheduler.
"""
from __future__ import annotations

import argparse
import os
import pickle
import queue
import socket
import sys
import threading
import time
import traceback
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.config import add_agent_cli_args, resolve as resolve_knob
from ..core.executors import (
    DeadlineExceededError,
    ProcessExecutor,
    WorkerCrashedError,
    _loads_fn,
)
from ..core.telemetry import HEARTBEAT_DEFAULT_S
from ..core.memory import (
    MemoryBudget,
    MemoryGovernor,
    SpilledValue,
    parse_bytes,
    spill_to_file,
    spillable,
)
from ..core.serialization import as_c_contiguous
from . import chaos
from .peer import PEER_FETCH_TIMEOUT, DataServer, PeerFetchError, PeerPool
from .protocol import (
    DEFAULT_INLINE_MAX,
    ConnectionClosed,
    Fetch,
    Frame,
    Put,
    RemoteRef,
    array_frame,
    datum_frame_bytes,
    frame_eligible,
    inline_max_from_env,
    recv_msg,
    send_msg,
    struct_nbytes,
    unpack_payload,
)


class NodePlane:
    """Node-local object cache keyed by ``(data_id, version)``: everything
    this node ever received or produced, so repeat reads never re-cross
    the wire.  Plus a token side-table for results the scheduler has not
    yet bound to a datum key.

    With a memory budget configured (DESIGN.md §13) the plane is bounded:
    cold ndarrays past the high watermark spill to node-local mmap-codec
    files and fault back as zero-copy ``np.memmap`` views on the next
    ``lookup`` — the scheduler keeps sending ``Ref`` markers for them and
    never needs to know.  Entries genuinely *lost* (the whole agent died)
    are re-shipped over the wire by the scheduler's residency reset, which
    is the remote-``Ref`` fault path."""

    def __init__(self, memory_budget=None):
        # reentrant: a governed store() can spill (re-entering plane
        # bookkeeping) while the lock is held
        self._lock = threading.RLock()
        self._data: Dict[Tuple[int, int], Any] = {}
        self._tmp: Dict[int, Any] = {}
        # per-key residency generations (DESIGN.md §20): bumped once per
        # residency *mark* the scheduler ships (Put/Fetch directive,
        # alias, broadcast leg) — the scheduler bumps its mirror ledger
        # at the same message, so after a clean stream both sides agree
        # and a resume manifest entry with a matching generation is valid
        self._gens: Dict[Tuple[int, int], int] = {}
        # keys with a peer fetch in flight (DESIGN.md §15): registered on
        # the reader thread in wire order, resolved by the peer pool;
        # lookups block on the event so a Ref can never observe a gap
        # between the scheduler's residency mark and the bytes landing
        self._pending: Dict[Tuple[int, int], "_PendingFetch"] = {}
        # tombstones for failed pulls: a lookup that starts AFTER the
        # failure must still surface a retryable PeerFetchError (carrying
        # lost_input), not a bare KeyError that burns the task's own
        # retry budget.  Cleared when a fresh Fetch re-registers or the
        # value arrives another way (re-Put after a residency strike)
        self._fetch_failed: Dict[Tuple[int, int], BaseException] = {}
        self.governor: Optional[MemoryGovernor] = None
        self.configure_memory(memory_budget)

    def configure_memory(self, budget, high_frac: float = 0.9,
                         low_frac: float = 0.7) -> None:
        cap = parse_bytes(budget)
        self.governor = None if cap is None else MemoryGovernor(
            MemoryBudget(cap, high_frac, low_frac), self._spill_key,
            name="node-plane")

    def _spill_key(self, key: Tuple[int, int]) -> int:
        value = self._data.get(key)
        if not spillable(value):
            return 0
        try:
            spilled = spill_to_file(
                value, prefix=f"rjax_node_d{key[0]}v{key[1]}_")
        except Exception:
            return 0
        self._data[key] = spilled
        return value.nbytes

    def contains(self, key: Tuple[int, int]) -> bool:
        """Residency probe that never faults (reader-thread pre-store).
        Pending peer fetches count as resident — the bytes are on their
        way, and ``lookup`` blocks until they land."""
        with self._lock:
            return key in self._data or key in self._pending

    def lookup(self, key: Tuple[int, int]) -> Any:
        while True:
            with self._lock:
                if key in self._data:
                    value = self._data[key]
                    if isinstance(value, SpilledValue):
                        view = value.load()   # file-backed: not re-charged
                        self._data[key] = view
                        if self.governor is not None:
                            self.governor.fault(key, value.nbytes)
                        return view
                    if self.governor is not None:
                        self.governor.touch(key)
                    return value
                pending = self._pending.get(key)
                if pending is None:
                    failed = self._fetch_failed.get(key)
            if pending is None:
                if failed is not None:
                    err = PeerFetchError(
                        f"peer fetch of d{key[0]}v{key[1]} failed earlier "
                        f"on this node: {failed}")
                    err.__cause__ = failed
                    raise err
                raise KeyError(key)
            # wait OUTSIDE the lock for the peer pull to land
            if not pending.event.wait(timeout=PEER_FETCH_TIMEOUT):
                raise PeerFetchError(
                    f"peer fetch of d{key[0]}v{key[1]} timed out after "
                    f"{PEER_FETCH_TIMEOUT}s")
            if pending.error is not None:
                raise pending.error

    # -- peer-fetch lifecycle (DESIGN.md §15) --------------------------------
    def begin_fetch(self, key: Tuple[int, int]) -> bool:
        """Register a pending peer pull; False if the key is already
        resident or in flight (nothing to do)."""
        with self._lock:
            if key in self._data or key in self._pending:
                return False
            self._fetch_failed.pop(key, None)   # fresh directive: retry
            self._pending[key] = _PendingFetch()
            return True

    def resolve_fetch(self, key: Tuple[int, int], value: Any) -> None:
        with self._lock:
            self.store(key, value)
            pending = self._pending.pop(key, None)
        if pending is not None:
            pending.event.set()

    def fail_fetch(self, key: Tuple[int, int], err: BaseException) -> None:
        """The pull failed (producer gone).  Current waiters observe the
        error, LATE lookups hit the tombstone (still a retryable
        lost-input error), and a retry's fresh ``Fetch`` directive (after
        the scheduler's residency reset) re-registers cleanly."""
        with self._lock:
            self._fetch_failed[key] = err
            pending = self._pending.pop(key, None)
        if pending is not None:
            pending.error = err
            pending.event.set()

    def lookup_serve(self, key: Optional[Tuple[int, int]],
                     token: Optional[int]) -> Any:
        """Data-server resolution: by datum key first, then by result
        token — a consumer's fetch may legitimately arrive before this
        node processed the ``alias`` that binds token to key."""
        if key is not None:
            try:
                return self.lookup(key)
            except KeyError:
                pass
        with self._lock:
            if token is not None and token in self._tmp:
                return self._tmp[token]
        raise KeyError(key if key is not None else token)

    def store(self, key: Tuple[int, int], value: Any) -> None:
        with self._lock:
            self._data[key] = value
            self._fetch_failed.pop(key, None)   # value arrived after all
            if self.governor is not None and spillable(value):
                self.governor.admit(key, value.nbytes)

    def hold(self, token: int, value: Any) -> None:
        with self._lock:
            self._tmp[token] = value

    def alias(self, token: int, key: Tuple[int, int]) -> None:
        with self._lock:
            v = self._tmp.pop(token, None)
            if v is not None:
                self.store(key, v)

    def drop(self, token: int) -> None:
        with self._lock:
            self._tmp.pop(token, None)

    def note_mark(self, key: Tuple[int, int]) -> int:
        """Bump (and return) the residency generation for ``key`` —
        called once per scheduler residency mark received (§20)."""
        with self._lock:
            g = self._gens.get(key, 0) + 1
            self._gens[key] = g
            return g

    def manifest(self) -> List[Tuple[Tuple[int, int], int, int]]:
        """The resume manifest: ``[(key, generation, nbytes), ...]`` for
        every resident datum (pending fetches excluded — their bytes may
        never land)."""
        with self._lock:
            out = []
            for key, v in self._data.items():
                nb = int(getattr(v, "nbytes", 0) or 0) \
                    if hasattr(v, "nbytes") else struct_nbytes(v)
                out.append((key, self._gens.get(key, 0), nb))
            return out

    def dispose_spills(self) -> None:
        """Unlink still-spilled entries' files (agent shutdown); faulted
        views unlink their own file at GC."""
        with self._lock:
            for key, value in list(self._data.items()):
                if isinstance(value, SpilledValue):
                    value.dispose()
                    del self._data[key]

    def stats(self) -> dict:
        with self._lock:
            vals = list(self._data.values())
            s = {
                "plane_entries": len(vals),
                "plane_tmp": len(self._tmp),
                "plane_pending_fetches": len(self._pending),
                "plane_bytes": sum(struct_nbytes(v) if not hasattr(v, "nbytes")
                                   else int(getattr(v, "nbytes", 0) or 0)
                                   for v in vals),
            }
            if self.governor is not None:
                s.update({f"plane_{k}": v
                          for k, v in self.governor.stats().items()})
            return s


class _PendingFetch:
    __slots__ = ("event", "error")

    def __init__(self):
        self.event = threading.Event()
        self.error: Optional[BaseException] = None


class NodeAgent:
    def __init__(self, address: str, workers: int,
                 node_id: Optional[int] = None,
                 mp_context: Optional[str] = None,
                 memory_budget=None,
                 heartbeat_s=None,
                 inline_max=None):
        host, _, port = address.rpartition(":")
        self.addr = (host or "127.0.0.1", int(port))
        self.workers = int(workers)
        self.node_id = node_id
        self._mp_context = mp_context
        # every knob resolves through the one precedence rule
        # (core/config.py): CLI flag > this host's env var > the
        # scheduler's welcome value > built-in default.  The welcome
        # tier is filled in by run(); constructor values are the CLI
        # (explicit) tier.
        self.memory_budget = parse_bytes(memory_budget)
        self._heartbeat_cli = None if heartbeat_s is None else float(heartbeat_s)
        self._inline_cli = None if inline_max is None else int(inline_max)
        self.plane = NodePlane()
        self.pool: Optional[ProcessExecutor] = None
        self.sock: Optional[socket.socket] = None
        # peer data plane (DESIGN.md §15): serve our node plane to peers,
        # pull Fetch directives from theirs.  The p2p flag and (unless
        # this host sets RJAX_INLINE_MAX itself) the inline threshold are
        # settled by the welcome handshake, so every agent applies the
        # scheduler's encoding policy
        self.data_server: Optional[DataServer] = None
        self.peers = PeerPool(label=f"agent{node_id}",
                              fd_hooks=(self._track_fd, self._untrack_fd))
        self.p2p = True
        self.heartbeat_s = 0.0   # settled by the welcome handshake
        self.inline_max = inline_max_from_env(self._inline_cli)
        self._send_lock = threading.Lock()
        self._slot_queues: List[queue.Queue] = []
        self._fns: Dict[int, Any] = {}
        self._fn_blobs: Dict[int, bytes] = {}
        self._fn_lock = threading.Lock()
        self._next_token = 1
        self._token_lock = threading.Lock()
        self._done = threading.Event()
        # session resumption (DESIGN.md §20): settled by the welcome
        self._session: Optional[str] = None
        self._grace = 0.0
        self._epoch = 0
        self._last_mid = 0              # highest mid received (serve order)
        self._sent_replies: "OrderedDict[int, tuple]" = OrderedDict()
        self._conn_ok = threading.Event()   # cleared while reconnecting
        self._conn_dead = False
        # per-slot deadline watchdogs (DESIGN.md §19): armed around the
        # pool invoke, they kill the slot's worker when the body overruns
        self._deadline_locks = [threading.Lock() for _ in range(self.workers)]
        self.watchdog_kills = 0

    # ------------------------------------------------------------- lifecycle
    def _track_fd(self, fd: int) -> None:
        """Data-plane sockets (accepted serve connections, outgoing peer
        pulls) must be closed at birth by respawned pool workers, exactly
        like the scheduler socket — a worker inheriting one keeps the
        connection half-open after this agent dies, masking the crash
        from the peer (GIL-atomic list ops; read at fork time)."""
        self.pool.inherit_blockers.append(fd)

    def _untrack_fd(self, fd: int) -> None:
        try:
            self.pool.inherit_blockers.remove(fd)
        except ValueError:
            pass

    def run(self) -> None:
        # fork the pool BEFORE connecting and before the slot threads exist
        # (never fork a multithreaded process, and never let a worker
        # inherit the scheduler socket — a worker holding it would keep
        # the connection half-open after this agent dies, hiding the crash
        # from the scheduler)
        self.pool = ProcessExecutor(self.workers, label="agent",
                                    mp_context=self._mp_context)
        self.pool.spawn_workers()
        self.sock = socket.create_connection(self.addr, timeout=30.0)
        self.sock.settimeout(None)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        # the data listener binds the interface that faces the cluster —
        # the local address of the scheduler connection (127.0.0.1 under
        # LocalCluster: never exposed off-host) — NOT all interfaces:
        # recv_msg unpickles request metadata, so an open port would be a
        # code-execution surface.  Multi-homed deployments where peers
        # live on a different network override with RJAX_DATA_HOST.
        # Binding happens before the hello so the port can ride it.
        data_host = os.environ.get("RJAX_DATA_HOST")
        self.data_server = DataServer(
            self.plane.lookup_serve,
            host=data_host or self.sock.getsockname()[0],
            fd_hooks=(self._track_fd, self._untrack_fd))
        # workers respawned after a crash fork with the socket open: make
        # them close it at birth (the data listener too — a worker holding
        # it would keep serving a dead node's port)
        self.pool.inherit_blockers.append(self.sock.fileno())
        self.pool.inherit_blockers.append(self.data_server._listener.fileno())
        hello = {"op": "hello", "node_id": self.node_id,
                 "workers": self.workers, "pid": os.getpid(),
                 "host": socket.gethostname(),
                 "data_port": self.data_server.port}
        if data_host:
            # explicitly-routed data network: advertise the host too —
            # the default peers derive (this connection's source host)
            # would point at the wrong interface
            hello["data_host"] = data_host
        send_msg(self.sock, hello)
        welcome, _ = recv_msg(self.sock)
        assert welcome.get("op") == "welcome", welcome
        self.node_id = welcome["node_id"]
        self.p2p = bool(welcome.get("p2p", True))
        # session resumption (§20): keep the token; on a transient
        # disconnect we re-dial within the grace window instead of dying
        self._session = welcome.get("session")
        self._grace = max(0.0, float(welcome.get("reconnect_grace_s") or 0.0))
        self._epoch = int(welcome.get("epoch") or 0)
        self._conn_ok.set()
        # CLI > env > welcome > default, uniformly (core/config.py)
        self.heartbeat_s = max(0.0, resolve_knob(
            self._heartbeat_cli, "RJAX_HEARTBEAT_S",
            welcome.get("heartbeat_s"), HEARTBEAT_DEFAULT_S, float))
        self.inline_max = max(0, resolve_knob(
            self._inline_cli, "RJAX_INLINE_MAX",
            welcome.get("inline_max"), DEFAULT_INLINE_MAX, int))
        budget = resolve_knob(
            self.memory_budget, "RJAX_MEMORY_BUDGET",
            welcome.get("memory_budget"), None, parse_bytes)
        if budget is not None:
            # both node-local tiers are governed: the wire-facing plane
            # spills to mmap files, the intra-node shm plane drops
            # segments (their authoritative copy is here or upstream)
            self.plane.configure_memory(budget)
            self.pool.plane.configure_memory(budget)
        self._slot_queues = [queue.Queue() for _ in range(self.workers)]
        threads = []
        for slot in range(self.workers):
            t = threading.Thread(target=self._slot_loop, args=(slot,),
                                 daemon=True, name=f"agent{self.node_id}-s{slot}")
            t.start()
            threads.append(t)
        if self.heartbeat_s > 0:
            threading.Thread(target=self._heartbeat_loop, daemon=True,
                             name=f"agent{self.node_id}-hb").start()
        try:
            self._serve()
        finally:
            self._done.set()
            for q in self._slot_queues:
                q.put(None)
            for t in threads:
                t.join(timeout=2.0)
            try:
                self.pool.shutdown(wait=False)
            except Exception:
                pass
            try:
                self.peers.close()
            except Exception:
                pass
            try:
                self.data_server.close()
            except Exception:
                pass
            try:
                self.plane.dispose_spills()
            except Exception:
                pass
            try:
                self.sock.close()
            except OSError:
                pass

    def _serve(self) -> None:
        while True:
            try:
                meta, frames = recv_msg(self.sock)
            except ConnectionClosed:
                # scheduler link dropped: resume the session within the
                # grace window (§20), else exit and let respawn happen
                if self._try_resume():
                    continue
                return
            mid = meta.get("mid")
            if mid is not None and mid > self._last_mid:
                # the resume hello reports this high-water mark so the
                # scheduler knows which in-flight requests we ever saw
                self._last_mid = mid
            op = meta.get("op")
            if op == "task":
                # pre-store Puts and the fn blob HERE, on the reader, before
                # the task is even queued: slot threads run concurrently, so
                # the scheduler's wire-FIFO residency guarantee (a Ref never
                # overtakes its Put; an fn token never beats its body) must
                # be anchored at the single in-order consumer of the stream
                try:
                    self._pre_store(meta, frames)
                except Exception as err:   # malformed payload: fail the task,
                    import traceback       # not the whole agent
                    self._reply({"op": "err", "mid": meta.get("mid"),
                                 "exc": None,
                                 "tb": f"{type(err).__name__}|{err}|"
                                       f"{traceback.format_exc()}"})
                    continue
                self._slot_queues[meta["slot"]].put((meta, frames))
            elif op == "alias":
                key = tuple(meta["key"])
                self.plane.note_mark(key)
                self.plane.alias(meta["token"], key)
            elif op == "bcast":
                self._handle_bcast(meta, frames)
            elif op == "drop":
                self.plane.drop(meta["token"])
            elif op == "stats":
                self._reply({"op": "stats", "mid": meta["mid"],
                             "stats": self._telemetry_stats()})
            elif op == "exit":
                return
            else:
                self._reply({"op": "err", "mid": meta.get("mid"), "exc": None,
                             "tb": f"agent: unknown op {op!r}"})

    _REPLAY_RING = 256   # recorded replies kept for resume replay

    @property
    def _resume_enabled(self) -> bool:
        return bool(self._session) and self._grace > 0

    def _record_reply(self, mid: int, meta: dict, frames) -> None:
        # caller holds _send_lock.  Bounded: entries reference plane-held
        # buffers, so the ring itself costs little extra memory, but it
        # must not grow with job length
        ring = self._sent_replies
        ring[mid] = (meta, frames)
        ring.move_to_end(mid)
        while len(ring) > self._REPLAY_RING:
            ring.popitem(last=False)

    def _reply(self, meta: dict, frames=()) -> None:
        """Send a reply/push to the scheduler.  With session resumption
        armed, a mid-carrying reply survives a transient disconnect: it
        is recorded in the replay ring and the send retried once the
        serve loop has swapped in the resumed socket (§20)."""
        mid = meta.get("mid")
        retryable = mid is not None and self._resume_enabled
        while True:
            if not self._conn_ok.wait(timeout=self._grace + 5.0):
                raise ConnectionClosed("scheduler connection not restored")
            if self._conn_dead:
                raise ConnectionClosed("scheduler gone")
            try:
                with self._send_lock:
                    inj = chaos.INJECTOR
                    if inj is not None:
                        # chaos seam (§19/§20): the node's uplink is
                        # partitioned — every outbound message stalls
                        inj.partition_stall(f"agent{self.node_id}-wire")
                    if retryable:
                        self._record_reply(mid, meta, frames)
                    send_msg(self.sock, meta, frames)
                return
            except (ConnectionClosed, OSError) as err:
                if not retryable:
                    raise ConnectionClosed(str(err) or "send failed") from err
                # the serve loop's recv fails too and drives the
                # reconnect; wait for the swapped socket and retry
                self._conn_ok.clear()

    # -------------------------------------------- session resumption (§20)
    def _try_resume(self) -> bool:
        """Re-dial the scheduler and resume this session after a
        transient disconnect.  Returns True with ``self.sock`` swapped to
        the accepted resume connection, or False (grace exhausted,
        session rejected, or resumption disabled) — the caller then exits
        and the scheduler's respawn path takes over."""
        if not self._resume_enabled or self._done.is_set():
            return False
        self._conn_ok.clear()
        self._epoch += 1
        deadline = time.monotonic() + self._grace + 2.0
        delay = 0.05
        while time.monotonic() < deadline and not self._done.is_set():
            sock = None
            try:
                sock = socket.create_connection(self.addr, timeout=5.0)
                try:
                    sock.setsockopt(socket.IPPROTO_TCP,
                                    socket.TCP_NODELAY, 1)
                except OSError:
                    pass
                hello = {"op": "hello", "resume": self._session,
                         "epoch": self._epoch, "node_id": self.node_id,
                         "workers": self.workers, "pid": os.getpid(),
                         "host": socket.gethostname(),
                         "data_port": self.data_server.port,
                         "seen_mid": self._last_mid,
                         "manifest": self.plane.manifest()}
                data_host = os.environ.get("RJAX_DATA_HOST")
                if data_host:
                    hello["data_host"] = data_host
                send_msg(sock, hello)
                sock.settimeout(10.0)
                welcome, _ = recv_msg(sock)
                sock.settimeout(None)
            except (OSError, ConnectionClosed):
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass
                time.sleep(delay)
                delay = min(delay * 2, 0.5)
                continue
            if not welcome.get("resumed"):
                # session superseded or grace expired scheduler-side:
                # this process is dead weight, the respawn owns the node
                try:
                    sock.close()
                except OSError:
                    pass
                break
            with self._send_lock:
                old, self.sock = self.sock, sock
            # fd hygiene: respawned pool workers must close the NEW
            # scheduler socket at birth, and stop blocking on the old fd
            old_fd = -1
            try:
                old_fd = old.fileno()
            except OSError:
                pass
            self.pool.inherit_blockers.append(sock.fileno())
            self._untrack_fd(old_fd)
            try:
                old.close()
            except OSError:
                pass
            self._replay(welcome.get("outstanding") or ())
            self._conn_ok.set()
            return True
        self._conn_dead = True
        self._conn_ok.set()   # wake blocked repliers so they fail fast
        return False

    def _replay(self, outstanding) -> None:
        """Re-send recorded replies for still-outstanding mids: the
        first copy may have died in the old socket's buffers.  The
        scheduler ignores a mid it has already completed."""
        with self._send_lock:
            for mid in outstanding:
                entry = self._sent_replies.get(mid)
                if entry is None:
                    continue   # task still executing: reply comes later
                try:
                    send_msg(self.sock, entry[0], entry[1])
                except (ConnectionClosed, OSError):
                    return

    # ------------------------------------------------------------- telemetry
    def _telemetry_stats(self) -> dict:
        """One node telemetry snapshot: plane ledger + pool counters +
        p2p fetch ledger + data-server stats + queued task depth.  Served
        on demand (``stats``) and pushed periodically (``hb``)."""
        s = dict(self.plane.stats())
        # the inner pool's shm plane reports its own governor under
        # plane_* too: namespace it so the node plane's ledger (the
        # wire-facing tier) isn't shadowed
        for k, v in self.pool.stats().items():
            s[f"pool_{k}" if (k in s or k.startswith("plane_"))
              else k] = v
        s["node_id"] = self.node_id
        # the pool is the single fetch ledger (counted where both
        # sync and async pulls converge, under the pool lock)
        s["p2p_fetches"] = self.peers.fetches
        s["p2p_fetch_bytes"] = self.peers.fetch_bytes
        if self.data_server is not None:
            s.update(self.data_server.stats())
        # in-flight credit depth: tasks the scheduler streamed ahead that
        # are still waiting for a pool slot (DESIGN.md §14/§17)
        s["queued"] = sum(q.qsize() for q in self._slot_queues)
        s["watchdog_kills"] = self.watchdog_kills
        return s

    def _heartbeat_loop(self) -> None:
        """Push the telemetry snapshot every ``heartbeat_s`` seconds on
        the scheduler connection.  No ``mid``: nothing awaits it — the
        scheduler's channel reader routes mid-less messages to its
        ``on_push`` hook (DESIGN.md §17).  Dies silently with the
        connection; the respawned agent starts a fresh loop.  Beats
        immediately so the scheduler's node view populates at startup
        rather than one cadence later."""
        while True:
            inj = chaos.INJECTOR
            if inj is not None and inj.roll("drop",
                                            f"agent{self.node_id}-hb") is not None:
                # chaos seam: heartbeat loss — the beat is simply never
                # sent; enough consecutive drops and the scheduler's
                # failure detector declares this node dead
                if self._done.wait(self.heartbeat_s):
                    return
                continue
            try:
                self._reply({"op": "hb", "node": self.node_id,
                             "t": time.time(),
                             "stats": self._telemetry_stats()})
            except (ConnectionClosed, OSError):
                if not self._resume_enabled or self._conn_dead:
                    return
                # reconnecting: skip this beat, keep the loop alive —
                # the resumed session needs heartbeats or the failure
                # detector would declare the node dead post-resume
            if self._done.wait(self.heartbeat_s):
                return

    # ------------------------------------------------------------- broadcast
    def _handle_bcast(self, meta: dict, frames) -> None:
        """One leg of a collective broadcast (DESIGN.md §16).  The *root*
        form (``root=True``) carries the datum's encoded structure +
        frames over the scheduler link: store into the plane and ack.
        The *peer* form carries a parent agent's data-plane address
        instead: pull the bytes agent→agent through the peer pool and
        ack once they land — the ack is what promotes this node to a
        source for the next frontier wave.  Acks are asynchronous; the
        reader thread never blocks on a pull."""
        key = tuple(meta["key"])
        mid = meta["mid"]
        self.plane.note_mark(key)

        def ack():
            try:
                self._reply({"op": "bcast_ok", "mid": mid,
                             "node": self.node_id})
            except ConnectionClosed:
                pass

        def nak(err):
            try:
                enc = pickle.dumps(err, protocol=5)
            except Exception:
                enc = None
            try:
                self._reply({"op": "err", "mid": mid, "exc": enc,
                             "tb": f"{type(err).__name__}|{err}"})
            except ConnectionClosed:
                pass

        if meta.get("root"):
            if not self.plane.contains(key):
                self.plane.store(key, unpack_payload(meta["structure"],
                                                     frames))
            ack()
            return

        if not self.plane.begin_fetch(key):
            # already resident or a pull is in flight: confirm from a
            # side thread (lookup may block on the pending entry)
            def confirm():
                try:
                    self.plane.lookup(key)
                    ack()
                except BaseException as err:  # noqa: BLE001 — ships back
                    nak(err)

            threading.Thread(target=confirm, daemon=True,
                             name=f"agent{self.node_id}-bcast").start()
            return

        addr = meta.get("addr")
        if not addr:
            err = PeerFetchError(
                f"no data-plane address for broadcast parent of "
                f"d{key[0]}v{key[1]}")
            self.plane.fail_fetch(key, err)
            nak(err)
            return

        def on_done(value, err):
            if err is not None:
                self.plane.fail_fetch(key, err)
                nak(err)
            else:
                self.plane.resolve_fetch(key, value)
                ack()

        self.peers.fetch_async(addr, key, meta.get("token"), on_done)

    # ------------------------------------------------------------- task path
    def _pre_store(self, meta: dict, frames) -> None:
        """Reader-thread half of a task message: pin the fn blob, cache
        every ``Put`` payload into the plane (frame decode is a zero-copy
        ``np.frombuffer``, so this stays cheap), and kick off the peer
        pull for every ``Fetch`` directive (registered here, in stream
        order, so a later ``Ref`` to the same key blocks on the pending
        entry instead of missing).  Runs for every task whether or not
        the body later fails — keeping the scheduler's residency/fn
        ledgers truthful."""
        blob = meta.get("fn")
        if blob:
            with self._fn_lock:
                self._fn_blobs.setdefault(meta["token"], blob)

        def walk(o):
            if isinstance(o, Put):
                # generation bump regardless of the contains-skip: the
                # scheduler bumped its mirror when it *sent* the mark
                self.plane.note_mark(o.key)
                if not self.plane.contains(o.key):   # probe, don't fault
                    # a Put payload is the datum's structure with Frame
                    # markers only (enc_value never nests other datums),
                    # so the protocol's own walker decodes it
                    self.plane.store(o.key, unpack_payload(o.value, frames))
            elif isinstance(o, Fetch):
                self.plane.note_mark(o.key)
                if self.plane.begin_fetch(o.key):
                    self._start_fetch(o)
            elif isinstance(o, (list, tuple)):
                for x in o:
                    walk(x)
            elif isinstance(o, dict):
                for x in o.values():
                    walk(x)

        walk(meta["structure"])

    def _start_fetch(self, directive: Fetch) -> None:
        """Queue the peer pull on the pooled per-peer connection; the
        callback lands the value in the plane (or fails current waiters)."""
        key = tuple(directive.key)
        if not directive.addr:
            # a channel without a derivable peer address (e.g. a
            # socketpair harness) can book RemoteValues with addr=None;
            # fail the pull cleanly instead of wedging the reader thread
            self.plane.fail_fetch(key, PeerFetchError(
                f"no data-plane address for node {directive.node} "
                f"(d{key[0]}v{key[1]})"))
            return

        def on_done(value, err):
            if err is not None:
                self.plane.fail_fetch(key, err)
                return
            self.plane.resolve_fetch(key, value)

        self.peers.fetch_async(directive.addr, key, directive.token, on_done)

    def _fn_for(self, token: int):
        with self._fn_lock:
            fn = self._fns.get(token)
            if fn is None:
                blob = self._fn_blobs.get(token)
                if not blob:
                    raise RuntimeError(f"fn token {token} unknown and no body sent")
                fn = _loads_fn(blob)
                self._fns[token] = fn
            return fn

    # -- deadline watchdog (DESIGN.md §19) -----------------------------------
    def _arm_deadline(self, slot: int, seconds: float) -> dict:
        """Start a timer that kills this slot's pool worker if the task
        body runs past ``seconds``.  The kill only terminates the process
        — no respawn here: the blocked ``pool.invoke`` observes the EOF
        and performs the single restart, so there is exactly one respawn
        owner and no double-restart race."""
        state = {"fired": False, "active": True}
        lock = self._deadline_locks[slot]

        def fire():
            with lock:
                if not state["active"]:
                    return
                state["fired"] = True
                self.watchdog_kills += 1
                try:
                    self.pool.kill_worker(slot)
                except Exception:
                    pass

        timer = threading.Timer(seconds, fire)
        timer.daemon = True
        state["timer"] = timer
        timer.start()
        return state

    def _disarm_deadline(self, slot: int, state: dict) -> bool:
        """Cancel the watchdog; returns whether it already fired (the
        fire/kill runs under the slot lock, so after this returns False
        no kill can happen)."""
        with self._deadline_locks[slot]:
            state["active"] = False
        state["timer"].cancel()
        return state["fired"]

    def _invoke_with_deadline(self, slot: int, deadline_s: float, fn,
                              args, kwargs, keyed):
        state = self._arm_deadline(slot, deadline_s)
        try:
            result = self.pool.invoke(slot, fn, args, kwargs,
                                      input_keys=keyed)
        except WorkerCrashedError as err:
            if self._disarm_deadline(slot, state):
                raise DeadlineExceededError(
                    f"task exceeded its deadline of {deadline_s}s on node "
                    f"{self.node_id} slot {slot} (worker killed)") from err
            raise
        self._disarm_deadline(slot, state)
        return result

    def _slot_loop(self, slot: int) -> None:
        while not self._done.is_set():
            item = self._slot_queues[slot].get()
            if item is None:
                return
            meta, frames = item
            mid = meta["mid"]
            try:
                fn = self._fn_for(meta["token"])
                inj = chaos.INJECTOR
                if inj is not None:
                    # chaos seam: a wedged worker — the sleep runs INSIDE
                    # the pool worker, so only a deadline can unwedge it
                    hang = inj.roll("hang", f"agent{self.node_id}-s{slot}")
                    if hang is not None:
                        fn = chaos._HangWrapper(fn, hang)
                keyed: Dict[int, Tuple[int, int]] = {}
                args, kwargs = unpack_payload(meta["structure"], frames,
                                              lookup=self.plane.lookup,
                                              store=self.plane.store)
                # keyed ndarray inputs enter the *intra-node* shm plane under
                # the same (data_id, version), deduping across pool workers
                for marker_key, v in _keyed_arrays(meta["structure"], self.plane):
                    keyed[id(v)] = marker_key
                deadline_s = meta.get("deadline_s")
                t_body = time.perf_counter()
                if deadline_s is not None:
                    result = self._invoke_with_deadline(
                        slot, float(deadline_s), fn, args, kwargs, keyed)
                else:
                    result = self.pool.invoke(slot, fn, args, kwargs,
                                              input_keys=keyed)
                # body seconds, free of queue/dispatch latency — the
                # scheduler's replication cost bar (DESIGN.md §20) needs
                # the true producer cost, not its pipeline wait
                dur = time.perf_counter() - t_body
                structure, out_frames, tokens = self._encode_result(
                    result, meta.get("n_out", -1))
                if inj is not None:
                    # chaos seam: a node draining slowly — reply latency
                    inj.sleep("stall", f"agent{self.node_id}-reply")
                self._reply({"op": "done", "mid": mid, "structure": structure,
                             "tokens": tokens, "dur": round(dur, 6)},
                            out_frames)
            except BaseException as err:  # noqa: BLE001 — ships to scheduler
                tb = traceback.format_exc()
                try:
                    enc = pickle.dumps(err, protocol=5)
                except Exception:
                    enc = None
                try:
                    self._reply({"op": "err", "mid": mid, "exc": enc,
                                 "tb": f"{type(err).__name__}|{err}|{tb}"})
                except ConnectionClosed:
                    return
            finally:
                self.pool.task_done()   # reclaim unpublished result segments

    def _new_token(self) -> int:
        with self._token_lock:
            token = self._next_token
            self._next_token += 1
            return token

    def _encode_result(self, result: Any, n_out: int = -1):
        """Encode a ``done`` reply (DESIGN.md §15).

        ``n_out`` is the task's declared output count, which tells us
        which positions of the result are whole *datums*: the root when
        ``n_out <= 1``, the top-level elements when the result is an
        ``n_out``-tuple.  A datum whose frame-eligible bytes reach the
        inline threshold stays HERE, in the token side-table, and the
        reply carries only a ``RemoteRef`` descriptor — the scheduler
        books a ``RemoteValue`` and consumers pull peer-to-peer.  Datums
        below the threshold (``RJAX_INLINE_MAX``) ride the reply inline:
        no frame, no token, no alias round-trip.  Arrays that are not at
        a datum position (or when p2p is off) keep the frame+token path
        so a later ``alias`` can still pin them."""
        frames: List = []
        tokens: List[int] = []

        def enc(o: Any) -> Any:
            if isinstance(o, np.ndarray) and frame_eligible(o, self.inline_max):
                token = self._new_token()
                o = as_c_contiguous(o)
                self.plane.hold(token, o)
                frames.append(array_frame(o))
                tokens.append(token)
                return Frame(len(frames) - 1)
            if isinstance(o, (list, tuple)):
                mapped = [enc(x) for x in o]
                if isinstance(o, tuple):
                    return type(o)(*mapped) if hasattr(o, "_fields") else tuple(mapped)
                return mapped
            if isinstance(o, dict):
                return {k: enc(v) for k, v in o.items()}
            return o

        def enc_datum(o: Any) -> Any:
            if self.p2p:
                nbytes = datum_frame_bytes(o)
                if nbytes >= max(1, self.inline_max):
                    if isinstance(o, np.ndarray):
                        o = as_c_contiguous(o)
                    token = self._new_token()
                    self.plane.hold(token, o)
                    return RemoteRef(token, nbytes)
            return enc(o)

        if n_out > 1 and isinstance(result, (tuple, list)) \
                and len(result) == n_out:
            mapped = [enc_datum(el) for el in result]
            structure: Any = tuple(mapped) if isinstance(result, tuple) \
                else mapped
        else:
            structure = enc_datum(result)
        return structure, frames, tokens


def _keyed_arrays(structure, plane):
    """Yield ``(key, value)`` for every keyed ndarray the decoded payload
    contains (fresh ``Put``s, plane-resident ``Ref``s and peer-pulled
    ``Fetch``es), so the inner pool's shm plane can dedup them by datum
    key.  Structured (tuple/dict) datums are skipped — they cross the
    worker pipe by value."""
    from .protocol import Fetch, Put, Ref

    out = []

    def walk(o):
        if isinstance(o, (Ref, Put, Fetch)):
            v = plane.lookup(o.key)
            if isinstance(v, np.ndarray):
                out.append((o.key, v))
        elif isinstance(o, (list, tuple)):
            for x in o:
                walk(x)
        elif isinstance(o, dict):
            for x in o.values():
                walk(x)

    walk(structure)
    return out


# ------------------------------------------------------------------------ CLI
def build_arg_parser() -> argparse.ArgumentParser:
    """The agent CLI: topology flags here, every tunable knob mirrored
    from :class:`repro.core.config.RuntimeConfig` (one source of truth
    for flag/env/welcome precedence — the flag is the explicit tier)."""
    p = argparse.ArgumentParser(
        prog="python -m repro.cluster.agent",
        description="RJAX cluster node agent: connect to a scheduler and "
                    "serve tasks on a local pool of persistent worker "
                    "processes.")
    p.add_argument("--connect", required=True, metavar="HOST:PORT",
                   help="scheduler address to register with")
    p.add_argument("--workers", type=int, default=2,
                   help="worker processes in this node's pool (default 2)")
    p.add_argument("--node-id", type=int, default=None,
                   help="node ordinal (assigned by the scheduler if omitted)")
    add_agent_cli_args(p)   # --memory-budget / --mp-context / --inline-max
    return p                # / --heartbeat-s, docs from RuntimeConfig


def main(argv=None) -> int:
    args = build_arg_parser().parse_args(argv)

    # SIGTERM's default action skips all cleanup, which would orphan the
    # daemon pool workers (they inherit pipes/stdio and can linger
    # forever).  Raise SystemExit instead so ``run()``'s finally block
    # shuts the pool down politely.
    import signal

    def _terminate(signum, frame):
        raise SystemExit(0)

    signal.signal(signal.SIGTERM, _terminate)

    agent = NodeAgent(args.connect, args.workers, node_id=args.node_id,
                      mp_context=args.mp_context,
                      memory_budget=args.memory_budget,
                      heartbeat_s=args.heartbeat_s,
                      inline_max=args.inline_max)
    try:
        agent.run()
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
