"""Length-prefixed wire protocol for the cluster data plane (DESIGN.md §12).

A *message* is a batch of frames::

    [4s magic "RJW1"][u64 n_frames][u64 len_0 ... u64 len_{n-1}]
    [frame_0][frame_1]...[frame_{n-1}]

Frame 0 is always pickled metadata (a dict).  Frames 1.. are ndarray
payloads in the ``raw``-codec layout from :mod:`repro.core.serialization`
(packed header + contiguous buffer).  On send, the array's own buffer is
handed to ``sendall`` as a memoryview — no intermediate serialized copy
(non-contiguous inputs are copied contiguous first, the codec's
copy-on-encode rule).  On receive, each frame lands in one freshly
allocated buffer and is reconstructed zero-copy with ``np.frombuffer``.

All length fields are unsigned 64-bit, so single frames and messages
beyond 4 GiB are representable (dask's comm core made the same choice
after real workloads hit the u32 ceiling).

Structure packing (``pack_payload`` / ``unpack_payload``) turns a nested
args/kwargs structure into (picklable metadata, frame list) using four
markers:

* ``Frame(i)``     — the value is ndarray frame *i* of the message;
* ``Ref(key)``     — the value is already cached in the receiving node's
                     object plane under ``(data_id, version)``;
* ``Put(key, v)``  — cache ``v`` (a structure possibly containing
                     ``Frame`` markers) under ``key``, then use it — the
                     send-once half of the send-once/reuse-many property.
                     Keying happens at the *datum* level: a tuple-valued
                     datum is one ``Put`` whose inner arrays ride frames,
                     so structured results get the same caching as plain
                     ndarrays;
* ``Fetch(key,…)`` — the value is node-resident on a *peer* (DESIGN.md
                     §15): the receiver pulls it straight from the
                     producing agent's data plane instead of the
                     scheduler shipping bytes it does not hold.

``RemoteRef`` is the result-side descriptor: a ``done`` reply whose datum
stays resident on the producing node carries ``RemoteRef(token, nbytes)``
instead of frames — the scheduler records a
:class:`~repro.core.futures.RemoteValue` placeholder and only metadata
crossed its link.
"""
from __future__ import annotations

import os
import pickle
import struct
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.serialization import _pack_header, _unpack_header, as_c_contiguous
from . import chaos
from ..core.config import parse_bool

MAGIC = b"RJW1"
_HEAD = struct.Struct("<4sQ")        # magic, n_frames
_U64 = struct.Struct("<Q")
_CRC = struct.Struct("<I")           # per-frame CRC32 trailer

# RJAX_WIRE_CHECKSUM: append a CRC32 trailer to every out-of-band frame
# (frames 1..; frame 0's pickle already fails loudly on corruption) and
# verify on receive — a flipped bit surfaces as ChecksumError, a
# retryable transfer error, never silent data corruption.  Read at
# import (agents inherit the scheduler's environment); both ends of a
# link MUST agree, which the single-env LocalCluster guarantees.
WIRE_CHECKSUM = parse_bool(os.environ.get("RJAX_WIRE_CHECKSUM"))


def refresh_checksum() -> bool:
    """Re-read ``RJAX_WIRE_CHECKSUM`` (tests toggle it mid-process)."""
    global WIRE_CHECKSUM
    WIRE_CHECKSUM = parse_bool(os.environ.get("RJAX_WIRE_CHECKSUM"))
    return WIRE_CHECKSUM

# frames are for raw-codec-eligible ndarrays; anything smaller than this
# is cheaper pickled inline in the metadata frame (keyed data is framed
# regardless — it gets cached and reused on the far side)
WIRE_MIN_FRAME_BYTES = 1024

# result datums whose frame-eligible bytes stay below this ride the `done`
# reply inline (one pickle, no token, no alias round-trip, no potential
# peer fetch); at or above it they stay node-resident and the reply
# carries only a RemoteRef descriptor (DESIGN.md §15)
DEFAULT_INLINE_MAX = 8192


def inline_max_from_env(explicit=None) -> int:
    """Resolve the ``RJAX_INLINE_MAX`` knob (0 = inline nothing, always
    defer/frame — the pre-§15 result encoding)."""
    if explicit is not None:
        return max(0, int(explicit))
    return max(0, int(os.environ.get("RJAX_INLINE_MAX", DEFAULT_INLINE_MAX)))

# messages whose total size (header + metadata + all frames) is at or
# below this are copied into ONE contiguous buffer and written with a
# single sendall — with TCP_NODELAY on, each sendall is its own packet,
# so a small task message with a handful of little Ref/Put frames would
# otherwise cost one packet per part (DESIGN.md §14).  Large frames keep
# the zero-copy path: their buffers go to sendall directly.
WIRE_COALESCE_MAX = int(os.environ.get("RJAX_WIRE_COALESCE", 65536))


class ConnectionClosed(ConnectionError):
    """The peer went away.  ``mid_message`` distinguishes a clean close
    between messages from a cut mid-frame (both are fatal for the
    connection; the executor surfaces either as a retryable
    ``WorkerCrashedError``)."""

    def __init__(self, message: str = "connection closed", mid_message: bool = False):
        super().__init__(message)
        self.mid_message = mid_message


class ChecksumError(ConnectionClosed):
    """A frame's CRC32 trailer did not match its payload (wire
    corruption).  A :class:`ConnectionClosed` subclass: the stream can no
    longer be trusted, so the connection is torn down and the transfer
    retried through the normal recovery paths (``WorkerCrashedError`` /
    ``PeerFetchError``) — corruption is loud, never silent."""

    def __init__(self, message: str = "frame checksum mismatch"):
        super().__init__(message, mid_message=True)


def frame_crc(parts) -> int:
    """CRC32 over one frame's buffer parts (send side streams the same
    bytes the receiver will hash as one contiguous buffer)."""
    import zlib
    crc = 0
    for p in parts:
        crc = zlib.crc32(p, crc)
    return crc & 0xFFFFFFFF


def _chaos_bitflip(frames: List[List]) -> List[List]:
    """The ``bitflip`` chaos seam: corrupt one byte of the first
    out-of-band frame in a COPY (the parts are memoryviews over live
    arrays — the sender's data must stay intact)."""
    inj = chaos.INJECTOR
    if inj is None or not frames:
        return frames
    if inj.roll("bitflip", "wire") is None:
        return frames
    blob = bytearray(b"".join(bytes(p) for p in frames[0]))
    if blob:
        blob[len(blob) // 2] ^= 0x01
    out = list(frames)
    out[0] = [bytes(blob)]
    return out


# ------------------------------------------------------------------ raw I/O
def recv_exactly(sock, n: int, mid_message: bool = True) -> memoryview:
    """Read exactly ``n`` bytes, tolerating arbitrarily short reads."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        try:
            k = sock.recv_into(view[got:])
        except (ConnectionResetError, BrokenPipeError, OSError) as err:
            raise ConnectionClosed(str(err) or "connection reset",
                                   mid_message=mid_message or got > 0) from err
        if k == 0:
            raise ConnectionClosed("peer closed the connection",
                                   mid_message=mid_message or got > 0)
        got += k
    return view


def send_msg(sock, meta: dict, frames: Sequence[Sequence] = ()) -> None:
    """Send one message.  Each entry of ``frames`` is a list of buffer
    parts (bytes/memoryview) forming one frame.

    Small messages (≤ ``WIRE_COALESCE_MAX`` total) are coalesced into one
    buffer and one ``sendall`` — one syscall, one packet — which is the
    common shape for pipelined task requests whose inputs are all ``Ref``
    markers or small ``Put`` frames.  Past the threshold, the header and
    metadata still go out in one write but each large frame part is handed
    to ``sendall`` straight from the array's own buffer — no intermediate
    serialized copy."""
    meta_blob = pickle.dumps(meta, protocol=5)
    if WIRE_CHECKSUM or chaos.INJECTOR is not None:
        frames = list(frames)
        # the trailer hashes the true payload BEFORE the bitflip seam
        # corrupts it — corruption happens "on the wire", after checksum
        trailers = [_CRC.pack(frame_crc(f)) for f in frames] \
            if WIRE_CHECKSUM else None
        frames = _chaos_bitflip(frames)
        if trailers is not None:
            frames = [list(f) + [t] for f, t in zip(frames, trailers)]
    lengths = [len(meta_blob)] + [sum(len(p) for p in f) for f in frames]
    header = _HEAD.pack(MAGIC, len(lengths)) + b"".join(_U64.pack(n) for n in lengths)
    total = len(header) + sum(lengths)
    try:
        if total <= WIRE_COALESCE_MAX:
            buf = bytearray(header)
            buf += meta_blob
            for f in frames:
                for part in f:
                    buf += part
            sock.sendall(buf)
            return
        sock.sendall(header + meta_blob)
        for f in frames:
            for part in f:
                sock.sendall(part)
    except (ConnectionResetError, BrokenPipeError, OSError) as err:
        raise ConnectionClosed(str(err) or "send failed", mid_message=True) from err


def recv_msg(sock) -> Tuple[dict, List[memoryview]]:
    """Receive one message: ``(metadata, [frame, ...])``.  Frames come back
    as memoryviews over freshly-owned buffers (safe to keep)."""
    head = recv_exactly(sock, _HEAD.size, mid_message=False)
    magic, n_frames = _HEAD.unpack(head)
    if magic != MAGIC:
        raise ConnectionClosed(f"bad magic {bytes(magic)!r} on wire", mid_message=True)
    lens_buf = recv_exactly(sock, 8 * n_frames)
    lengths = struct.unpack(f"<{n_frames}Q", lens_buf)
    meta = pickle.loads(recv_exactly(sock, lengths[0]))
    frames = [recv_exactly(sock, n) for n in lengths[1:]]
    if WIRE_CHECKSUM:
        frames = [verify_frame(f) for f in frames]
    return meta, frames


def verify_frame(frame: memoryview) -> memoryview:
    """Strip and verify a frame's CRC32 trailer (checksummed wire)."""
    if len(frame) < _CRC.size:
        raise ChecksumError("frame shorter than its CRC32 trailer")
    payload, trailer = frame[:-_CRC.size], frame[-_CRC.size:]
    if frame_crc((payload,)) != _CRC.unpack(trailer)[0]:
        raise ChecksumError()
    return payload


# ------------------------------------------------------------ ndarray frames
def array_frame(arr: np.ndarray) -> List:
    """An ndarray as raw-codec frame parts: ``[packed header, buffer]``.
    Copy-on-encode for non-contiguous inputs (sliced/Fortran/0-d views);
    contiguous arrays ship their own buffer."""
    arr = as_c_contiguous(arr)
    return [_pack_header(arr), memoryview(arr).cast("B")]


def frame_to_array(frame) -> np.ndarray:
    """Zero-copy reconstruction (the RMVL deserialize-side property).
    Accepts a received contiguous buffer, or an unsent part-list straight
    from :func:`array_frame` (loopback/testing)."""
    if isinstance(frame, (list, tuple)):
        frame = memoryview(b"".join(bytes(p) for p in frame))
    dtype, shape, off = _unpack_header(frame)
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    arr = np.frombuffer(frame, dtype=dtype, offset=off, count=count).reshape(shape)
    arr.flags.writeable = False
    return arr


def frame_eligible(arr: np.ndarray, min_bytes: int = 0) -> bool:
    if arr.dtype.hasobject or arr.nbytes < min_bytes:
        return False
    try:
        _pack_header(arr)
        return True
    except TypeError:  # dtype outside the raw-codec table
        return False


def _sum_array_bytes(value: Any, pred: Callable[[np.ndarray], bool]) -> int:
    """Sum ``nbytes`` of the ndarrays inside a datum value that satisfy
    ``pred`` — the one structure walker behind both byte ledgers below
    (a container type added here is counted consistently everywhere)."""
    total = 0
    stack = [value]
    while stack:
        o = stack.pop()
        if isinstance(o, np.ndarray):
            if pred(o):
                total += int(o.nbytes)
        elif isinstance(o, (list, tuple)):
            stack.extend(o)
        elif isinstance(o, dict):
            stack.extend(o.values())
    return total


def datum_frame_bytes(value: Any) -> int:
    """Total frame-eligible ndarray bytes inside one datum value — the
    size that decides inline-vs-node-resident result encoding."""
    return _sum_array_bytes(value, frame_eligible)


# -------------------------------------------------------- structure markers
class Frame:
    """Placeholder: the value is ndarray frame ``i`` of this message."""

    __slots__ = ("i",)

    def __init__(self, i: int):
        self.i = i

    def __getstate__(self):
        return self.i

    def __setstate__(self, state):
        self.i = state


class Ref:
    """Placeholder: the value is plane-resident under ``key`` on the
    receiving node (the reuse-many half)."""

    __slots__ = ("key",)

    def __init__(self, key: Tuple[int, int]):
        self.key = key

    def __getstate__(self):
        return self.key

    def __setstate__(self, state):
        self.key = state


class Put:
    """Placeholder: cache ``value`` under ``key`` on the receiving node,
    then use it (``value`` may itself be a ``Frame``)."""

    __slots__ = ("key", "value")

    def __init__(self, key: Tuple[int, int], value: Any):
        self.key = key
        self.value = value

    def __getstate__(self):
        return (self.key, self.value)

    def __setstate__(self, state):
        self.key, self.value = state


class Fetch:
    """Placeholder: the value lives on peer ``node`` (reachable at
    ``addr``, a ``host:port`` data-plane address) under ``key`` — or still
    under result ``token`` if the producer has not yet processed its
    ``alias``.  The receiver pulls it peer-to-peer (DESIGN.md §15)."""

    __slots__ = ("key", "token", "node", "addr", "nbytes")

    def __init__(self, key: Tuple[int, int], token: Optional[int],
                 node: int, addr: str, nbytes: int):
        self.key = key
        self.token = token
        self.node = node
        self.addr = addr
        self.nbytes = nbytes

    def __getstate__(self):
        return (self.key, self.token, self.node, self.addr, self.nbytes)

    def __setstate__(self, state):
        self.key, self.token, self.node, self.addr, self.nbytes = state


class RemoteRef:
    """Result-side descriptor: the datum stays resident on the producing
    node under result ``token``; only (token, nbytes) cross the
    scheduler's link."""

    __slots__ = ("token", "nbytes")

    def __init__(self, token: int, nbytes: int):
        self.token = token
        self.nbytes = nbytes

    def __getstate__(self):
        return (self.token, self.nbytes)

    def __setstate__(self, state):
        self.token, self.nbytes = state


_MARKERS = (Frame, Ref, Put, Fetch, RemoteRef)


def struct_nbytes(value: Any) -> int:
    """Sum of ndarray bytes inside a datum value (ledger accounting)."""
    return _sum_array_bytes(value, lambda _arr: True)


def pack_payload(
    obj: Any,
    input_keys: Optional[Dict[int, Tuple[int, int]]] = None,
    resident: Optional[set] = None,
    peer_sources: Optional[Dict[Tuple[int, int], Tuple[int, str, int]]] = None,
) -> Tuple[Any, List, Dict[str, Any]]:
    """Encode a nested structure for the wire.

    Keying is at the *datum* level (``id(value)`` in ``input_keys`` —
    ndarray, tuple, list or dict values straight from the object store):
    a keyed datum becomes ``Ref`` when ``key`` is in ``resident`` (the
    receiver already holds it), ``Fetch`` when the datum is a
    :class:`~repro.core.futures.RemoteValue` resident on a peer node
    (the receiver pulls it peer-to-peer, DESIGN.md §15), and ``Put``
    otherwise — the ``Put`` payload is the datum's structure with its
    raw-eligible ndarrays as out-of-band frames.  Unkeyed large arrays
    ride anonymous frames; everything else stays inline for frame 0's
    pickle.  Returns ``(structure, frames, info)`` where ``info`` reports
    the ``Put`` keys/bytes, the ``Fetch`` keys/bytes (the peer data-plane
    ledger) and the ``Ref`` count (dedup wins).

    ``peer_sources`` maps keys of *scheduler-resident* datums that some
    agent already holds to ``(node, addr, nbytes)``: instead of shipping
    a second ``Put`` of the same bytes over the scheduler link, the
    receiver is directed to pull them from that agent by key
    (a ``Fetch`` with no token — the broadcast-residue fix, DESIGN.md
    §16).
    """
    from ..core.futures import RemoteValue
    input_keys = input_keys or {}
    resident = resident if resident is not None else set()
    peer_sources = peer_sources or {}
    frames: List = []
    info = {"put_keys": [], "put_bytes": 0, "put_sizes": {}, "refs": 0,
            "fetch_keys": [], "fetch_bytes": 0}
    put_in_msg: set = set()   # intra-message dedup: same datum twice = one Put

    def frame_of(arr: np.ndarray) -> Frame:
        frames.append(array_frame(arr))
        return Frame(len(frames) - 1)

    def enc_value(o: Any) -> Any:
        """A keyed datum's payload: inner arrays ride frames, no keying
        (store values never nest other datums)."""
        if isinstance(o, np.ndarray):
            if frame_eligible(o) and o.nbytes >= WIRE_MIN_FRAME_BYTES:
                return frame_of(o)
            return o
        if isinstance(o, (list, tuple)):
            mapped = [enc_value(x) for x in o]
            if isinstance(o, tuple):
                return type(o)(*mapped) if hasattr(o, "_fields") else tuple(mapped)
            return mapped
        if isinstance(o, dict):
            return {k: enc_value(v) for k, v in o.items()}
        return o

    def walk(o: Any) -> Any:
        if isinstance(o, RemoteValue):
            key = input_keys.get(id(o))
            if key is None:
                key = o.key
            if key is None:
                raise TypeError(
                    f"{o!r} outside the object store cannot cross the wire")
            if key in resident or key in put_in_msg:
                info["refs"] += 1
                return Ref(key)
            put_in_msg.add(key)
            info["fetch_keys"].append(key)
            info["fetch_bytes"] += int(o.nbytes)
            return Fetch(key, o.token, o.node, o.addr, int(o.nbytes))
        if isinstance(o, (np.ndarray, list, tuple, dict)):
            key = input_keys.get(id(o))
            if key is not None:
                if key in resident or key in put_in_msg:
                    info["refs"] += 1
                    return Ref(key)
                src = peer_sources.get(key)
                if src is not None:
                    node, addr, nbytes = src
                    put_in_msg.add(key)
                    info["fetch_keys"].append(key)
                    info["fetch_bytes"] += int(nbytes)
                    return Fetch(key, None, node, addr, int(nbytes))
                put_in_msg.add(key)
                nb = struct_nbytes(o)
                info["put_keys"].append(key)
                info["put_bytes"] += nb
                info["put_sizes"][key] = nb
                return Put(key, enc_value(o))
            if isinstance(o, np.ndarray):
                if frame_eligible(o) and o.nbytes >= WIRE_MIN_FRAME_BYTES:
                    return frame_of(o)
                return o
            if isinstance(o, (list, tuple)):
                mapped = [walk(x) for x in o]
                if isinstance(o, tuple):
                    return type(o)(*mapped) if hasattr(o, "_fields") \
                        else tuple(mapped)
                return mapped
            return {k: walk(v) for k, v in o.items()}
        return o

    return walk(obj), frames, info


def unpack_payload(
    structure: Any,
    frames: Sequence[memoryview],
    lookup: Optional[Callable[[Tuple[int, int]], Any]] = None,
    store: Optional[Callable[[Tuple[int, int], Any], None]] = None,
) -> Any:
    """Decode a ``pack_payload`` structure.  ``lookup(key)`` resolves
    ``Ref`` markers from the local plane; ``store(key, value)`` caches
    ``Put`` payloads into it."""

    def walk(o: Any) -> Any:
        if isinstance(o, Frame):
            return frame_to_array(frames[o.i])
        if isinstance(o, (Ref, Fetch)):
            # a Fetch was resolved (or registered as pending) when the
            # reader pre-stored this message; by now the plane either has
            # the value or blocks the lookup until the peer pull lands
            if lookup is None:
                raise ValueError(f"{type(o).__name__} marker but no plane "
                                 "lookup provided")
            return lookup(o.key)
        if isinstance(o, Put):
            if lookup is not None:
                # already cached (e.g. the receiver pre-stored Puts on its
                # reader thread): reuse THAT object so identity-keyed
                # downstream dedup sees one value per datum.  Missing may
                # surface as KeyError or None (dict.get-style lookups);
                # cached Put values are ndarrays, never None.
                try:
                    cached = lookup(o.key)
                except KeyError:
                    cached = None
                if cached is not None:
                    return cached
            v = walk(o.value)
            if store is not None:
                store(o.key, v)
            return v
        if isinstance(o, (list, tuple)):
            mapped = [walk(x) for x in o]
            if isinstance(o, tuple):
                return type(o)(*mapped) if hasattr(o, "_fields") else tuple(mapped)
            return mapped
        if isinstance(o, dict):
            return {k: walk(v) for k, v in o.items()}
        return o

    return walk(structure)
