"""Cluster harnesses: who listens, who spawns agents (DESIGN.md §12).

The scheduler side always *listens*; agents always *dial in* (the
``--connect`` flag), because in real deployments the scheduler's address
is the one thing every node knows.  ``LocalCluster`` packages that for a
single machine: bind an ephemeral localhost port, spawn N agent
subprocesses pointed at it, and hand the listener to the cluster executor
so it can accept the registrations.  With ``spawn=False`` it degrades to
a plain listener for externally-started agents (real multi-node: run
``python -m repro.cluster.agent --connect HOST:PORT --workers N`` on each
node yourself).
"""
from __future__ import annotations

import os
import queue
import secrets
import socket
import subprocess
import sys
import threading
import time
from typing import Callable, Dict, List, Optional

from .channel import AgentChannel
from .protocol import recv_msg, send_msg


def _repro_pythonpath() -> str:
    """A PYTHONPATH under which agent subprocesses can import ``repro``
    AND resolve by-reference pickled task functions from the caller's
    modules (e.g. a test module pytest put on ``sys.path``) — the full
    parent search path is propagated, deduplicated, order-preserved."""
    import repro
    # repro is a namespace package: __path__[0] is .../src/repro
    root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    parts = [root] + [p for p in sys.path if p]
    return os.pathsep.join(dict.fromkeys(parts))


class LocalCluster:
    """Spawn-and-listen harness for N node agents on this machine.

    Usage::

        with LocalCluster(n_agents=2, workers_per_node=2) as cluster:
            rt = api.runtime_start(backend="cluster", cluster=cluster)
            ...
            api.runtime_stop()   # also tears the agents down

    The runtime's cluster executor calls :meth:`accept_agents` during
    startup and :meth:`respawn` when an agent dies.
    """

    def __init__(self, n_agents: int = 2, workers_per_node: int = 2,
                 host: str = "127.0.0.1", port: int = 0, spawn: bool = True,
                 agent_args: Optional[List[str]] = None):
        self.n_agents = int(n_agents)
        self.workers_per_node = int(workers_per_node)
        self.spawn = spawn
        # per-node object-plane budget, forwarded in the welcome message;
        # the runtime sets this from its memory_budget knob before the
        # executor accepts agents (an agent's own --memory-budget wins)
        self.memory_budget: Optional[int] = None
        # peer-to-peer data plane (DESIGN.md §15): the executor sets these
        # from RJAX_P2P / RJAX_INLINE_MAX before accepting agents;
        # forwarded in the welcome so agents on OTHER hosts (which never
        # saw the scheduler's environment) apply the same result-encoding
        # policy.  An agent's own RJAX_INLINE_MAX wins, like --memory-budget
        self.p2p: bool = True
        self.inline_max: Optional[int] = None
        # telemetry heartbeat cadence (DESIGN.md §17), forwarded in the
        # welcome like the knobs above; an agent's own RJAX_HEARTBEAT_S
        # wins.  None = let agents use their default
        self.heartbeat_s: Optional[float] = None
        # session resumption (DESIGN.md §20): the executor sets the grace
        # window before accepting agents; each welcome carries a fresh
        # session token the agent presents when it re-dials after a
        # transient disconnect.  0/None = resumption disabled.
        self.reconnect_grace_s: Optional[float] = None
        self.session_tokens: Dict[int, str] = {}   # node_id -> current token
        # how accepted/respawned connections become channel objects: the
        # async control plane (DESIGN.md §18) swaps in AsyncAgentChannel
        # bound to its IOLoop; the default is the legacy thread-per-
        # channel reader
        self.channel_factory = AgentChannel
        self._lock = threading.Lock()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(self.n_agents * 2 + 2)
        self.address = "%s:%d" % self._listener.getsockname()[:2]
        self._agent_args = list(agent_args or ())
        self._procs: List[Optional[subprocess.Popen]] = [None] * self.n_agents
        self._closed = False
        # background acceptor (started by the executor once the initial
        # agents are in): routes resume hellos to the executor's handler
        # and parks fresh hellos for respawn() to claim
        self._acceptor: Optional[threading.Thread] = None
        self._fresh_q: "queue.Queue" = queue.Queue()
        if spawn:
            for i in range(self.n_agents):
                self._spawn(i)

    # ------------------------------------------------------------- spawning
    def _spawn(self, i: int) -> None:
        env = dict(os.environ, PYTHONPATH=_repro_pythonpath())
        cmd = [sys.executable, "-m", "repro.cluster.agent",
               "--connect", self.address,
               "--workers", str(self.workers_per_node),
               "--node-id", str(i), *self._agent_args]
        self._procs[i] = subprocess.Popen(cmd, env=env)

    @property
    def can_respawn(self) -> bool:
        return self.spawn and not self._closed

    # ----------------------------------------------------------- accepting
    def _accept_one(self, timeout: float):
        self._listener.settimeout(timeout)
        try:
            conn, _ = self._listener.accept()
        except socket.timeout:
            raise TimeoutError(
                f"no agent registered with {self.address} within {timeout}s")
        finally:
            self._listener.settimeout(None)
        # the handshake gets the same deadline: a connected-but-silent peer
        # (port scanner, stalled agent) must not hang registration forever
        conn.settimeout(timeout)
        try:
            hello, _ = recv_msg(conn)
        except Exception as err:
            conn.close()
            raise ConnectionError(
                f"agent handshake on {self.address} failed or timed out "
                f"after {timeout}s: {err}") from err
        conn.settimeout(None)
        if hello.get("op") != "hello":
            conn.close()
            raise ConnectionError(f"bad registration message: {hello}")
        return conn, hello

    def _welcome_payload(self, nid: int) -> dict:
        """Mint a fresh session for node ``nid`` and build its welcome.
        A respawned process gets a NEW token — the old session (and any
        reconnect attempt still carrying its token) is dead."""
        tok = secrets.token_hex(8)
        self.session_tokens[nid] = tok
        return {"op": "welcome", "node_id": nid,
                "memory_budget": self.memory_budget,
                "p2p": self.p2p, "inline_max": self.inline_max,
                "heartbeat_s": self.heartbeat_s,
                "session": tok, "epoch": 0,
                "reconnect_grace_s": self.reconnect_grace_s}

    def accept_agents(self, timeout: float = 60.0) -> List[AgentChannel]:
        """Accept ``n_agents`` registrations; returns channels ordered by
        node id.  Defensive against externally-launched agents
        (``spawn=False``): a wrong ``--workers`` is rejected outright (the
        scheduler's slot math depends on it), and an out-of-range or
        duplicate ``--node-id`` is treated as unassigned."""
        raw = [self._accept_one(timeout) for _ in range(self.n_agents)]
        for conn, hello in raw:
            if int(hello.get("workers", -1)) != self.workers_per_node:
                msg = (f"agent pid={hello.get('pid')} registered with "
                       f"--workers {hello.get('workers')} but this cluster "
                       f"requires workers_per_node={self.workers_per_node}")
                for c, _ in raw:
                    c.close()
                raise ConnectionError(msg)
        taken = set()
        for _, h in raw:   # claim valid, non-duplicate explicit node ids
            nid = h.get("node_id")
            if nid is not None and 0 <= nid < self.n_agents and nid not in taken:
                taken.add(nid)
            else:
                h["node_id"] = None
        free = iter(i for i in range(self.n_agents) if i not in taken)
        channels: List[Optional[AgentChannel]] = [None] * self.n_agents
        for conn, hello in raw:
            nid = hello.get("node_id")
            if nid is None:
                nid = next(free)
            send_msg(conn, self._welcome_payload(nid))
            channels[nid] = self.channel_factory(conn, nid, hello)
        return channels

    # -------------------------------------------------- session resumption
    def start_acceptor(self, resume_handler: Callable) -> None:
        """Run a background accept loop (DESIGN.md §20): resume hellos —
        those carrying a ``resume`` token — go to ``resume_handler(conn,
        hello)``; fresh registrations are queued for :meth:`respawn` to
        claim.  Idempotent; the thread exits when the listener closes."""
        if self._acceptor is not None or self._closed:
            return

        def loop():
            while not self._closed:
                try:
                    conn, _ = self._listener.accept()
                except OSError:
                    return   # listener closed: shutdown
                try:
                    conn.settimeout(10.0)
                    hello, _ = recv_msg(conn)
                    conn.settimeout(None)
                    if hello.get("op") != "hello":
                        raise ConnectionError(f"bad hello: {hello}")
                except Exception:
                    conn.close()
                    continue
                if hello.get("resume"):
                    try:
                        resume_handler(conn, hello)
                    except Exception:
                        conn.close()
                else:
                    self._fresh_q.put((conn, hello))

        self._acceptor = threading.Thread(target=loop, daemon=True,
                                          name="cluster-acceptor")
        self._acceptor.start()

    def respawn(self, i: int, timeout: float = 60.0) -> AgentChannel:
        """Replace a dead agent: kill leftovers, spawn a fresh process,
        accept its registration."""
        with self._lock:
            if not self.can_respawn:
                raise RuntimeError("cluster cannot respawn agents")
            proc = self._procs[i]
            if proc is not None and proc.poll() is None:
                proc.kill()
                proc.wait(timeout=5.0)
            self._spawn(i)
            if self._acceptor is not None:
                # the background acceptor owns the listener now; fresh
                # registrations arrive via its queue (respawns are
                # serialized under self._lock, so the next fresh hello is
                # ours)
                try:
                    conn, hello = self._fresh_q.get(timeout=timeout)
                except queue.Empty:
                    raise TimeoutError(
                        f"no agent registered with {self.address} "
                        f"within {timeout}s")
            else:
                conn, hello = self._accept_one(timeout)
            send_msg(conn, self._welcome_payload(i))
            return self.channel_factory(conn, i, hello)

    # ------------------------------------------------------------ teardown
    def shutdown(self, timeout: float = 5.0) -> None:
        if self._closed:
            return
        self._closed = True
        # grace period: the executor posts "exit" before calling us, so
        # agents are usually mid-teardown — let them finish cleanly (a
        # SIGTERM racing the pool shutdown risks leaving worker processes
        # behind on platforms where the signal wins)
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            if all(p is None or p.poll() is not None for p in self._procs):
                break
            time.sleep(0.05)
        for p in self._procs:
            if p is None or p.poll() is not None:
                continue
            try:
                p.terminate()
            except OSError:
                pass
        for p in self._procs:
            if p is None:
                continue
            try:
                p.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=2.0)
        try:
            self._listener.close()
        except OSError:
            pass

    def __enter__(self) -> "LocalCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
