"""repro.cluster — real multi-node execution over TCP (DESIGN.md §12).

The package has five pieces:

* :mod:`repro.cluster.protocol` — the length-prefixed wire format: message
  metadata rides pickle, ndarrays ride separate raw-codec frames (the
  ``serialization.py`` header format), so arrays cross the socket without
  an intermediate copy on the send side.
* :mod:`repro.cluster.channel`  — the scheduler-side multiplexed connection
  to one node agent (request/response routing by message id, one reader
  thread per agent).
* :mod:`repro.cluster.agent`    — the node agent server
  (``python -m repro.cluster.agent --connect HOST:PORT --workers N``): runs
  task bodies on a PR-1 process-executor pool and caches received data in a
  node-local object plane keyed by ``(data_id, version)``.
* :mod:`repro.cluster.peer`     — the peer-to-peer data plane (DESIGN.md
  §15): every agent serves its node plane over an ephemeral data port,
  and consumers (other agents, or the scheduler on gather) pull
  node-resident results through pooled per-peer connections.
* :mod:`repro.cluster.cluster`  — ``LocalCluster``, a harness that spawns N
  agents on localhost so tests/CI/benchmarks exercise the real multi-node
  path on one machine.

The scheduler-side executor backend lives in
:class:`repro.core.executors.ClusterExecutor` (``backend="cluster"``).
"""
from .cluster import LocalCluster  # noqa: F401
from .peer import PeerFetchError, PeerPool  # noqa: F401
from .protocol import ConnectionClosed  # noqa: F401
