"""Single-threaded asyncio control plane (DESIGN.md §18).

The scheduler used to spend one reader **thread** per agent channel plus
one dispatcher thread per worker slot — O(agents + slots) threads whose
wakeup latency bounded dispatch at scale.  This module replaces the
per-channel thread with one :class:`IOLoop` (a single daemon thread
running an asyncio event loop) that owns a reader/writer **coroutine
pair** per agent socket:

* the *writer* drains a per-channel send queue, coalescing consecutive
  small messages (≤ ``RJAX_WIRE_COALESCE`` each) into one socket write —
  the batched-stream idiom — and falling back to per-part zero-copy
  ``sock_sendall`` for large framed payloads;
* the *reader* parses the §12 wire format with exact-size
  ``sock_recv_into`` reads (frames land in freshly allocated buffers,
  no intermediate copies) and routes completions **inline on the loop**:
  mid-less pushes to ``on_push`` (§17 heartbeats), callback slots
  directly, blocking requests via an event bridge.

Protocol invariants the loop *enforces* (formerly emergent from thread
structure):

* **wire FIFO / Put-before-Ref (§12)** — each channel has exactly one
  send queue drained by exactly one writer coroutine, so messages leave
  in enqueue order no matter how many threads enqueue; the executor's
  per-agent order lock pins residency marks to enqueue order, and the
  queue does the rest.
* **credit accounting (§14)** — completions release credits on the loop
  and re-enter the dispatch pump inline, so a freed credit is reused
  without a thread wakeup.
* **exactly-once completion (§14/§15)** — a registered mid resolves
  exactly once: with the reply, or with ``ConnectionClosed`` when the
  channel fails; callback draining on failure happens OFF the loop (a
  one-shot thread) so restart work can never stall the other channels.

``AsyncAgentChannel`` is interface-compatible with
``channel.AgentChannel`` (the legacy per-thread channel, kept for
``RJAX_CONTROL_PLANE=threads``): same constructor shape via
``LocalCluster.channel_factory``, same ``request`` / ``request_async`` /
``request_cb`` / ``post`` / ``on_push`` / ``on_close`` surface.
"""
from __future__ import annotations

import asyncio
import pickle
import socket
import threading
import traceback
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import chaos, protocol
from .protocol import ConnectionClosed

__all__ = ["IOLoop", "AsyncAgentChannel"]


class IOLoop:
    """An asyncio event loop confined to one daemon thread.

    The loop thread is the *only* place channel coroutines run;
    schedule work onto it from any thread with :meth:`call_soon`.
    """

    def __init__(self, name: str = "rjax-io"):
        self._loop = asyncio.new_event_loop()
        self._started = threading.Event()
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()
        self._started.wait()

    def _run(self) -> None:
        asyncio.set_event_loop(self._loop)
        self._loop.call_soon(self._started.set)
        try:
            self._loop.run_forever()
        finally:
            pending = asyncio.all_tasks(self._loop)
            for task in pending:
                task.cancel()
            if pending:
                try:
                    self._loop.run_until_complete(asyncio.gather(
                        *pending, return_exceptions=True))
                except BaseException:
                    pass
            try:
                self._loop.close()
            except BaseException:
                pass

    @property
    def loop(self) -> asyncio.AbstractEventLoop:
        return self._loop

    def in_loop(self) -> bool:
        return threading.current_thread() is self._thread

    def call_soon(self, cb: Callable, *args: Any) -> bool:
        """Run ``cb(*args)`` on the loop thread; False if the loop is
        already gone (shutdown races are the caller's no-op)."""
        if self._closed:
            return False
        if self.in_loop():
            cb(*args)
            return True
        try:
            self._loop.call_soon_threadsafe(cb, *args)
            return True
        except RuntimeError:
            return False

    def stop(self, timeout: float = 2.0) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._loop.call_soon_threadsafe(self._loop.stop)
        except RuntimeError:
            pass
        if not self.in_loop():
            self._thread.join(timeout)


class _Slot:
    """One in-flight request: either a blocking waiter (event bridge)
    or a completion callback routed inline on the loop."""
    __slots__ = ("event", "meta", "frames", "error", "callback")

    def __init__(self, callback=None):
        self.event = None if callback is not None else threading.Event()
        self.meta = None
        self.frames = None
        self.error: Optional[BaseException] = None
        self.callback = callback


class AsyncAgentChannel:
    """One agent connection, serviced by coroutines on a shared IOLoop.

    Thread-free per channel: senders encode on their own thread and
    enqueue; the loop's writer coroutine owns the socket's write side,
    the reader coroutine owns the read side and routes completions.
    """

    def __init__(self, sock: socket.socket, node_id: int, hello: dict,
                 io: IOLoop, start_mid: int = 1):
        self.sock = sock
        self.node_id = node_id
        self.hello = hello
        self.io = io
        self.closed = False
        self.on_close: Optional[Callable[[], None]] = None
        self.on_push: Optional[Callable[[dict, list], None]] = None
        # session resumption (DESIGN.md §20): when the channel dies, the
        # executor may take ownership of the in-flight mid->slot map via
        # this hook (returning True) instead of having every slot errored
        # — the slots are re-adopted into the resumed channel.  A DEAD
        # liveness verdict sets ``liveness_killed`` before close() so the
        # park path can tell a kill from a transient disconnect.
        self.on_lost_pending: Optional[
            Callable[[Dict[int, _Slot]], bool]] = None
        self.liveness_killed = False
        try:
            self._peer = sock.getpeername()
        except OSError:
            self._peer = None
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass   # socketpair harnesses have no TCP options
        sock.setblocking(False)
        # send side: encoded messages [(parts, total_bytes)], one queue,
        # one writer — FIFO by construction
        self._send_queue: deque = deque()
        self._send_lock = threading.Lock()
        self._wake = asyncio.Event()
        # request side
        self._pending: Dict[int, _Slot] = {}
        self._pending_lock = threading.Lock()
        self._next_mid = int(start_mid)
        self._failed = False
        # batching counters (asserted by tests: msgs_sent can exceed
        # writes when the coalescer is doing its job)
        self.msgs_sent = 0
        self.writes = 0
        self._tasks: List[asyncio.Task] = []
        io.call_soon(self._start_io)

    # ------------------------------------------------------------ loop side
    def _start_io(self) -> None:
        if self.closed:
            return
        loop = self.io.loop
        self._tasks = [loop.create_task(self._read_loop()),
                       loop.create_task(self._write_loop())]

    async def _write_loop(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while True:
                await self._wake.wait()
                self._wake.clear()
                # chaos seam (§19/§20): a network partition blackholes
                # this channel's sends for a window WITHOUT closing the
                # socket.  awaited, never slept — other channels on the
                # shared loop keep flowing (per-scope windows).
                inj = chaos.INJECTOR
                if inj is not None:
                    stall = inj.partition_window(
                        f"sched-aioch{self.node_id}")
                    if stall > 0.0:
                        await asyncio.sleep(stall)
                while True:
                    # coalesce: consecutive small messages become ONE
                    # socket write; a large framed message flushes the
                    # batch and goes out part-by-part (zero-copy)
                    coalesce = max(1, protocol.WIRE_COALESCE_MAX)
                    flush_cap = max(coalesce, min(16 * coalesce, 1 << 20))
                    batch = bytearray()
                    big = None
                    with self._send_lock:
                        if not self._send_queue:
                            break
                        while self._send_queue:
                            parts, total = self._send_queue[0]
                            if total <= coalesce \
                                    and len(batch) + total <= flush_cap:
                                self._send_queue.popleft()
                                for p in parts:
                                    batch += p
                                self.msgs_sent += 1
                            elif not batch:
                                big = self._send_queue.popleft()
                                self.msgs_sent += 1
                                break
                            else:
                                break
                    if batch:
                        self.writes += 1
                        await loop.sock_sendall(self.sock, batch)
                    if big is not None:
                        self.writes += 1
                        for p in big[0]:
                            await loop.sock_sendall(self.sock, p)
        except asyncio.CancelledError:
            raise
        except (OSError, ConnectionClosed) as err:
            self._fail_all(ConnectionClosed(
                f"agent {self.node_id} connection lost: {err}",
                mid_message=True))
        except BaseException as err:   # pragma: no cover - defensive
            self._fail_all(err)

    async def _recv_exactly(self, loop, n: int) -> memoryview:
        buf = bytearray(n)
        view = memoryview(buf)
        got = 0
        while got < n:
            k = await loop.sock_recv_into(self.sock, view[got:])
            if k == 0:
                raise ConnectionClosed(
                    f"agent {self.node_id} connection closed mid-message",
                    mid_message=got > 0)
            got += k
        return view

    async def _read_loop(self) -> None:
        loop = asyncio.get_running_loop()
        head_size = protocol._HEAD.size
        try:
            while True:
                head = await self._recv_exactly(loop, head_size)
                magic, n = protocol._HEAD.unpack(bytes(head))
                if magic != protocol.MAGIC:
                    raise ConnectionClosed(
                        f"bad magic {magic!r} from agent {self.node_id}",
                        mid_message=True)
                lens = await self._recv_exactly(loop, 8 * n)
                lengths = [protocol._U64.unpack_from(lens, 8 * i)[0]
                           for i in range(n)]
                meta = pickle.loads(await self._recv_exactly(
                    loop, lengths[0]))
                frames = [await self._recv_exactly(loop, ln)
                          for ln in lengths[1:]]
                if protocol.WIRE_CHECKSUM:
                    frames = [protocol.verify_frame(f) for f in frames]
                self._dispatch(meta, frames)
        except asyncio.CancelledError:
            raise
        except (OSError, EOFError, ConnectionClosed,
                pickle.UnpicklingError) as err:
            self._fail_all(ConnectionClosed(
                f"agent {self.node_id} connection lost: {err}",
                mid_message=True))
        except BaseException as err:   # pragma: no cover - defensive
            self._fail_all(err)

    def _dispatch(self, meta: dict, frames: list) -> None:
        """Completion routing, inline on the loop (DESIGN.md §18)."""
        mid = meta.get("mid")
        if mid is None:
            cb = self.on_push
            if cb is not None:
                try:
                    cb(meta, frames)
                except BaseException:
                    traceback.print_exc()
            return
        with self._pending_lock:
            slot = self._pending.pop(mid, None)
        if slot is None:
            return   # timed-out waiter already gave up on this mid
        if slot.callback is not None:
            try:
                slot.callback(meta, frames, None)
            except BaseException:
                traceback.print_exc()
        else:
            slot.meta, slot.frames = meta, frames
            slot.event.set()

    # ---------------------------------------------------------- caller side
    @property
    def next_mid(self) -> int:
        """The next mid this channel would assign — a resumed channel is
        constructed with ``start_mid=next_mid`` of its predecessor so the
        mid sequence stays monotonic across the session (§20)."""
        with self._pending_lock:
            return self._next_mid

    def adopt_pending(self, pending: Dict[int, _Slot]) -> None:
        """Re-register surviving in-flight slots from a predecessor
        channel (session resumption): their replies will arrive on THIS
        connection carrying the original mids."""
        with self._pending_lock:
            for mid, slot in pending.items():
                self._pending.setdefault(mid, slot)

    def data_addr(self) -> Optional[str]:
        """The agent's peer data-plane address (``host:port``): the host
        this connection actually came from (or the ``data_host`` the
        agent explicitly advertised — RJAX_DATA_HOST on multi-homed
        nodes) plus the ``data_port`` from its hello."""
        port = self.hello.get("data_port")
        if not port:
            return None
        host = self.hello.get("data_host")
        if not host:
            host = self._peer[0] if self._peer else None
        if not host:
            return None
        return f"{host}:{port}"

    @staticmethod
    def _encode(meta: dict, frames) -> Tuple[list, int]:
        """Wire-encode on the *caller's* thread (pickling off the loop);
        mirrors ``protocol.send_msg``'s framing exactly, including the
        optional CRC32 trailers (RJAX_WIRE_CHECKSUM).  The ``bitflip``
        chaos seam intentionally lives only in ``protocol.send_msg`` —
        agent replies and the p2p plane — so injected corruption always
        exercises a *receive*-side detection path."""
        checksum = protocol.WIRE_CHECKSUM
        meta_blob = pickle.dumps(meta, protocol=5)
        lengths = [len(meta_blob)]
        parts: list = [b"", meta_blob]   # placeholder for the header
        for f in frames or ():
            if not isinstance(f, (list, tuple)):
                f = (f,)
            ln = sum(len(p) for p in f)
            parts.extend(f)
            if checksum:
                parts.append(protocol._CRC.pack(protocol.frame_crc(f)))
                ln += protocol._CRC.size
            lengths.append(ln)
        header = protocol._HEAD.pack(protocol.MAGIC, len(lengths)) \
            + b"".join(protocol._U64.pack(ln) for ln in lengths)
        parts[0] = header
        return parts, len(header) + sum(lengths)

    def _enqueue(self, meta: dict, frames=()) -> None:
        # chaos seam (DESIGN.md §19): scheduler→agent message latency on
        # the async plane.  One global load when chaos is off.  Note the
        # pump runs _enqueue on the loop thread, so an injected delay
        # stalls the whole control plane for its duration — exactly the
        # pathological-scheduler-stall failure mode worth exercising.
        inj = chaos.INJECTOR
        if inj is not None:
            inj.sleep("delay", f"sched-aioch{self.node_id}")
        parts, total = self._encode(meta, frames)
        with self._send_lock:
            if self.closed:
                raise ConnectionClosed(
                    f"agent {self.node_id} channel closed")
            self._send_queue.append((parts, total))
        self.io.call_soon(self._wake.set)

    def post(self, meta: dict, frames=()) -> None:
        """Fire-and-forget (no mid, no reply expected)."""
        self._enqueue(meta, frames)

    def request_async(self, meta: dict, frames=()):
        """Send now, collect later: returns ``wait(timeout)``."""
        slot = _Slot()
        with self._pending_lock:
            if self.closed:
                raise ConnectionClosed(
                    f"agent {self.node_id} channel closed")
            mid = self._next_mid
            self._next_mid += 1
            self._pending[mid] = slot
        meta = dict(meta, mid=mid)
        op = meta.get("op")
        try:
            self._enqueue(meta, frames)
        except ConnectionClosed:
            with self._pending_lock:
                self._pending.pop(mid, None)
            self._fail_all()
            raise

        def wait(timeout: Optional[float] = None):
            assert not self.io.in_loop(), \
                "blocking request on the IO loop thread"
            if not slot.event.wait(timeout):
                with self._pending_lock:
                    self._pending.pop(mid, None)
                raise TimeoutError(
                    f"agent {self.node_id} did not reply to {op!r} "
                    f"within {timeout}s")
            if slot.error is not None:
                raise slot.error
            return slot.meta, slot.frames

        return wait

    def request(self, meta: dict, frames=(), timeout: Optional[float] = None):
        return self.request_async(meta, frames)(timeout)

    def request_cb(self, meta: dict, frames,
                   callback: Callable[[Optional[dict], Optional[list],
                                       Optional[BaseException]], None]) -> int:
        """Send now, deliver the reply to ``callback(meta, frames, err)``
        exactly once — with the reply (on the loop) or with the channel
        failure (off the loop).  Returns the assigned mid (the session
        resumption ledger keys re-submittable requests by it).  Raises
        only if the send itself failed while this call still owned the
        mid (the caller then handles the task; the callback will never
        fire for it)."""
        slot = _Slot(callback=callback)
        with self._pending_lock:
            if self.closed:
                raise ConnectionClosed(
                    f"agent {self.node_id} channel closed")
            mid = self._next_mid
            self._next_mid += 1
            self._pending[mid] = slot
        meta = dict(meta, mid=mid)
        try:
            self._enqueue(meta, frames)
        except ConnectionClosed:
            with self._pending_lock:
                owned = self._pending.pop(mid, None) is not None
            self._fail_all()
            if owned:
                raise
        return mid

    # ------------------------------------------------------------- teardown
    def _cancel_tasks(self) -> None:
        for t in self._tasks:
            t.cancel()

    def _fail_all(self, err: Optional[BaseException] = None) -> None:
        """Idempotent teardown: every registered mid resolves with the
        error, ``on_close`` fires once.  Callback draining and on_close
        run on a one-shot thread so channel failure can never block the
        loop (restart work happens there)."""
        with self._pending_lock:
            if self._failed:
                return
            self._failed = True
            self.closed = True
            pending = dict(self._pending)
            self._pending.clear()
            on_close, self.on_close = self.on_close, None
        if err is None:
            err = ConnectionClosed(
                f"agent {self.node_id} connection lost", mid_message=True)
        self.io.call_soon(self._cancel_tasks)
        # session resumption (§20): give the executor first refusal on
        # the in-flight map — True means it parked the slots for adoption
        # by a resumed channel, so they are NOT errored here.  on_close
        # still fires (it drives the park/grace bookkeeping).
        adopted = False
        hook = self.on_lost_pending
        if pending and hook is not None:
            try:
                adopted = bool(hook(pending))
            except BaseException:
                traceback.print_exc()
        cbs = []
        if not adopted:
            for slot in pending.values():
                if slot.callback is None:
                    slot.error = err
                    slot.event.set()
                else:
                    cbs.append(slot)
        if cbs or on_close is not None:
            def drain():
                if on_close is not None:
                    try:
                        on_close()
                    except BaseException:
                        traceback.print_exc()
                for slot in cbs:
                    try:
                        slot.callback(None, None, err)
                    except BaseException:
                        traceback.print_exc()
            threading.Thread(target=drain, daemon=True,
                             name=f"agent{self.node_id}-fail").start()

    def _close_sock(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass

    def close(self) -> None:
        self._fail_all(ConnectionClosed(
            f"agent {self.node_id} channel closed"))
        # close the fd from the loop, after the coroutines are cancelled,
        # so a pending sock_recv_into never sees a recycled fd; fall back
        # to closing inline when the loop is already gone
        if not self.io.call_soon(self._close_sock):
            self._close_sock()
