"""Deterministic chaos injection for the cluster runtime (DESIGN.md §19).

``RJAX_CHAOS=<seed>:<spec>`` arms a seeded fault injector at process
start — in the scheduler *and* (because spawned agents inherit the
environment) in every node agent.  ``<spec>`` is a comma-separated list
of fault classes, each optionally carrying an argument and a firing
rate::

    RJAX_CHAOS="1234:delay=0.02@0.3,hang=5@0.1,fetch-slow=0.2"

    <fault>[=<arg>][@<rate>]      # rate defaults per fault, arg too

Fault classes and the seams they fire at:

=============  =========================================================
``delay``      sleep ``arg`` seconds before a control-plane message is
               sent/queued (``AgentChannel``/``AsyncAgentChannel`` send
               paths) — network latency.
``drop``       swallow a heartbeat push on the agent before it is sent —
               heartbeat loss.  Only at-most-once telemetry traffic is
               droppable: request/reply messages ride TCP's reliable
               stream by design, and losing one *is* the connection-death
               fault class the respawn tests already cover.
``stall``      sleep ``arg`` seconds before an agent sends a task reply —
               a node draining slowly (scheduler-side deadline food).
``freeze``     a ``DataServer`` connection accepts the fetch request and
               then never answers — the half-open peer a network
               partition leaves behind; the consumer must time out
               retryable (``PeerFetchError``), never block forever.
``hang``       wrap the task body so it sleeps ``arg`` seconds first,
               *inside the pool worker* — a wedged worker; with a
               ``deadline_s`` armed, the agent watchdog kills it.
``fetch-slow`` sleep ``arg`` seconds before a peer pull request is sent —
               a congested data plane.
``partition``  blackhole a scheduler↔agent channel for ``arg`` seconds
               without closing the socket: every send on the seam's
               endpoint stalls until the window passes (TCP keeps the
               stream intact, so nothing is *lost* — exactly what a
               transient network partition looks like).  Distinct from
               ``freeze``, which parks the DataServer.  Windows are
               per-scope: one channel partitions, the rest keep flowing.
``bitflip``    flip one bit of an out-of-band array frame before it is
               sent (``protocol.send_msg``) — wire corruption.  With
               ``RJAX_WIRE_CHECKSUM`` armed the receiver detects it and
               fails the transfer retryably; without checksums this is
               the silent corruption the knob exists to catch.
=============  =========================================================

Determinism: every (seam scope, fault) pair draws from its own
``random.Random`` stream derived from the single seed, so one seam's
firing sequence is independent of how other seams interleave — the same
seed replays the same per-seam decision sequence whenever the seam is
hit in a deterministic order.

The module-level :data:`INJECTOR` is ``None`` unless ``RJAX_CHAOS`` is
set, so every seam costs exactly one global load + ``is None`` test on
the hot path (bench-gated with the rest of dispatch overhead).
"""
from __future__ import annotations

import os
import random
import threading
import time
import zlib
from typing import Dict, Optional, Tuple

__all__ = ["ChaosInjector", "INJECTOR", "refresh", "FAULTS"]

# fault -> (default rate, default arg)
FAULTS: Dict[str, Tuple[float, float]] = {
    "delay": (0.1, 0.01),        # seconds of added send latency
    "drop": (0.25, 0.0),         # heartbeat loss probability
    "stall": (0.1, 0.05),        # seconds of added reply latency
    "freeze": (0.1, 0.0),        # half-open DataServer connection
    "hang": (0.1, 1.0),          # seconds the task body sleeps first
    "fetch-slow": (0.2, 0.05),   # seconds of added peer-pull latency
    "partition": (0.02, 2.0),    # seconds a channel is blackholed
    "bitflip": (0.05, 0.0),      # corrupt one array-frame byte pre-send
}


class ChaosSpecError(ValueError):
    """Malformed ``RJAX_CHAOS`` value."""


class ChaosInjector:
    """Seeded fault injector; one per process, armed from ``RJAX_CHAOS``.

    :meth:`roll` is the one decision point: it returns ``None`` ("don't
    inject") or the fault's argument.  Sleeping/dropping is the seam's
    job — the injector never blocks anything itself.
    """

    def __init__(self, seed: int, faults: Dict[str, Tuple[float, float]]):
        self.seed = int(seed)
        self.faults = dict(faults)
        self._lock = threading.Lock()
        self._streams: Dict[Tuple[str, str], random.Random] = {}
        # open partition windows, scope -> monotonic deadline
        self._windows: Dict[str, float] = {}

    # ------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, spec: str) -> "ChaosInjector":
        """``"<seed>:<fault>[=<arg>][@<rate>],..."`` → injector."""
        seed_part, sep, fault_part = spec.partition(":")
        if not sep or not fault_part.strip():
            raise ChaosSpecError(
                f"RJAX_CHAOS={spec!r}: expected '<seed>:<fault>[=arg][@rate],...'")
        try:
            seed = int(seed_part)
        except ValueError:
            raise ChaosSpecError(
                f"RJAX_CHAOS={spec!r}: seed {seed_part!r} is not an integer")
        faults: Dict[str, Tuple[float, float]] = {}
        for clause in fault_part.split(","):
            clause = clause.strip()
            if not clause:
                continue
            name, _, rate_part = clause.partition("@")
            name, _, arg_part = name.partition("=")
            name = name.strip()
            if name not in FAULTS:
                raise ChaosSpecError(
                    f"RJAX_CHAOS={spec!r}: unknown fault {name!r} "
                    f"(known: {', '.join(sorted(FAULTS))})")
            default_rate, default_arg = FAULTS[name]
            try:
                rate = float(rate_part) if rate_part else default_rate
                arg = float(arg_part) if arg_part else default_arg
            except ValueError:
                raise ChaosSpecError(
                    f"RJAX_CHAOS={spec!r}: bad number in clause {clause!r}")
            if not 0.0 <= rate <= 1.0:
                raise ChaosSpecError(
                    f"RJAX_CHAOS={spec!r}: rate {rate} outside [0, 1]")
            faults[name] = (rate, arg)
        if not faults:
            raise ChaosSpecError(f"RJAX_CHAOS={spec!r}: no fault clauses")
        return cls(seed, faults)

    @classmethod
    def from_env(cls) -> Optional["ChaosInjector"]:
        spec = os.environ.get("RJAX_CHAOS", "").strip()
        return cls.parse(spec) if spec else None

    # ------------------------------------------------------------ decisions
    def _stream(self, fault: str, scope: str) -> random.Random:
        key = (fault, scope)
        rng = self._streams.get(key)
        if rng is None:
            # independent deterministic stream per (fault, scope): one
            # seam's draw count never perturbs another's sequence
            mix = zlib.crc32(f"{fault}|{scope}".encode())
            rng = self._streams[key] = random.Random(self.seed ^ mix)
        return rng

    def roll(self, fault: str, scope: str = "") -> Optional[float]:
        """``None`` = don't inject; else the fault's configured argument
        (seconds for the latency faults, unused for drop/freeze)."""
        ent = self.faults.get(fault)
        if ent is None:
            return None
        rate, arg = ent
        with self._lock:
            fire = self._stream(fault, scope).random() < rate
        return arg if fire else None

    def partition_window(self, scope: str = "") -> float:
        """The ``partition`` seam decision: seconds the caller must
        stall before its send may proceed (0.0 = no partition).  While
        a window is open no new rolls are drawn for the scope — one
        partition event is one decision, however many sends pile up
        behind it.  The caller does the stalling (synchronously or with
        ``asyncio.sleep`` — the async control plane's writer coroutine
        must not block its loop)."""
        if "partition" not in self.faults:
            return 0.0
        now = time.monotonic()
        with self._lock:
            deadline = self._windows.get(scope, 0.0)
            if now >= deadline:
                rate, arg = self.faults["partition"]
                if self._stream("partition", scope).random() < rate \
                        and arg > 0.0:
                    deadline = now + arg
                    self._windows[scope] = deadline
                else:
                    return 0.0
        return max(0.0, deadline - time.monotonic())

    def partition_stall(self, scope: str = "") -> bool:
        """Roll the ``partition`` seam and block out the window —
        the synchronous seam body (agent send path, legacy channel)."""
        remaining = self.partition_window(scope)
        if remaining > 0.0:
            time.sleep(remaining)
        return remaining > 0.0

    def sleep(self, fault: str, scope: str = "") -> bool:
        """Roll and, on a hit, sleep the fault's argument.  Returns
        whether the fault fired — the commonest seam body."""
        arg = self.roll(fault, scope)
        if arg is None:
            return False
        if arg > 0.0:
            time.sleep(arg)
        return True


class _HangWrapper:
    """Picklable body wrapper the agent's ``hang`` seam installs: sleeps
    inside the worker process, then runs the real body — a deterministic
    stand-in for a wedged task."""

    def __init__(self, fn, seconds: float):
        self.fn = fn
        self.seconds = float(seconds)

    def __call__(self, *args, **kwargs):
        time.sleep(self.seconds)
        return self.fn(*args, **kwargs)


# Armed once at import from the environment: agents inherit RJAX_CHAOS
# from the spawning scheduler, so every process in the job sees the same
# spec (each drawing from streams scoped by its own seam names).
INJECTOR: Optional[ChaosInjector] = ChaosInjector.from_env()


def refresh() -> Optional[ChaosInjector]:
    """Re-read ``RJAX_CHAOS`` (tests set the env var mid-process; real
    deployments set it before launch and never need this)."""
    global INJECTOR
    INJECTOR = ChaosInjector.from_env()
    return INJECTOR
