"""Scheduler-side connection to one node agent (DESIGN.md §12).

One TCP connection per agent carries every worker slot's traffic,
multiplexed by message id: ``request`` blocks the calling dispatcher
thread until the matching reply arrives, ``request_cb`` registers a
completion *callback* instead (the pipelined dispatch path, DESIGN.md
§14: a slot streams up to depth requests and the reader thread routes
each reply straight into the executor's completion handler), and ``post``
is fire-and-forget (alias/drop/exit control messages).  A single reader
thread per channel routes replies; per-connection FIFO ordering is what
makes the data-plane bookkeeping safe (an ``alias`` posted when a result
is published is always processed by the agent before any later task that
``Ref``-erences the aliased key).

If the agent dies, every pending and future request fails with
:class:`~repro.cluster.protocol.ConnectionClosed`: blocking waiters are
woken with the error, and callback requests are drained (with the error)
on a dedicated thread — never on the thread that noticed the failure,
which may hold the executor's per-agent ordering lock.  The executor maps
either to a retryable ``WorkerCrashedError`` and respawns the agent.
"""
from __future__ import annotations

import socket
import sys
import threading
import traceback
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from . import chaos
from .protocol import ConnectionClosed, recv_msg, send_msg


class _Pending:
    __slots__ = ("event", "meta", "frames", "error", "callback")

    def __init__(self, callback: Optional[Callable] = None):
        self.event = threading.Event()
        self.meta: Optional[dict] = None
        self.frames: Optional[List[memoryview]] = None
        self.error: Optional[BaseException] = None
        self.callback = callback


class AgentChannel:
    """A registered, live agent connection."""

    def __init__(self, sock: socket.socket, node_id: int, hello: dict,
                 start_mid: int = 1):
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass   # not TCP (e.g. a socketpair in tests)
        self.sock = sock
        self.node_id = node_id
        self.hello = hello            # {"workers": N, "pid": ..., "host": ...,
        #                                "data_port": ...}
        self.closed = False
        # session-resumption interface parity with AsyncAgentChannel
        # (DESIGN.md §20).  The executor only parks channels on the async
        # control plane, but the surface must exist on both so the park
        # logic never AttributeErrors under RJAX_CONTROL_PLANE=threads.
        self.on_lost_pending: Optional[
            Callable[[Dict[int, "_Pending"]], bool]] = None
        self.liveness_killed = False
        # fired exactly once when the connection dies (crash OR close);
        # the executor uses it to start recovery even when no request was
        # in flight — a producer can die holding node-resident results
        # that nobody has asked for yet (DESIGN.md §15)
        self.on_close: Optional[Callable[[], None]] = None
        # agent-initiated push messages (no ``mid``: nothing awaited
        # them) — the telemetry heartbeats ride here (DESIGN.md §17).
        # Runs on the reader thread: handlers must be cheap and non-blocking.
        self.on_push: Optional[Callable[[dict, List[memoryview]], None]] = None
        self._send_lock = threading.Lock()
        self._pending: Dict[int, _Pending] = {}
        self._pending_lock = threading.Lock()
        self._next_mid = int(start_mid)
        self._reader = threading.Thread(target=self._read_loop, daemon=True,
                                        name=f"agent{node_id}-reader")
        self._reader.start()

    @property
    def next_mid(self) -> int:
        """The next mid this channel would assign (mid monotonicity
        across a resumed session, DESIGN.md §20)."""
        with self._pending_lock:
            return self._next_mid

    def adopt_pending(self, pending: Dict[int, _Pending]) -> None:
        """Re-register surviving in-flight slots from a predecessor
        channel (session resumption)."""
        with self._pending_lock:
            for mid, slot in pending.items():
                self._pending.setdefault(mid, slot)

    def data_addr(self) -> Optional[str]:
        """The agent's peer data-plane address (``host:port``): the host
        this connection actually came from (or the ``data_host`` the
        agent explicitly advertised — RJAX_DATA_HOST on multi-homed
        nodes) plus the ``data_port`` from its hello."""
        port = self.hello.get("data_port")
        if not port:
            return None
        host = self.hello.get("data_host")
        if not host:
            try:
                peer = self.sock.getpeername()
                host = peer[0] if isinstance(peer, tuple) else None
            except OSError:
                return None
        if not host:
            return None
        return f"{host}:{port}"

    # ---------------------------------------------------------------- sending
    def _chaos_delay(self) -> None:
        # chaos seam (DESIGN.md §19): scheduler→agent message latency.
        # INJECTOR is None unless RJAX_CHAOS is set — one global load on
        # the hot path.  Sleeps before taking the send lock so injected
        # latency contends like real network latency, not like a stall
        # inside the channel.
        inj = chaos.INJECTOR
        if inj is not None:
            inj.sleep("delay", f"sched-ch{self.node_id}")
            # partition (§20): blackhole this channel's sends for a
            # window without closing the socket.  Blocking is fine here —
            # each legacy channel owns its own sender threads.
            inj.partition_stall(f"sched-ch{self.node_id}")

    def request_async(self, meta: dict, frames: Sequence[Sequence] = ()):
        """Send a request and return a ``wait(timeout=None)`` callable that
        blocks for the reply.  Splitting send from wait lets the executor
        hold its per-agent ordering lock across the send only."""
        slot = _Pending()
        with self._pending_lock:
            if self.closed:
                raise ConnectionClosed(f"agent {self.node_id} is gone")
            mid = self._next_mid
            self._next_mid += 1
            self._pending[mid] = slot
        meta = dict(meta, mid=mid)
        self._chaos_delay()
        try:
            with self._send_lock:
                send_msg(self.sock, meta, frames)
        except ConnectionClosed:
            self._fail_all()
            raise

        def wait(timeout: Optional[float] = None) -> Tuple[dict, List[memoryview]]:
            if not slot.event.wait(timeout=timeout):
                with self._pending_lock:
                    self._pending.pop(mid, None)
                raise TimeoutError(f"agent {self.node_id} did not reply to "
                                   f"{meta.get('op')!r} within {timeout}s")
            if slot.error is not None:
                raise slot.error
            return slot.meta, slot.frames

        return wait

    def request(self, meta: dict, frames: Sequence[Sequence] = (),
                timeout: Optional[float] = None) -> Tuple[dict, List[memoryview]]:
        return self.request_async(meta, frames)(timeout=timeout)

    def request_cb(self, meta: dict, frames: Sequence[Sequence],
                   callback: Callable) -> int:
        """Send a request whose reply is delivered as
        ``callback(meta, frames, error)`` on the channel's reader thread
        (``error`` is None on success); returns the assigned mid.
        Exactly one invocation per accepted request; if the *send
        itself* fails, the callback is NOT invoked — the
        ``ConnectionClosed`` propagates to the caller, which owns that
        task's completion (every other pending request is failed through
        its own callback/waiter)."""
        slot = _Pending(callback=callback)
        with self._pending_lock:
            if self.closed:
                raise ConnectionClosed(f"agent {self.node_id} is gone")
            mid = self._next_mid
            self._next_mid += 1
            self._pending[mid] = slot
        meta = dict(meta, mid=mid)
        self._chaos_delay()
        try:
            with self._send_lock:
                send_msg(self.sock, meta, frames)
        except ConnectionClosed:
            # if the reader noticed the death first it already owns (or
            # drained) every pending slot, ours included — in that case the
            # callback fires with the error and we must NOT also raise, or
            # the task would be completed twice
            with self._pending_lock:
                owned = self._pending.pop(mid, None) is not None
            self._fail_all()
            if owned:
                raise
        return mid

    def post(self, meta: dict, frames: Sequence[Sequence] = ()) -> None:
        """Fire-and-forget control message (no reply expected)."""
        self._chaos_delay()
        try:
            with self._send_lock:
                send_msg(self.sock, meta, frames)
        except ConnectionClosed:
            self._fail_all()
            raise

    # --------------------------------------------------------------- receiving
    def _read_loop(self) -> None:
        try:
            while True:
                meta, frames = recv_msg(self.sock)
                mid = meta.get("mid")
                if mid is None:
                    # unsolicited agent→scheduler push (heartbeats)
                    cb = self.on_push
                    if cb is not None:
                        try:
                            cb(meta, frames)
                        except BaseException:
                            traceback.print_exc(file=sys.stderr)
                    continue
                with self._pending_lock:
                    slot = self._pending.pop(mid, None)
                if slot is None:
                    continue
                if slot.callback is not None:
                    # completion runs here, outside the pending lock; a
                    # raising completion is an executor bug — surfacing it
                    # must not take the whole channel down
                    try:
                        slot.callback(meta, frames, None)
                    except BaseException:
                        traceback.print_exc(file=sys.stderr)
                else:
                    slot.meta, slot.frames = meta, frames
                    slot.event.set()
        except BaseException as err:  # noqa: BLE001 — a reader that dies
            # silently (e.g. an unpicklable reply meta) would leave every
            # dispatcher on this agent blocked forever; ANY exit must fail
            # the pending requests
            self._fail_all(err)

    def _fail_all(self, err: Optional[BaseException] = None) -> None:
        with self._pending_lock:
            self.closed = True
            pending = dict(self._pending)
            self._pending.clear()
            on_close, self.on_close = self.on_close, None
        if on_close is not None:
            # on its own thread: recovery (agent respawn, lineage
            # re-execution) takes executor/store/graph locks that the
            # thread noticing the failure may already hold
            threading.Thread(target=on_close, daemon=True,
                             name=f"agent{self.node_id}-onclose").start()
        if not pending:
            return
        # session resumption (§20): the executor may adopt the in-flight
        # map instead of having every slot errored (see AsyncAgentChannel)
        hook = self.on_lost_pending
        if hook is not None:
            try:
                if hook(pending):
                    return
            except BaseException:
                traceback.print_exc(file=sys.stderr)
        err = err if err is not None else ConnectionClosed(
            f"agent {self.node_id} connection lost", mid_message=True)
        cb_slots = []
        for slot in pending.values():
            if slot.callback is not None:
                cb_slots.append(slot)
            else:
                slot.error = err
                slot.event.set()
        if cb_slots:
            # drain callbacks on their own thread: _fail_all may run on a
            # sender thread that holds the executor's per-agent ordering
            # lock, which the failure handlers (agent restart) also take
            def drain():
                for slot in cb_slots:
                    try:
                        slot.callback(None, None, err)
                    except BaseException:
                        traceback.print_exc(file=sys.stderr)

            threading.Thread(target=drain, daemon=True,
                             name=f"agent{self.node_id}-fail").start()

    # ----------------------------------------------------------------- closing
    def close(self) -> None:
        self._fail_all(ConnectionClosed(f"agent {self.node_id} channel closed"))
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
