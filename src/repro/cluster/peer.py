"""Peer-to-peer data plane: node-resident results move agent↔agent
(DESIGN.md §15).

Every node agent runs a :class:`DataServer` — a tiny TCP listener on an
ephemeral port (advertised in the hello/welcome handshake) that serves
``fetch`` requests straight out of the agent's node plane.  Consumers —
other agents resolving a ``Fetch`` directive, or the scheduler
materializing a gather — pull through a :class:`PeerPool`: one pooled,
persistent connection per peer with a dedicated sender thread, so
requests to a given peer are strictly FIFO (the per-peer ordering that
keeps Put-before-Ref residency reasoning intact) and connection setup is
paid once, not per datum.

Wire format is the cluster protocol's length-prefixed framing
(:mod:`repro.cluster.protocol`): a fetch request is one metadata frame,
the reply is the datum's structure with its ndarrays as raw-codec frames
(zero-copy on both sides, same as task payloads).

Failure model: a dead producer surfaces as :class:`PeerFetchError`, a
subclass of the retryable
:class:`~repro.core.executors.WorkerCrashedError` — the scheduler
answers by re-executing the producer from graph lineage and retrying the
consumer (see ``Runtime.recover_lost_node``).
"""
from __future__ import annotations

import os
import queue
import socket
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from ..core.executors import WorkerCrashedError
from . import chaos
from .protocol import (
    ConnectionClosed,
    pack_payload,
    recv_msg,
    send_msg,
    struct_nbytes,
    unpack_payload,
)

# how long a fetch may sit on a peer's wire before the consumer gives up
# (covers a wedged-but-connected producer — a half-open connection after
# a partition or freeze never EOFs, so this timeout is the ONLY thing
# standing between the consumer and blocking forever; a dead producer
# fails fast on connect/EOF).  Read at call time so tests can tighten it.
PEER_FETCH_TIMEOUT = float(os.environ.get("RJAX_PEER_FETCH_TIMEOUT", 60.0))


def _fetch_timeout() -> float:
    """The effective peer-fetch timeout — module attribute lookup at call
    time, so monkeypatching ``peer.PEER_FETCH_TIMEOUT`` (the half-open
    tests) takes effect without re-importing."""
    return PEER_FETCH_TIMEOUT


class PeerFetchError(WorkerCrashedError):
    """A peer-to-peer pull failed (producer down, datum gone).  Retryable:
    the scheduler re-executes the producer from lineage.

    ``lost_input`` marks this as an *input* loss, not a failure of the
    task's own execution: the runtime grants such failures a bounded
    retry allowance beyond the task's ``max_retries`` — pre-§15 a crash
    after the producer completed could never hurt consumers (the bytes
    were already on the scheduler), and the default ``max_retries=0``
    must not regress that."""

    lost_input = True


def encode_value(value: Any):
    """One datum as ``(structure, frames)`` for a data-plane reply —
    ``pack_payload`` with no keys, so inner arrays ride raw-codec
    frames and everything else pickles."""
    structure, frames, _ = pack_payload(value)
    return structure, frames


def decode_value(structure: Any, frames) -> Any:
    return unpack_payload(structure, frames)


class DataServer:
    """Serves this node's plane to peers.  ``lookup(key, token)`` is
    supplied by the agent: resolve by datum key first, then by result
    token (covers the window where a consumer's fetch beats the
    producer's ``alias`` control message — cross-channel ordering is not
    guaranteed, which is exactly why fetch requests carry both)."""

    def __init__(self, lookup: Callable[[Tuple[int, int], Optional[int]], Any],
                 host: str = "127.0.0.1",
                 fd_hooks: Optional[Tuple[Callable, Callable]] = None):
        self._lookup = lookup
        # (track, untrack) callbacks keeping the owner's fork-time
        # close-fd list current: a pool worker forked while a data-plane
        # connection is open would otherwise inherit it and keep the
        # peer's socket half-open after this agent dies — masking the
        # crash from consumers (the §12 fd-hygiene invariant)
        self._fd_track, self._fd_untrack = fd_hooks or (None, None)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, 0))
        self._listener.listen(64)
        self.port = self._listener.getsockname()[1]
        self._closed = False
        self._conns: Dict[int, socket.socket] = {}
        self._lock = threading.Lock()
        self.serves = 0
        self.served_bytes = 0
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="data-accept")
        self._accept_thread.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return   # listener closed
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            with self._lock:
                if self._closed:
                    conn.close()
                    return
                self._conns[conn.fileno()] = conn
            if self._fd_track is not None:
                self._fd_track(conn.fileno())
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True, name="data-serve").start()

    def _serve_conn(self, conn: socket.socket) -> None:
        fd = conn.fileno()
        try:
            while True:
                try:
                    meta, _ = recv_msg(conn)
                except ConnectionClosed:
                    return   # peer hung up (pool teardown)
                if meta.get("op") != "fetch":
                    send_msg(conn, {"op": "data", "ok": False,
                                    "error": f"unknown op {meta.get('op')!r}"})
                    continue
                # chaos seam (DESIGN.md §19): half-open freeze — the
                # request was accepted but no reply ever comes (what a
                # network partition leaves behind).  The consumer's
                # PEER_FETCH_TIMEOUT must turn this into a retryable
                # PeerFetchError; parking the serving thread (rather
                # than closing) is the point — no EOF, no on_close.
                inj = chaos.INJECTOR
                if inj is not None and inj.roll("freeze", "data-serve") is not None:
                    while not self._closed:
                        time.sleep(0.05)
                    return
                key = tuple(meta["key"]) if meta.get("key") else None
                token = meta.get("token")
                try:
                    value = self._lookup(key, token)
                    structure, frames = encode_value(value)
                except KeyError:
                    send_msg(conn, {"op": "data", "ok": False,
                                    "error": f"datum {key} (token {token}) "
                                             "not resident"})
                    continue
                except ConnectionClosed:
                    raise
                except Exception as err:
                    send_msg(conn, {"op": "data", "ok": False,
                                    "error": f"{type(err).__name__}: {err}"})
                    continue
                send_msg(conn, {"op": "data", "ok": True,
                                "structure": structure}, frames)
                nbytes = sum(sum(len(p) for p in f) for f in frames)
                with self._lock:   # one serving thread per connection
                    self.serves += 1
                    self.served_bytes += nbytes
        except (ConnectionClosed, OSError):
            pass
        finally:
            with self._lock:
                self._conns.pop(fd, None)
            if self._fd_untrack is not None:
                self._fd_untrack(fd)
            try:
                conn.close()
            except OSError:
                pass

    def stats(self) -> dict:
        return {"p2p_serves": self.serves, "p2p_served_bytes": self.served_bytes}

    def close(self) -> None:
        self._closed = True
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for c in conns:
            try:
                c.close()
            except OSError:
                pass


class _FetchJob:
    __slots__ = ("key", "token", "callback")

    def __init__(self, key, token, callback):
        self.key = key
        self.token = token
        self.callback = callback


class _Peer:
    """One pooled connection to a peer's data server, with a dedicated
    sender thread draining a FIFO of fetch jobs — per-peer ordering."""

    def __init__(self, addr: str, label: str, pool: "PeerPool" = None):
        host, _, port = addr.rpartition(":")
        self.addr = addr
        self._pool = pool
        self._sockaddr = (host or "127.0.0.1", int(port))
        self._q: "queue.Queue[Optional[_FetchJob]]" = queue.Queue()
        self._sock: Optional[socket.socket] = None
        # dead-flag and queue share one lock so a job can never be
        # enqueued AFTER the close sentinel: either it lands ahead of the
        # sentinel (and is processed/failed normally) or submit() returns
        # False and the pool retries with a fresh peer.  A job silently
        # stranded behind the sentinel would never fire its callback —
        # permanently wedging the consumer plane's pending-fetch entry
        self._dead = False
        self._retired = False   # connection-level failure seen (loop-local)
        self._state_lock = threading.Lock()
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name=f"{label}-peer-{addr}")
        self._thread.start()

    def submit(self, job: _FetchJob) -> bool:
        with self._state_lock:
            if self._dead:
                return False
            self._q.put(job)
            return True

    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self._sockaddr, timeout=10.0)
        sock.settimeout(_fetch_timeout())
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        if self._pool is not None and self._pool.fd_track is not None:
            self._pool.fd_track(sock.fileno())
        return sock

    def _close_sock(self) -> None:
        if self._sock is None:
            return
        if self._pool is not None and self._pool.fd_untrack is not None:
            try:
                self._pool.fd_untrack(self._sock.fileno())
            except OSError:
                pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._sock = None

    def _loop(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                self._close_sock()
                return
            value, error = None, None
            try:
                if self._retired:
                    # a connection-level failure already retired this
                    # peer: jobs queued behind the failure must not each
                    # pay a fresh connect timeout to the dead address —
                    # fail them immediately into the retry/lineage path
                    raise PeerFetchError(
                        f"peer {self.addr} is gone (connection lost before "
                        f"d{job.key[0]}v{job.key[1]} was served)")
                if self._sock is None:
                    self._sock = self._connect()
                # chaos seam (DESIGN.md §19): congested data plane —
                # added latency ahead of the pull request
                inj = chaos.INJECTOR
                if inj is not None:
                    inj.sleep("fetch-slow", f"peer-{self.addr}")
                send_msg(self._sock, {"op": "fetch", "key": job.key,
                                      "token": job.token})
                meta, frames = recv_msg(self._sock)
                if not meta.get("ok"):
                    raise PeerFetchError(
                        f"peer {self.addr} cannot serve d{job.key[0]}"
                        f"v{job.key[1]}: {meta.get('error')}")
                value = decode_value(meta["structure"], frames)
            except PeerFetchError as err:
                error = err
            except Exception as err:
                # connection-level failure: drop the socket, and retire
                # this pooled peer entirely — a dead producer never comes
                # back on the same ephemeral port, so keeping the entry
                # would leak one parked sender thread per crash (a later
                # fetch_async to the same addr simply pools a fresh peer)
                self._close_sock()
                self._retired = True
                if self._pool is not None:
                    self._pool._evict(self.addr, self)
                error = PeerFetchError(
                    f"peer fetch of d{job.key[0]}v{job.key[1]} from "
                    f"{self.addr} failed: {type(err).__name__}: {err}")
                error.__cause__ = err
            if error is None and self._pool is not None:
                self._pool.note_fetched(struct_nbytes(value))
            # a raising callback (e.g. a spill dir hitting ENOSPC inside
            # the consumer plane's store) must not kill the ONLY sender
            # thread for this peer — that would strand every queued and
            # future fetch with no reconnect path
            try:
                job.callback(value, error)
            except BaseException:
                import traceback
                traceback.print_exc()

    def close(self) -> None:
        with self._state_lock:
            self._dead = True
            self._q.put(None)


class PeerPool:
    """Pooled peer connections keyed by ``host:port`` data-plane address."""

    def __init__(self, label: str = "rjax",
                 fd_hooks: Optional[Tuple[Callable, Callable]] = None):
        self._label = label
        self._lock = threading.Lock()
        self._peers: Dict[str, _Peer] = {}
        self._closed = False
        self.fd_track, self.fd_untrack = fd_hooks or (None, None)
        self.fetches = 0
        self.fetch_bytes = 0

    def _peer(self, addr: str) -> Optional[_Peer]:
        with self._lock:
            if self._closed:
                return None
            p = self._peers.get(addr)
            if p is None:
                p = self._peers[addr] = _Peer(addr, self._label, pool=self)
            return p

    def note_fetched(self, nbytes: int) -> None:
        """Ledger hook for the per-peer sender threads (locked: several
        peers complete concurrently and a bare ``+=`` loses updates)."""
        with self._lock:
            self.fetches += 1
            self.fetch_bytes += int(nbytes)

    def _evict(self, addr: str, peer: _Peer) -> None:
        """A peer's connection died: retire it (its sender thread exits
        once the queued jobs have been failed through their callbacks)."""
        with self._lock:
            if self._peers.get(addr) is peer:
                del self._peers[addr]
        peer.close()

    def fetch_async(self, addr: str, key, token,
                    callback: Callable[[Any, Optional[BaseException]], None]
                    ) -> None:
        """Queue a pull; ``callback(value, error)`` fires on the peer's
        sender thread (exactly once).  A peer retired by a concurrent
        eviction refuses the job; loop for a fresh one (bounded — a new
        _Peer accepts at least its first job before it can die)."""
        job = _FetchJob(tuple(key), token, callback)
        while True:
            peer = self._peer(addr)
            if peer is None:
                # pool closed (executor shutdown racing a straggler
                # gather): fail the job instead of pooling a peer whose
                # sender thread nobody would ever close
                callback(None, PeerFetchError(
                    f"peer pool closed; cannot fetch "
                    f"d{job.key[0]}v{job.key[1]} from {addr}"))
                return
            if peer.submit(job):
                return
            # raced an eviction: drop the stale mapping if still present
            with self._lock:
                if self._peers.get(addr) is peer:
                    del self._peers[addr]

    def fetch(self, addr: str, key, token,
              timeout: Optional[float] = None) -> Any:
        """Synchronous pull (the scheduler's gather path).  ``timeout``
        defaults to the effective ``PEER_FETCH_TIMEOUT`` at call time."""
        if timeout is None:
            timeout = _fetch_timeout()
        done = threading.Event()
        box: list = [None, None]

        def cb(value, err):
            box[0], box[1] = value, err
            done.set()

        self.fetch_async(addr, key, token, cb)
        if not done.wait(timeout=timeout):
            raise PeerFetchError(
                f"peer fetch of d{key[0]}v{key[1]} from {addr} timed out "
                f"after {timeout}s")
        if box[1] is not None:
            raise box[1]
        return box[0]

    def drop(self, addr: Optional[str]) -> None:
        """Close the pooled connection to ``addr`` (peer died/respawned)."""
        if addr is None:
            return
        with self._lock:
            p = self._peers.pop(addr, None)
        if p is not None:
            p.close()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            peers = list(self._peers.values())
            self._peers.clear()
        for p in peers:
            p.close()
