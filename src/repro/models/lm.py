"""Unified decoder-only LM covering all assigned architectures.

A model is a cycle of *block types* (``block_pattern``) over ``n_layers``:

* ``dense``      — GQA attention + SwiGLU MLP (granite, qwen3, internlm2,
                   VLM/audio backbones)
* ``moe``        — GQA attention + routed MoE (+ optional shared experts)
* ``ssd``        — Mamba-2 SSD block (attention-free)
* ``rglru``      — RG-LRU temporal mixing + MLP (RecurrentGemma)
* ``local_attn`` — sliding-window GQA + MLP (RecurrentGemma's 1:2 pattern)

Layers are grouped into ``lax.scan``-stacked *super-blocks* (one pattern
period per step) so the compiled HLO is O(1) in depth; the remainder layers
(``n_layers % len(pattern)``) run unrolled.  Caches mirror the grouping.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..layers.attention import attention_axes, attn_forward, init_attention, init_kv_cache
from ..layers.mlp import init_mlp, mlp_axes, mlp_forward
from ..layers.moe import init_moe, moe_apply_local, moe_apply_sharded, moe_axes
from ..layers.norms import init_rmsnorm, rmsnorm, rmsnorm_axes
from ..layers.tp_block import tp_attn_sublayer, tp_mlp_sublayer, tp_rglru_sublayer
from ..layers.rglru import init_rglru, init_rglru_cache, rglru_axes, rglru_forward
from ..layers.ssd import init_ssd, init_ssd_cache, ssd_axes, ssd_forward

AxTree = Any  # same structure as params, leaves = tuples of logical names


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    block_pattern: Tuple[str, ...] = ("dense",)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_renormalize: bool = True
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU
    rnn_width: int = 0
    local_window: int = 2048
    # input modality: "tokens" | "embeds" (audio stub) | "prefix_embeds" (VLM stub)
    input_mode: str = "tokens"
    prefix_len: int = 0
    mlp_gated: bool = True
    # numerics / compilation
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    cache_dtype: Any = jnp.bfloat16
    remat: str = "none"            # none | full | dots
    attn_impl: str = "auto"
    attn_chunk: int = 1024
    # dry-run cost probes: python-loop the layers / unroll inner scans so
    # XLA cost_analysis (which counts while bodies once) sees every FLOP
    scan_layers: bool = True
    unroll_scans: bool = False
    # distribution hints (consumed by repro.distributed)
    moe_ff_shard_axis: Optional[str] = "data"
    # §Perf levers: explicit shard_map TP for dense sub-blocks (train path)
    # and bf16 storage for attention score/probability tensors
    tp_block: str = "gspmd"          # "gspmd" | "shard_map"
    attn_scores_bf16: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def layer_types(self) -> Tuple[str, ...]:
        m = len(self.block_pattern)
        return tuple(self.block_pattern[i % m] for i in range(self.n_layers))

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def n_tail(self) -> int:
        return self.n_layers % len(self.block_pattern)

    @property
    def is_recurrent_only(self) -> bool:
        return all(t in ("ssd", "rglru") for t in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        return all(t in ("ssd", "rglru", "local_attn") for t in self.block_pattern)


# ------------------------------------------------------------------ builders
def _init_block(cfg: LMConfig, key, btype: str) -> Dict:
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    if btype in ("dense", "local_attn"):
        return {
            "ln1": init_rmsnorm(cfg.d_model, dt),
            "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, cfg.qk_norm, dt),
            "ln2": init_rmsnorm(cfg.d_model, dt),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt, gated=cfg.mlp_gated),
        }
    if btype == "moe":
        p = {
            "ln1": init_rmsnorm(cfg.d_model, dt),
            "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, cfg.qk_norm, dt),
            "ln2": init_rmsnorm(cfg.d_model, dt),
            "moe": init_moe(ks[1], cfg.d_model, cfg.d_ff_expert, cfg.n_experts, dt),
        }
        if cfg.n_shared_experts:
            p["shared"] = init_mlp(ks[2], cfg.d_model,
                                   cfg.n_shared_experts * cfg.d_ff_expert, dt)
        return p
    if btype == "ssd":
        return {
            "ln1": init_rmsnorm(cfg.d_model, dt),
            "ssd": init_ssd(ks[0], cfg.d_model, expand=cfg.ssm_expand,
                            headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                            conv_width=cfg.conv_width, dtype=dt),
        }
    if btype == "rglru":
        return {
            "ln1": init_rmsnorm(cfg.d_model, dt),
            "rec": init_rglru(ks[0], cfg.d_model, cfg.rnn_width or cfg.d_model,
                              conv_width=cfg.conv_width, dtype=dt),
            "ln2": init_rmsnorm(cfg.d_model, dt),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt, gated=cfg.mlp_gated),
        }
    raise ValueError(f"unknown block type {btype}")


def _block_axes(cfg: LMConfig, btype: str) -> Dict:
    if btype in ("dense", "local_attn"):
        return {"ln1": rmsnorm_axes(), "attn": attention_axes(cfg.qk_norm),
                "ln2": rmsnorm_axes(), "mlp": mlp_axes(cfg.mlp_gated)}
    if btype == "moe":
        ax = {"ln1": rmsnorm_axes(), "attn": attention_axes(cfg.qk_norm),
              "ln2": rmsnorm_axes(), "moe": moe_axes()}
        if cfg.n_shared_experts:
            ax["shared"] = mlp_axes()
        return ax
    if btype == "ssd":
        return {"ln1": rmsnorm_axes(), "ssd": ssd_axes()}
    if btype == "rglru":
        return {"ln1": rmsnorm_axes(), "rec": rglru_axes(),
                "ln2": rmsnorm_axes(), "mlp": mlp_axes(cfg.mlp_gated)}
    raise ValueError(btype)


def _init_super(cfg: LMConfig, key) -> Dict:
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}": _init_block(cfg, ks[i], t)
            for i, t in enumerate(cfg.block_pattern)}


def _super_axes(cfg: LMConfig) -> Dict:
    return {f"b{i}": _block_axes(cfg, t) for i, t in enumerate(cfg.block_pattern)}


def _stack(trees: List) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: LMConfig, key) -> Dict:
    k_emb, k_scan, k_tail, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if cfg.input_mode in ("tokens", "prefix_embeds"):
        params["embed"] = (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                           * 0.02).astype(cfg.param_dtype)
    if cfg.n_super > 0:
        ks = jax.random.split(k_scan, cfg.n_super)
        params["scan"] = _stack([_init_super(cfg, k) for k in ks])
    tail_types = cfg.layer_types[cfg.n_super * len(cfg.block_pattern):]
    if tail_types:
        ks = jax.random.split(k_tail, len(tail_types))
        params["tail"] = [_init_block(cfg, ks[i], t) for i, t in enumerate(tail_types)]
    params["final_norm"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size))
                             * 0.02).astype(cfg.param_dtype)
    return params


def param_axes(cfg: LMConfig) -> AxTree:
    """Same tree structure as ``init_params``; leaves are tuples of logical
    axis names (scan groups get a leading ``"layers"``)."""
    ax: Dict[str, Any] = {}
    if cfg.input_mode in ("tokens", "prefix_embeds"):
        ax["embed"] = ("vocab", "embed")
    if cfg.n_super > 0:
        sup = _super_axes(cfg)
        ax["scan"] = jax.tree.map(
            lambda t: ("layers",) + t, sup,
            is_leaf=lambda x: isinstance(x, tuple))
    tail_types = cfg.layer_types[cfg.n_super * len(cfg.block_pattern):]
    if tail_types:
        ax["tail"] = [_block_axes(cfg, t) for t in tail_types]
    ax["final_norm"] = rmsnorm_axes()
    if not cfg.tie_embeddings:
        ax["lm_head"] = ("embed", "vocab")
    return ax


# -------------------------------------------------------------------- caches
def _init_block_cache(cfg: LMConfig, btype: str, batch: int, cache_len: int):
    if btype == "dense" or btype == "moe":
        return init_kv_cache(batch, cache_len, cfg.n_kv_heads, cfg.hd, cfg.cache_dtype)
    if btype == "local_attn":
        return init_kv_cache(batch, min(cache_len, cfg.local_window),
                             cfg.n_kv_heads, cfg.hd, cfg.cache_dtype)
    if btype == "ssd":
        return init_ssd_cache(batch, cfg.d_model, expand=cfg.ssm_expand,
                              headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                              conv_width=cfg.conv_width, dtype=cfg.compute_dtype)
    if btype == "rglru":
        return init_rglru_cache(batch, cfg.rnn_width or cfg.d_model,
                                conv_width=cfg.conv_width, dtype=cfg.compute_dtype)
    raise ValueError(btype)


def init_caches(cfg: LMConfig, batch: int, cache_len: int) -> Dict:
    caches: Dict[str, Any] = {}
    if cfg.n_super > 0:
        one = {f"b{i}": _init_block_cache(cfg, t, batch, cache_len)
               for i, t in enumerate(cfg.block_pattern)}
        caches["scan"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_super,) + x.shape), one)
    tail_types = cfg.layer_types[cfg.n_super * len(cfg.block_pattern):]
    if tail_types:
        caches["tail"] = [_init_block_cache(cfg, t, batch, cache_len)
                          for t in tail_types]
    return caches


# ------------------------------------------------------------------- forward
def _apply_block(cfg: LMConfig, p, x, btype: str, *, cache, pos_offset,
                 make_cache_len, mesh):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if btype in ("dense", "local_attn", "moe"):
        window = cfg.local_window if btype == "local_attn" else None
        mcl = make_cache_len
        if btype == "local_attn" and mcl is not None:
            mcl = min(mcl, cfg.local_window)
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        use_tp = (cfg.tp_block == "shard_map" and tp > 1
                  and cache is None and mcl is None
                  and cfg.n_heads % tp == 0)  # heads must divide the TP axis
        if use_tp:
            data_axes = tuple(a for a in ("pod", "data")
                              if a in mesh.axis_names)
            x = tp_attn_sublayer(p["ln1"], p["attn"], x, cfg=cfg, mesh=mesh,
                                 window=window, pos_offset=pos_offset,
                                 data_axes=data_axes)
            new_cache = None
            if btype != "moe" and cfg.d_ff % tp == 0:
                x = tp_mlp_sublayer(p["ln2"], p["mlp"], x, cfg=cfg, mesh=mesh,
                                    data_axes=data_axes)
                return x, None, aux
            if btype != "moe":
                x = x + mlp_forward(p["mlp"], rmsnorm(p["ln2"], x))
                return x, None, aux
        else:
            a, new_cache = attn_forward(
                p["attn"], rmsnorm(p["ln1"], x),
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm, window=window,
                pos_offset=pos_offset, cache=cache, make_cache_len=mcl,
                cache_dtype=cfg.cache_dtype, impl=cfg.attn_impl,
                chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
                scores_dtype=jnp.bfloat16 if cfg.attn_scores_bf16
                else jnp.float32)
            x = x + a
        h = rmsnorm(p["ln2"], x)
        if btype == "moe":
            if mesh is not None and mesh.shape.get("model", 1) > 1:
                routed, aux = moe_apply_sharded(
                    p["moe"], h, mesh=mesh, top_k=cfg.top_k,
                    data_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
                    model_axis="model", ff_shard_axis=cfg.moe_ff_shard_axis,
                    capacity_factor=cfg.moe_capacity_factor,
                    renormalize=cfg.moe_renormalize)
            else:
                routed, aux = moe_apply_local(
                    p["moe"], h, top_k=cfg.top_k,
                    capacity_factor=cfg.moe_capacity_factor,
                    renormalize=cfg.moe_renormalize)
            y = routed
            if cfg.n_shared_experts:
                y = y + mlp_forward(p["shared"], h)
        else:
            y = mlp_forward(p["mlp"], h)
        return x + y, new_cache, aux
    if btype == "ssd":
        y, new_cache = ssd_forward(
            p["ssd"], rmsnorm(p["ln1"], x), expand=cfg.ssm_expand,
            headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
            conv_width=cfg.conv_width, chunk=cfg.ssm_chunk, cache=cache,
            make_cache=make_cache_len is not None, unroll=cfg.unroll_scans)
        return x + y, new_cache, aux
    if btype == "rglru":
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        use_tp = (cfg.tp_block == "shard_map" and tp > 1 and cache is None
                  and make_cache_len is None
                  and (cfg.rnn_width or cfg.d_model) % tp == 0
                  and cfg.d_ff % tp == 0)
        if use_tp:
            data_axes = tuple(a for a in ("pod", "data")
                              if a in mesh.axis_names)
            x = tp_rglru_sublayer(p["ln1"], p["rec"], x, cfg=cfg, mesh=mesh,
                                  data_axes=data_axes)
            x = tp_mlp_sublayer(p["ln2"], p["mlp"], x, cfg=cfg, mesh=mesh,
                                data_axes=data_axes)
            return x, None, aux
        y, new_cache = rglru_forward(p["rec"], rmsnorm(p["ln1"], x), cache=cache,
                                     make_cache=make_cache_len is not None)
        x = x + y
        x = x + mlp_forward(p["mlp"], rmsnorm(p["ln2"], x))
        return x, new_cache, aux
    raise ValueError(btype)


def _apply_super(cfg: LMConfig, p_sb, x, caches_sb, *, pos_offset,
                 make_cache_len, mesh):
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for i, btype in enumerate(cfg.block_pattern):
        c = caches_sb.get(f"b{i}") if caches_sb else None
        x, nc, aux = _apply_block(cfg, p_sb[f"b{i}"], x, btype, cache=c,
                                  pos_offset=pos_offset,
                                  make_cache_len=make_cache_len, mesh=mesh)
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[f"b{i}"] = nc
    return x, (new_caches or None), aux_total


def embed_inputs(cfg: LMConfig, params, batch) -> jnp.ndarray:
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    elif cfg.input_mode == "embeds":
        x = batch["embeds"]
    elif cfg.input_mode == "prefix_embeds":
        parts = []
        if "prefix_embeds" in batch:
            parts.append(batch["prefix_embeds"].astype(cfg.compute_dtype))
        if "tokens" in batch:
            parts.append(jnp.take(params["embed"], batch["tokens"], axis=0))
        x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    else:
        raise ValueError(cfg.input_mode)
    return x.astype(cfg.compute_dtype)


def forward(cfg: LMConfig, params, batch, *, caches=None, pos_offset=0,
            make_cache_len: Optional[int] = None, mesh=None,
            remat: Optional[str] = None, last_only: bool = False):
    """Returns (logits fp32 (B,S,V), new_caches or None, aux_loss).
    ``last_only=True`` computes logits for the final position only (prefill
    memory saver: avoids materializing (B, S, V))."""
    remat = cfg.remat if remat is None else remat
    x = embed_inputs(cfg, params, batch)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}

    if cfg.n_super > 0:
        scan_caches = caches.get("scan") if caches else None

        def body(carry, xs):
            x, aux = carry
            p_sb, c_sb = xs
            x, nc, a = _apply_super(cfg, p_sb, x, c_sb, pos_offset=pos_offset,
                                    make_cache_len=make_cache_len, mesh=mesh)
            return (x, aux + a), nc

        if remat == "full":
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        if cfg.scan_layers:
            (x, aux_total), nc_scan = jax.lax.scan(
                body, (x, aux_total), (params["scan"], scan_caches))
        else:
            # dry-run probe path: python loop so every layer's cost is in HLO
            ncs = []
            for i in range(cfg.n_super):
                p_sb = jax.tree.map(lambda a: a[i], params["scan"])
                c_sb = (jax.tree.map(lambda a: a[i], scan_caches)
                        if scan_caches is not None else None)
                (x, aux_total), nc = body((x, aux_total), (p_sb, c_sb))
                ncs.append(nc)
            nc_scan = (jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
                       if ncs and ncs[0] is not None else None)
        if nc_scan is not None:
            new_caches["scan"] = nc_scan

    tail_types = cfg.layer_types[cfg.n_super * len(cfg.block_pattern):]
    if tail_types:
        tail_caches = (caches.get("tail") if caches else [None] * len(tail_types))
        nc_tail = []
        for i, btype in enumerate(tail_types):
            x, nc, a = _apply_block(cfg, params["tail"][i], x, btype,
                                    cache=tail_caches[i], pos_offset=pos_offset,
                                    make_cache_len=make_cache_len, mesh=mesh)
            aux_total = aux_total + a
            nc_tail.append(nc)
        if any(c is not None for c in nc_tail):
            new_caches["tail"] = nc_tail

    if last_only:
        x = x[:, -1:]
    x = rmsnorm(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, (new_caches or None), aux_total


def loss_fn(cfg: LMConfig, params, batch, *, mesh=None, aux_weight: float = 0.01,
            remat: Optional[str] = None):
    """Masked next-token cross entropy.  batch must carry ``targets`` (B,S)
    and ``loss_mask`` (B,S) aligned with the model's output positions."""
    logits, _, aux = forward(cfg, params, batch, mesh=mesh, remat=remat)
    targets = batch["targets"]
    mask = batch["loss_mask"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "tokens": jnp.sum(mask)}
