"""Model zoo: one unified decoder-only LM covering the 10 assigned
architectures (dense GQA / MoE / Mamba-2 SSD / RG-LRU hybrid / VLM & audio
backbones with stubbed frontends)."""
from .lm import (  # noqa: F401
    LMConfig,
    forward,
    init_caches,
    init_params,
    loss_fn,
    param_axes,
)
