"""Quickstart — the paper's Fig. 2 program, verbatim semantics.

Four numbers are summed through three asynchronous ``add`` tasks; the
runtime discovers the dependency DAG (main -> {1,2} -> 3 -> sync) and
prints it in Graphviz form, exactly like ``runcompss --lang=r -g job.R``.

Run:  PYTHONPATH=src python examples/quickstart.py [--backend process]

``--backend process`` runs the same program on persistent worker
*processes* behind the shared-memory object plane (the paper's per-node
worker model) — the user program does not change at all.
"""
import sys

from repro.core import api


def add(x, y):
    return x + y


def main() -> None:
    backend = "process" if "--backend" in sys.argv and "process" in sys.argv \
        else "thread"
    api.runtime_start(n_workers=4, backend=backend)   # compss_start()
    add_t = api.task(add)                    # task(add, ...)

    a, b, c, d = 4, 5, 6, 7
    res1 = add_t(a, b)                       # Task (1)
    res2 = add_t(c, d)                       # Task (2)
    res3 = add_t(res1, res2)                 # Task (3) — depends on 1 & 2
    res3 = api.wait_on(res3)                 # compss_wait_on(res3)
    print("The result is:", res3)

    rt = api.current_runtime()
    print("\nTask DAG (the -g flag's output):")
    print(rt.graph.to_dot())
    api.runtime_stop()                       # compss_stop()
    assert res3 == 22


if __name__ == "__main__":
    main()
