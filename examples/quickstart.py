"""Quickstart — the paper's Fig. 2 program, verbatim semantics.

Four numbers are summed through three asynchronous ``add`` tasks; the
runtime discovers the dependency DAG (main -> {1,2} -> 3 -> sync) and
prints it in Graphviz form, exactly like ``runcompss --lang=r -g job.R``.

Run:  PYTHONPATH=src python examples/quickstart.py [--backend process|cluster]

``--backend process`` runs the same program on persistent worker
*processes* behind the shared-memory object plane (the paper's per-node
worker model); ``--backend cluster`` runs it on two real TCP node agents
(each with two worker processes) spawned on localhost — the user program
does not change at all.
"""
import sys

from repro.core import api


def add(x, y):
    return x + y


def main() -> None:
    backend = "thread"
    for b in ("process", "cluster"):
        if "--backend" in sys.argv and b in sys.argv:
            backend = b
    if backend == "cluster":
        api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2)
    else:
        api.runtime_start(n_workers=4, backend=backend)   # compss_start()
    add_t = api.task(add)                    # task(add, ...)

    a, b, c, d = 4, 5, 6, 7
    res1 = add_t(a, b)                       # Task (1)
    res2 = add_t(c, d)                       # Task (2)
    res3 = add_t(res1, res2)                 # Task (3) — depends on 1 & 2
    res3 = api.wait_on(res3)                 # compss_wait_on(res3)
    print("The result is:", res3)

    rt = api.current_runtime()
    print("\nTask DAG (the -g flag's output):")
    print(rt.graph.to_dot())
    api.runtime_stop()                       # compss_stop()
    assert res3 == 22


if __name__ == "__main__":
    main()
