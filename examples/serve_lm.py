"""Batched serving example: prefill + greedy decode over KV caches, with
request pre/post-processing as runtime tasks.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch recurrentgemma-9b]
      (always uses the --reduced config so it runs on CPU in seconds)
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.launch.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=12)
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=True)
    out = serve_batch(cfg, batch=args.requests, prompt_len=args.prompt_len,
                      gen_len=args.gen_len)
    print(f"arch={args.arch} (reduced)")
    print(f"generated token matrix {out['tokens'].shape}:")
    print(out["tokens"])
    print(f"prefill {out['prefill_s']*1e3:.0f} ms, "
          f"decode {out['decode_tokens_per_s']:.1f} tokens/s")


if __name__ == "__main__":
    main()
