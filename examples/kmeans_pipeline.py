"""Task-parallel K-means (paper §4.2) with trace analysis — the paper's
workflow end to end: sequential-style program, automatic DAG, locality
scheduling, Extrae-style trace, and a replay of the measured DAG on a
virtual 64-worker machine to project scaling.

Run:  PYTHONPATH=src python examples/kmeans_pipeline.py [--backend process|cluster]

With ``--backend process`` the fragment tasks execute on persistent worker
processes; the point fragments travel through the shared-memory object
plane once and are re-read zero-copy on every iteration (DESIGN.md §11).
With ``--backend cluster`` they run on two real TCP node agents, each
fragment shipped to a node once and reused from its plane every
iteration (DESIGN.md §12).
"""
import sys

import numpy as np

from repro.algorithms import kmeans
from repro.core import api
from repro.core.simulator import MachineModel, replay_graph, simulate


def main() -> None:
    backend = "thread"
    for b in ("process", "cluster"):
        if b in sys.argv:
            backend = b
    if backend == "cluster":
        api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2,
                          policy="locality", tracing=True)
    else:
        api.runtime_start(n_workers=4, policy="locality", tracing=True,
                          backend=backend)
    try:
        res = kmeans.run_kmeans(n_points=60_000, d=16, k=8, fragments=8,
                                max_iters=6)
        print(f"k-means: {res.iterations} iterations, SSE={res.sse:.1f}")
        cref, _, sseref = kmeans.reference_kmeans(60_000, 16, 8, 8, 6, 1e-4)
        assert np.allclose(res.centroids, cref, atol=1e-8)
        print("matches the single-shot oracle ✓")

        rt = api.current_runtime()
        print("\nexecution trace (4 workers):")
        print(rt.tracer.ascii_gantt(width=88))
        print(f"utilization: {rt.tracer.utilization(4):.2f}")

        sims = replay_graph(rt.graph)
        for w in (1, 8, 64):
            r = simulate(sims, MachineModel(n_nodes=1, workers_per_node=w))
            print(f"projected makespan on {w:3d} workers: "
                  f"{r.makespan*1e3:8.1f} ms (eff {r.efficiency:.2f})")
    finally:
        api.runtime_stop()


if __name__ == "__main__":
    main()
