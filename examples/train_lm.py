"""End-to-end training driver example.

Trains a LM with the full production stack: task-runtime data prefetch,
pjit train step, async checkpointing, cosine schedule, retry-on-failure.

Presets:
  --preset tiny   (default)  ~3M-param qwen3-style model, 30 steps — minutes
  --preset 100m              ~100M params, a few hundred steps — the
                             assignment's end-to-end target (hours on 1 CPU
                             core; the default on any real accelerator)

Run:  PYTHONPATH=src python examples/train_lm.py [--preset tiny]
"""
import argparse

import jax

from repro.launch.train import train_loop
from repro.models.lm import LMConfig, init_params


PRESETS = {
    # ~3M params: fast CPU sanity run
    "tiny": dict(
        cfg=LMConfig(name="tiny-lm", n_layers=4, d_model=128, n_heads=8,
                     n_kv_heads=4, d_ff=512, vocab_size=2048, qk_norm=True),
        steps=30, batch=8, seq=64, lr=1e-3,
    ),
    # ~100M params (the assignment's end-to-end scale)
    "100m": dict(
        cfg=LMConfig(name="lm-100m", n_layers=12, d_model=512, n_heads=8,
                     n_kv_heads=4, d_ff=2048, vocab_size=32768, qk_norm=True),
        steps=300, batch=8, seq=256, lr=6e-4,
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/rjax_train_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    cfg = p["cfg"]
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(x.size for x in jax.tree.leaves(shapes))
    print(f"model: {cfg.name}  params≈{n_params/1e6:.1f}M")
    out = train_loop(
        cfg, steps=args.steps or p["steps"], batch=p["batch"], seq=p["seq"],
        lr=p["lr"], workers=4, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=10)
    print(f"\nloss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"({out['tokens_per_s']:.0f} tokens/s)")
    print("runtime stats:", {k: v for k, v in out["runtime_stats"].items()
                             if k in ("tasks_done", "retries", "utilization")})


if __name__ == "__main__":
    main()
