"""Benchmark regression gate (CI `bench` job).

Merges the per-script JSON outputs into one ``BENCH_pr.json`` and fails
if the PR regresses against the committed ``benchmarks/BENCH_baseline.json``:

* **dispatch overhead** (µs/task, per backend) — the hot-path number the
  paper's §5.1 microbenchmark guards — may not exceed baseline × 1.25
  plus a 150 µs absolute slack.  The slack is the cross-hardware noise
  floor: the committed baseline is recorded on whatever box ran it last
  (regenerate with the two `--quick --json` runs + `--merge` onto
  `benchmarks/BENCH_baseline.json`), while the gate runs on shared CI
  runners whose scheduler jitter on µs-scale numbers routinely exceeds
  25% alone; the measurement itself is a min-of-repeats for the same
  reason.
* **out-of-core correctness** — every ``out_of_core`` block must report
  ``match: true`` and a non-zero spill AND fault count, keeping the
  bounded-memory path honest (a silently-unbounded run would show 0/0).
* **scheduler relay bytes** (DESIGN.md §15) — the KNN tile pipeline's
  intermediate traffic over the scheduler's own link may not regress
  above baseline × 1.5 + 128 KiB.  Bytes are near-deterministic (task
  placement wiggles a fragment or two); a real regression — results
  relaying through the scheduler again instead of staying node-resident
  — is an order of magnitude, not a fragment.
* **linreg simulated efficiency** (DESIGN.md §16) — the collective
  k-ary merge tree is what lifted linreg's eff@128; falling below
  baseline × 0.9 means the reduction degenerated back toward the
  pairwise chain (the 0.9 floor absorbs per-run calibration noise in
  the task cost models, which is a few percent).
* **broadcast byte split** (DESIGN.md §16) — a broadcast's value may
  cross the scheduler's own link at most ~once (× 1.25 envelope slack);
  every remaining agent must receive it peer-to-peer.
* **telemetry overhead** (DESIGN.md §17) — dispatch overhead with the
  telemetry plane enabled may not exceed the same-run telemetry-off
  number × 1.05 plus a 25 µs jitter slack.  This gate is PR-internal
  (both numbers come from the same box in the same run, interleaved),
  so no baseline entry is needed and no cross-hardware slack applies.
* **control-plane flatness** (DESIGN.md §18) — per-task dispatch
  overhead of a no-op fan-out at 8 agents may not exceed the same-run
  2-agent number × 1.25 plus a 25 µs jitter slack, and the scheduler's
  mid-run thread count at 8 agents may not exceed the 2-agent count
  plus 1.  PR-internal like the telemetry gate: the point of the single
  event-loop control plane is that neither number scales with agents
  (the legacy plane grew a reader thread per agent and a dispatcher
  thread per slot).

Efficiency numbers are recorded in the artifact for trend tracking but
not gated (CI runner variance swamps them).

Usage::

    python benchmarks/bench_gate.py --merge a.json b.json -o BENCH_pr.json \
        --baseline benchmarks/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys

REL_TOLERANCE = 1.25     # >25% regression fails...
ABS_SLACK_US = 150.0     # ...but only past the cross-hardware noise floor
RELAY_TOLERANCE = 1.5            # scheduler-link bytes: placement wiggle...
RELAY_SLACK_BYTES = 128 * 1024   # ...a real regression is 10x, not 1.5x
EFF_TOLERANCE = 0.9              # linreg sim eff: calibration noise floor
BCAST_TOLERANCE = 1.25           # scheduler-link copies per broadcast
TELEMETRY_TOLERANCE = 1.05       # telemetry-on vs -off, same box same run...
TELEMETRY_SLACK_US = 25.0        # ...plus the min-of-repeats jitter floor
PLANE_TOLERANCE = 1.25           # 8-agent vs 2-agent dispatch, same run...
PLANE_SLACK_US = 25.0            # ...plus the min-of-repeats jitter floor
PLANE_THREAD_SLACK = 1           # transient helper thread racing the sample
CHECKSUM_TOLERANCE = 1.3         # CRC32 trailers on vs off, same box
#                                  same run (min-of-repeats each side)


def deep_merge(dst: dict, src: dict) -> dict:
    for k, v in src.items():
        if isinstance(v, dict) and isinstance(dst.get(k), dict):
            deep_merge(dst[k], v)
        else:
            dst[k] = v
    return dst


def iter_out_of_core(tree, path=""):
    # any key named out_of_core* is a spill/fault ledger block (e.g. the
    # single-node quick bench emits out_of_core + out_of_core_thread)
    if isinstance(tree, dict):
        for k, v in tree.items():
            where = f"{path}.{k}" if path else k
            if k.startswith("out_of_core") and isinstance(v, dict):
                yield where, v
            else:
                yield from iter_out_of_core(v, where)


def check(pr: dict, baseline: dict) -> list:
    failures = []
    base_ovh = baseline.get("single_node", {}).get("dispatch_overhead_us", {})
    pr_ovh = pr.get("single_node", {}).get("dispatch_overhead_us", {})
    for backend, base in base_ovh.items():
        got = pr_ovh.get(backend)
        if got is None:
            failures.append(f"dispatch_overhead_us.{backend}: missing from PR run")
            continue
        limit = base * REL_TOLERANCE + ABS_SLACK_US
        status = "FAIL" if got > limit else "ok"
        print(f"  [{status}] dispatch {backend}: {got:.1f} us "
              f"(baseline {base:.1f}, limit {limit:.1f})")
        if got > limit:
            failures.append(
                f"dispatch_overhead_us.{backend}: {got:.1f} us > "
                f"{limit:.1f} us (baseline {base:.1f} × {REL_TOLERANCE} "
                f"+ {ABS_SLACK_US})")
    base_relay = baseline.get("multi_node", {}).get(
        "data_plane", {}).get("scheduler_relay_bytes")
    if base_relay is not None:
        got = pr.get("multi_node", {}).get(
            "data_plane", {}).get("scheduler_relay_bytes")
        if got is None:
            failures.append(
                "data_plane.scheduler_relay_bytes: missing from PR run")
        else:
            limit = base_relay * RELAY_TOLERANCE + RELAY_SLACK_BYTES
            status = "FAIL" if got > limit else "ok"
            print(f"  [{status}] scheduler relay bytes: {got} "
                  f"(baseline {base_relay}, limit {int(limit)})")
            if got > limit:
                failures.append(
                    f"data_plane.scheduler_relay_bytes: {got} > "
                    f"{int(limit)} (baseline {base_relay} × "
                    f"{RELAY_TOLERANCE} + {RELAY_SLACK_BYTES})")
    for mode in ("weak_eff@128", "strong_eff@128"):
        base_eff = baseline.get("single_node", {}).get(mode, {}).get("linreg")
        if base_eff is None:
            continue
        got = pr.get("single_node", {}).get(mode, {}).get("linreg")
        if got is None:
            failures.append(f"single_node.{mode}.linreg: missing from PR run")
            continue
        floor = base_eff * EFF_TOLERANCE
        status = "FAIL" if got < floor else "ok"
        print(f"  [{status}] linreg {mode}: {got:.3f} "
              f"(baseline {base_eff:.3f}, floor {floor:.3f})")
        if got < floor:
            failures.append(
                f"single_node.{mode}.linreg: {got:.3f} < {floor:.3f} "
                f"(baseline {base_eff:.3f} × {EFF_TOLERANCE})")
    bcast = pr.get("multi_node", {}).get("collectives", {}).get("broadcast")
    if bcast is None:
        if baseline.get("multi_node", {}).get("collectives"):
            failures.append("collectives.broadcast: missing from PR run")
    else:
        nb, agents = bcast["nbytes"], bcast["agents"]
        link, p2p = bcast["scheduler_link_bytes"], bcast["p2p_bytes"]
        link_ok = link <= nb * BCAST_TOLERANCE
        p2p_ok = p2p >= (agents - 2) * nb
        status = "ok" if link_ok and p2p_ok else "FAIL"
        print(f"  [{status}] broadcast ({agents} agents, {nb} B): "
              f"{link} B over the scheduler link, {p2p} B peer-to-peer")
        if not link_ok:
            failures.append(
                f"collectives.broadcast: {link} scheduler-link bytes > "
                f"{int(nb * BCAST_TOLERANCE)} (one copy × {BCAST_TOLERANCE})")
        if not p2p_ok:
            failures.append(
                f"collectives.broadcast: {p2p} p2p bytes < "
                f"{(agents - 2) * nb} — agents not fed peer-to-peer")
    tel = pr.get("single_node", {}).get("telemetry_overhead_us")
    if tel is not None:
        on, off = tel.get("on"), tel.get("off")
        if on is None or off is None:
            failures.append("telemetry_overhead_us: incomplete (need on+off)")
        else:
            limit = off * TELEMETRY_TOLERANCE + TELEMETRY_SLACK_US
            status = "FAIL" if on > limit else "ok"
            print(f"  [{status}] telemetry overhead: on {on:.1f} us vs "
                  f"off {off:.1f} us (limit {limit:.1f})")
            if on > limit:
                failures.append(
                    f"telemetry_overhead_us: {on:.1f} us with telemetry on > "
                    f"{limit:.1f} us (off {off:.1f} × {TELEMETRY_TOLERANCE} "
                    f"+ {TELEMETRY_SLACK_US})")
    cp = pr.get("multi_node", {}).get("control_plane")
    if cp is None:
        if baseline.get("multi_node", {}).get("control_plane"):
            failures.append("multi_node.control_plane: missing from PR run")
    else:
        lo, hi = cp.get("2", {}), cp.get("8", {})
        if not lo or not hi:
            failures.append("multi_node.control_plane: incomplete (need "
                            "2- and 8-agent rows)")
        else:
            limit = lo["per_task_us"] * PLANE_TOLERANCE + PLANE_SLACK_US
            flat_ok = hi["per_task_us"] <= limit
            thr_limit = lo["sched_threads"] + PLANE_THREAD_SLACK
            thr_ok = hi["sched_threads"] <= thr_limit
            status = "ok" if flat_ok and thr_ok else "FAIL"
            print(f"  [{status}] control plane: dispatch "
                  f"{lo['per_task_us']:.1f} us @2 -> {hi['per_task_us']:.1f} "
                  f"us @8 agents (limit {limit:.1f}); threads "
                  f"{lo['sched_threads']} -> {hi['sched_threads']} "
                  f"(limit {thr_limit})")
            if not flat_ok:
                failures.append(
                    f"control_plane: {hi['per_task_us']:.1f} us/task @8 "
                    f"agents > {limit:.1f} (2-agent {lo['per_task_us']:.1f} "
                    f"× {PLANE_TOLERANCE} + {PLANE_SLACK_US})")
            if not thr_ok:
                failures.append(
                    f"control_plane: {hi['sched_threads']} scheduler threads "
                    f"@8 agents > {thr_limit} — dispatch is growing threads "
                    f"with agent count again")
    rec = pr.get("multi_node", {}).get("recovery")
    if rec is None:
        if baseline.get("multi_node", {}).get("recovery"):
            failures.append("multi_node.recovery: missing from PR run")
    else:
        on = rec.get("replication_on", {})
        off = rec.get("replication_off", {})
        hit_ok = on.get("replica_hits", 0) > 0
        zero_ok = on.get("reexecuted") == 0
        lineage_ok = off.get("reexecuted", 0) > 0
        ok = hit_ok and zero_ok and lineage_ok
        print(f"  [{'ok' if ok else 'FAIL'}] recovery: replication-on "
              f"re-executed {on.get('reexecuted')} "
              f"({on.get('replica_hits')} replica hits, "
              f"{on.get('recover_s')}s); replication-off re-executed "
              f"{off.get('reexecuted')} ({off.get('recover_s')}s)")
        if not zero_ok:
            failures.append(
                f"recovery.replication_on.reexecuted: "
                f"{on.get('reexecuted')} != 0 — replicated producers "
                f"re-ran instead of serving from replicas")
        if not hit_ok:
            failures.append(
                "recovery.replication_on.replica_hits: 0 — no store "
                "placeholder was redirected to a surviving replica")
        if not lineage_ok:
            failures.append(
                "recovery.replication_off.reexecuted: 0 — the control "
                "run lost no work, the kill did not exercise recovery")
    wc = pr.get("multi_node", {}).get("wire_checksum")
    if wc is None:
        if baseline.get("multi_node", {}).get("wire_checksum"):
            failures.append("multi_node.wire_checksum: missing from PR run")
    else:
        ratio = wc.get("overhead_ratio")
        ok = ratio is not None and ratio <= CHECKSUM_TOLERANCE
        print(f"  [{'ok' if ok else 'FAIL'}] wire checksum: "
              f"{wc.get('off_s')}s off -> {wc.get('on_s')}s on "
              f"(ratio {ratio}, limit {CHECKSUM_TOLERANCE})")
        if not ok:
            failures.append(
                f"wire_checksum.overhead_ratio: {ratio} > "
                f"{CHECKSUM_TOLERANCE} — CRC32 trailers cost too much")
    for where, ooc in iter_out_of_core(pr):
        spills = ooc.get("spills", 0) + ooc.get("node_spills", 0) \
            + ooc.get("plane_spills", 0)
        faults = ooc.get("faults", 0) + ooc.get("node_faults", 0) \
            + ooc.get("plane_faults", 0)
        ok = ooc.get("match") and spills > 0 and faults > 0
        print(f"  [{'ok' if ok else 'FAIL'}] {where}: "
              f"match={ooc.get('match')} spills={spills} faults={faults}")
        if not ok:
            failures.append(
                f"{where}: expected match=true with >0 spills and faults, "
                f"got match={ooc.get('match')} spills={spills} faults={faults}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--merge", nargs="+", required=True, metavar="JSON",
                    help="per-script measurement files to combine")
    ap.add_argument("-o", "--output", default="BENCH_pr.json",
                    help="merged artifact path (default BENCH_pr.json)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline to gate against "
                         "(omit to only merge)")
    args = ap.parse_args(argv)

    merged: dict = {"schema": 1}
    for path in args.merge:
        with open(path) as f:
            deep_merge(merged, json.load(f))
    with open(args.output, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    print(f"wrote {args.output}")

    if not args.baseline:
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    print(f"gating against {args.baseline}:")
    failures = check(merged, baseline)
    if failures:
        print("\nbench gate FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
