"""§Roofline — the three-term roofline table from the compiled dry-run
artifacts (results/dryrun/*.json), per (arch × shape) on the single-pod
mesh.  MODEL_FLOPS is recomputed here from the configs (the authoritative
definition: 6·N_active·D train / 2·N_active·D inference, decode counting
one new token per sequence)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Tuple

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.distributed.analysis import Roofline, model_flops

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def load_cells(mesh: str = "16x16", tag: str = "") -> List[dict]:
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            suffix = f"_{tag}" if tag else ""
            f = DRYRUN_DIR / f"{arch}_{shape}_{mesh}{suffix}.json"
            if f.exists():
                cells.append(json.loads(f.read_text()))
    return cells


def rebuilt_roofline(cell: dict) -> Roofline | None:
    if cell.get("status") != "OK" or "roofline" not in cell:
        return None
    r = cell["roofline"]
    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    mf = model_flops(cfg, shape.kind, shape.batch, shape.seq)
    return Roofline(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        chips=r["chips"], hlo_flops=r["hlo_flops"], hlo_bytes=r["hlo_bytes"],
        collective_bytes=r["collective_bytes"], model_flops_total=mf,
    ).finalize()


def run_optimized_comparison() -> List[Tuple[str, float, str]]:
    """§Perf: baseline vs optimized (tp_block=shard_map + bf16 scores)
    dominant-term comparison for the train cells."""
    rows: List[Tuple[str, float, str]] = []
    print("\n# §Perf — train_4k baseline vs optimized (single-pod)")
    print(f"{'arch':22s} {'base dom (s)':>12s} {'opt dom (s)':>12s} "
          f"{'speedup':>8s} {'base frac':>10s} {'opt frac':>9s}")
    for arch in ARCH_IDS:
        pair = {}
        for tag, label in (("", "base"), ("_opt2", "opt")):
            f = DRYRUN_DIR / f"{arch}_train_4k_16x16{tag}.json"
            if not f.exists():
                continue
            cell = json.loads(f.read_text())
            rl = rebuilt_roofline(cell)
            if rl is not None:
                pair[label] = rl
        if "base" not in pair or "opt" not in pair:
            continue
        db = max(pair["base"].compute_s, pair["base"].memory_s,
                 pair["base"].collective_s)
        do = max(pair["opt"].compute_s, pair["opt"].memory_s,
                 pair["opt"].collective_s)
        fb = pair["base"].compute_s / db if db else 0
        fo = pair["opt"].compute_s / do if do else 0
        print(f"{arch:22s} {db:12.2f} {do:12.2f} {db/do:7.2f}x "
              f"{fb:10.3f} {fo:9.3f}")
        rows.append((f"perf/{arch}/train_4k", do * 1e6,
                     f"speedup={db/do:.2f}x frac={fo:.3f}"))
    return rows


def run(tag: str = "") -> List[Tuple[str, float, str]]:
    cells = load_cells(tag=tag)
    rows: List[Tuple[str, float, str]] = []
    print("# §Roofline — single-pod (16x16, 256 chips), terms in ms "
          "(compute | memory | collective), bottleneck, useful ratio")
    print(f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>10s} "
          f"{'coll':>10s}  {'bound':10s} {'useful':>7s} {'frac':>6s}")
    for arch in ARCH_IDS:
        for shape in SHAPES:
            cfg = get_config(arch)
            if not shape_applicable(cfg, shape):
                if any(c["arch"] == arch and c["shape"] == shape
                       for c in cells):
                    pass
                print(f"{arch:22s} {shape:12s} {'—':>9s} {'—':>10s} {'—':>10s}"
                      f"  SKIP (full attention @500k)")
                continue
            match = [c for c in cells if c["arch"] == arch
                     and c["shape"] == shape]
            if not match:
                continue
            rl = rebuilt_roofline(match[0])
            if rl is None:
                continue
            dominant = max(rl.compute_s, rl.memory_s, rl.collective_s)
            frac = rl.compute_s / dominant if dominant else 0.0
            print(f"{arch:22s} {shape:12s} {rl.compute_s*1e3:9.1f} "
                  f"{rl.memory_s*1e3:10.1f} {rl.collective_s*1e3:10.1f}  "
                  f"{rl.bottleneck:10s} {rl.useful_ratio:7.2f} {frac:6.2f}")
            rows.append((f"roofline/{arch}/{shape}", dominant * 1e6,
                         f"bound={rl.bottleneck} frac={frac:.3f}"))
    rows.extend(run_optimized_comparison())
    return rows


if __name__ == "__main__":
    run()
