"""Paper Fig. 10: execution traces of the three algorithms on the real
runtime — per-worker timelines (ASCII Gantt standing in for Paraver),
per-task-type duration stats, utilization, and serialization share."""
from __future__ import annotations

from typing import List, Tuple

from repro.algorithms import kmeans, knn, linreg
from repro.core import api


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    jobs = {
        "KNN": lambda: knn.run_knn(n_train=1500, n_test=1200, d=30, k=5,
                                   train_fragments=4, test_blocks=4),
        "KMeans": lambda: kmeans.run_kmeans(n_points=30_000, d=20, k=8,
                                            fragments=8, max_iters=4),
        "LinReg": lambda: linreg.run_linreg(n_rows=20_000, p=80, n_pred=4_000,
                                            fragments=8, pred_blocks=4),
    }
    print("# Fig. 10 analogue — execution traces (4 workers)")
    for name, job in jobs.items():
        api.runtime_start(n_workers=4, policy="locality", tracing=True)
        try:
            job()
            api.barrier()
            rt = api.current_runtime()
            util = rt.tracer.utilization(4)
            stats = rt.tracer.task_duration_stats()
            print(f"\n--- {name} ---")
            print(rt.tracer.ascii_gantt(width=88))
            print(f"utilization={util:.2f}  tasks={rt.stats()['tasks_done']}  "
                  f"critical_path={rt.graph.critical_path_seconds()*1e3:.1f}ms")
            for tname, st in sorted(stats.items()):
                print(f"  {tname:24s} n={st['count']:3d} mean={st['mean']*1e3:7.2f}ms "
                      f"p50={st['p50']*1e3:7.2f}ms max={st['max']*1e3:7.2f}ms")
            rows.append((f"trace/{name.lower()}_utilization", 0.0,
                         f"util={util:.3f}"))
        finally:
            api.runtime_stop(wait=False)
    return rows


if __name__ == "__main__":
    run()
