"""Paper Figs. 6 & 7: single-node weak/strong scaling of KNN, K-means,
linear regression.

Methodology (DESIGN.md §8): per-task cost models are calibrated by timing
the *real* task functions on this machine, then the *same DAGs* the runtime
builds are replayed through the discrete-event simulator over 1..128 virtual
workers with a Shaheen-like machine model (per-task master dispatch overhead
is what produces the paper's roll-off at high core counts).

Validation targets from the paper (§5.2): KNN weak efficiency > 70% at 128
cores, K-means > 60%; linreg declines with dependency depth (~41% at 128).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from repro.algorithms import kmeans, knn, linreg
from repro.core.simulator import MachineModel, simulate

CORES = (1, 2, 4, 8, 16, 32, 64, 128)
# Shaheen-III-like single node: shared memory (no transfers), small serial
# dispatch cost per task at the master
MACHINE = dict(bandwidth_Bps=100e9, latency_s=2e-6, ser_Bps=None,
               dispatch_overhead_s=0.4e-3)


def _machine(workers: int) -> MachineModel:
    return MachineModel(n_nodes=1, workers_per_node=workers, **MACHINE)


def knn_dags(costs):
    # paper-regime task sizes (their Fig. 6/7 runtimes are 1e2..1e5 s,
    # i.e. seconds-long tasks): test rows scale with cores; train fixed
    def weak(n):
        return knn.dag_spec(costs, n_train=2000, n_test=20_000 * n, d=50, k=5,
                            train_fragments=4, test_blocks=max(n, 1))

    def strong(n):  # paper sizes: train 1,228,800 x 50; test 64,000 x 50
        return knn.dag_spec(costs, n_train=1_228_800, n_test=64_000, d=50,
                            k=5, train_fragments=128, test_blocks=8)

    return weak, strong


def kmeans_dags(costs):
    def weak(n):  # paper: 864,000 x 50 per core
        return kmeans.dag_spec(costs, n_points=400_000 * n, d=50, k=8,
                               fragments=max(n, 1), iterations=5)

    def strong(n):  # paper: 51,200,000 x 100 total
        return kmeans.dag_spec(costs, n_points=12_800_000, d=50, k=8,
                               fragments=128, iterations=5)

    return weak, strong


def linreg_dags(costs):
    def weak(n):  # paper: 80,000 x 1000 per core (p scaled to calib)
        return linreg.dag_spec(costs, n_rows=50_000 * n, p=200,
                               n_pred=12_500 * n, fragments=max(n, 1),
                               pred_blocks=max(n, 1))

    def strong(n):  # paper: 10,240,000 x 1000 total
        return linreg.dag_spec(costs, n_rows=6_400_000, p=200,
                               n_pred=1_600_000, fragments=128,
                               pred_blocks=128)

    return weak, strong


def scaling_table(mode: str, dag_fn: Callable, cores=CORES) -> Dict[int, float]:
    eff = {}
    if mode == "weak":
        t1 = simulate(dag_fn(1), _machine(1)).makespan
        for n in cores:
            tn = simulate(dag_fn(n), _machine(n)).makespan
            eff[n] = t1 / tn
    else:
        t1 = simulate(dag_fn(1), _machine(1)).makespan
        for n in cores:
            tn = simulate(dag_fn(n), _machine(n)).makespan
            eff[n] = t1 / (n * tn)
    return eff


def run() -> List[Tuple[str, float, str]]:
    print("# Figs. 6/7 analogue — single-node weak/strong scaling efficiency")
    print("calibrating task cost models on this machine ...")
    costs = {
        "KNN": knn.calibrate(d=50, k=5, units=(500, 1000, 2000)),
        "KMeans": kmeans.calibrate(d=50, k=8, units=(4000, 10000, 20000)),
        "LinReg": linreg.calibrate(p=200, units=(1000, 2000, 4000)),
    }
    dagmakers = {"KNN": knn_dags, "KMeans": kmeans_dags, "LinReg": linreg_dags}
    rows: List[Tuple[str, float, str]] = []
    results = {}
    for mode_i, mode in enumerate(("weak", "strong")):
        print(f"\n== {mode} scaling ==")
        print("algo    " + "".join(f"{n:>8d}" for n in CORES))
        for name in ("KNN", "KMeans", "LinReg"):
            weak_fn, strong_fn = dagmakers[name](costs[name])
            eff = scaling_table(mode, weak_fn if mode == "weak" else strong_fn)
            results[(name, mode)] = eff
            print(f"{name:7s} " + "".join(f"{eff[n]:8.2f}" for n in CORES))
            rows.append((f"scaling/{mode}/{name.lower()}@128",
                         0.0, f"eff={eff[128]:.3f}"))
    # paper-claim checks (§5.2, Shaheen-III)
    checks = [
        ("KNN weak eff@128 > 0.70", results[("KNN", "weak")][128] > 0.70),
        ("KMeans weak eff@128 > 0.60", results[("KMeans", "weak")][128] > 0.60),
        ("LinReg weak declines with depth",
         results[("LinReg", "weak")][128] < results[("LinReg", "weak")][16]),
        ("KNN strong eff@64 > 0.80", results[("KNN", "strong")][64] > 0.80),
    ]
    print("\npaper-claim validation:")
    for label, ok in checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {label}")
    rows.append(("scaling/claims_passed", 0.0,
                 f"{sum(ok for _, ok in checks)}/{len(checks)}"))
    return rows


if __name__ == "__main__":
    run()
