"""Paper Figs. 6 & 7: single-node weak/strong scaling of KNN, K-means,
linear regression — plus a *live* executor-backend axis.

Methodology (DESIGN.md §8): per-task cost models are calibrated by timing
the *real* task functions on this machine, then the *same DAGs* the runtime
builds are replayed through the discrete-event simulator over 1..128 virtual
workers with a Shaheen-like machine model (per-task master dispatch overhead
is what produces the paper's roll-off at high core counts).

The ``--backend`` axis (DESIGN.md §11) measures *real* strong scaling of a
CPU-bound pure-Python task through the runtime, thread vs process
executors: threads serialize on the GIL, persistent worker processes
reproduce the paper's per-node worker parallelism.  Run e.g.::

    PYTHONPATH=src python benchmarks/scaling_single_node.py --backend both

Validation targets from the paper (§5.2): KNN weak efficiency > 70% at 128
cores, K-means > 60%; linreg declines with dependency depth (~41% at 128).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.algorithms import kmeans, knn, linreg
from repro.core.runtime import Runtime
from repro.core.simulator import MachineModel, simulate

CORES = (1, 2, 4, 8, 16, 32, 64, 128)
# Shaheen-III-like single node: shared memory (no transfers), small serial
# dispatch cost per task at the master
MACHINE = dict(bandwidth_Bps=100e9, latency_s=2e-6, ser_Bps=None,
               dispatch_overhead_s=0.4e-3)


def _machine(workers: int) -> MachineModel:
    return MachineModel(n_nodes=1, workers_per_node=workers, **MACHINE)


def knn_dags(costs):
    # paper-regime task sizes (their Fig. 6/7 runtimes are 1e2..1e5 s,
    # i.e. seconds-long tasks): test rows scale with cores; train fixed
    def weak(n):
        return knn.dag_spec(costs, n_train=2000, n_test=20_000 * n, d=50, k=5,
                            train_fragments=4, test_blocks=max(n, 1))

    def strong(n):  # paper sizes: train 1,228,800 x 50; test 64,000 x 50
        return knn.dag_spec(costs, n_train=1_228_800, n_test=64_000, d=50,
                            k=5, train_fragments=128, test_blocks=8)

    return weak, strong


def kmeans_dags(costs):
    def weak(n):  # paper: 864,000 x 50 per core
        return kmeans.dag_spec(costs, n_points=400_000 * n, d=50, k=8,
                               fragments=max(n, 1), iterations=5)

    def strong(n):  # paper: 51,200,000 x 100 total
        return kmeans.dag_spec(costs, n_points=12_800_000, d=50, k=8,
                               fragments=128, iterations=5)

    return weak, strong


def linreg_dags(costs):
    def weak(n):  # paper: 80,000 x 1000 per core (p scaled to calib)
        return linreg.dag_spec(costs, n_rows=50_000 * n, p=200,
                               n_pred=12_500 * n, fragments=max(n, 1),
                               pred_blocks=max(n, 1))

    def strong(n):  # paper: 10,240,000 x 1000 total
        return linreg.dag_spec(costs, n_rows=6_400_000, p=200,
                               n_pred=1_600_000, fragments=128,
                               pred_blocks=128)

    return weak, strong


def scaling_table(mode: str, dag_fn: Callable, cores=CORES) -> Dict[int, float]:
    eff = {}
    if mode == "weak":
        t1 = simulate(dag_fn(1), _machine(1)).makespan
        for n in cores:
            tn = simulate(dag_fn(n), _machine(n)).makespan
            eff[n] = t1 / tn
    else:
        t1 = simulate(dag_fn(1), _machine(1)).makespan
        for n in cores:
            tn = simulate(dag_fn(n), _machine(n)).makespan
            eff[n] = t1 / (n * tn)
    return eff


# --------------------------------------------------- live backend axis (§11)
def _spin(units: int) -> int:
    """CPU-bound pure-Python work: never releases the GIL, so thread
    workers serialize on it while process workers run truly parallel."""
    acc = 0
    for i in range(units * 10_000):
        acc += (i * i) ^ (acc >> 3)
    return acc


def measure_backend(backend: str, n_workers: int, n_tasks: int = 32,
                    units: int = 10) -> float:
    """Wall-seconds to drain ``n_tasks`` CPU-bound tasks on the real
    runtime (startup/shutdown excluded — the paper's persistent workers
    amortize those over the application)."""
    rt = Runtime(n_workers=n_workers, backend=backend, tracing=False)
    try:
        rt.wait_on(rt.submit(_spin, (1,), name="warmup"))  # ship code once
        t0 = time.perf_counter()
        for _ in range(n_tasks):
            rt.submit(_spin, (units,), name="spin")
        rt.barrier()
        return time.perf_counter() - t0
    finally:
        rt.stop(wait=False)


def run_backend_axis(backends=("thread", "process"), cores=(1, 2, 4, 8),
                     n_tasks: int = 32, units: int = 10
                     ) -> List[Tuple[str, float, str]]:
    print("# executor-backend strong scaling — CPU-bound pure-Python task")
    print(f"{n_tasks} tasks, {units * 10_000} loop iterations each")
    rows: List[Tuple[str, float, str]] = []
    walls: Dict[Tuple[str, int], float] = {}
    print("backend " + "".join(f"{n:>9d}" for n in cores))
    for backend in backends:
        line = f"{backend:8s}"
        for n in cores:
            wall = measure_backend(backend, n, n_tasks=n_tasks, units=units)
            walls[(backend, n)] = wall
            line += f"{wall:8.2f}s"
            rows.append((f"scaling/backend/{backend}@{n}",
                         wall / n_tasks * 1e6, f"wall={wall:.3f}s"))
        print(line)
    if set(backends) >= {"thread", "process"}:
        for n in cores:
            sp = walls[("thread", n)] / max(walls[("process", n)], 1e-9)
            rows.append((f"scaling/backend/process_speedup@{n}", 0.0,
                         f"speedup={sp:.2f}x"))
        top = cores[-1]
        sp = walls[("thread", top)] / max(walls[("process", top)], 1e-9)
        print(f"\nprocess-vs-thread speedup @ {top} workers: {sp:.2f}x "
              f"(CPU-bound pure-Python; GIL holds threads at ~1 core)")
    return rows


def measure_dispatch_overhead(backend: str, n_workers: int = 2,
                              n_tasks: int = 200, repeats: int = 5,
                              pipeline_depth: int = None) -> float:
    """Per-task master overhead in µs: drain ``n_tasks`` no-op tasks and
    divide.  Min over ``repeats`` — the stable statistic for a gate.

    Startup effects are excluded, matching the paper's persistent-worker
    model (§5.4 treats worker init as a separate, amortized cost): the
    first process-backend runtime in an interpreter pays one-time
    copy-on-write page faults in its freshly forked workers, so a
    throwaway warm-up runtime runs first."""
    if backend == "process":
        warm = Runtime(n_workers=n_workers, backend=backend, tracing=False,
                       pipeline_depth=pipeline_depth)
        try:
            for _ in range(50):
                warm.submit(_spin, (0,), name="warm")
            warm.barrier()
        finally:
            warm.stop(wait=False)
    rt = Runtime(n_workers=n_workers, backend=backend, tracing=False,
                 pipeline_depth=pipeline_depth)
    try:
        rt.wait_on(rt.submit(_spin, (0,), name="warmup"))
        best = float("inf")
        for i in range(repeats):
            if i:
                # spread repeats in time: CPU-supply noise on shared boxes
                # comes in multi-second bursts, so back-to-back repeats
                # would all land inside one burst and min() couldn't dodge
                time.sleep(0.4)
            t0 = time.perf_counter()
            for _ in range(n_tasks):
                rt.submit(_spin, (0,), name="noop")
            rt.barrier()
            best = min(best, (time.perf_counter() - t0) / n_tasks * 1e6)
        return best
    finally:
        rt.stop(wait=False)


def measure_telemetry_overhead(n_workers: int = 2, n_tasks: int = 200,
                               repeats: int = 5) -> Dict[str, float]:
    """Process-backend dispatch overhead with the telemetry plane on vs
    off (DESIGN.md §17) — the gate that keeps instrumentation off the
    hot path.  Both runtimes live for the whole measurement and the
    timing rounds interleave on/off, so multi-second CPU-supply bursts
    on shared boxes hit both configurations instead of biasing one; min
    per configuration is the reported statistic."""
    warm = Runtime(n_workers=n_workers, backend="process", tracing=False)
    try:   # first fork in the interpreter pays one-time COW page faults
        for _ in range(50):
            warm.submit(_spin, (0,), name="warm")
        warm.barrier()
    finally:
        warm.stop(wait=False)
    rts = {
        "off": Runtime(n_workers=n_workers, backend="process",
                       tracing=False, telemetry=False),
        "on": Runtime(n_workers=n_workers, backend="process",
                      tracing=False, telemetry=True),
    }
    best = {"off": float("inf"), "on": float("inf")}
    try:
        for rt in rts.values():
            rt.wait_on(rt.submit(_spin, (0,), name="warmup"))
        for i in range(repeats):
            if i:
                time.sleep(0.3)
            for label, rt in rts.items():
                t0 = time.perf_counter()
                for _ in range(n_tasks):
                    rt.submit(_spin, (0,), name="noop")
                rt.barrier()
                best[label] = min(
                    best[label], (time.perf_counter() - t0) / n_tasks * 1e6)
    finally:
        for rt in rts.values():
            rt.stop(wait=False)
    return {k: round(v, 1) for k, v in best.items()}


def run_depth_sweep(depths=(1, 2, 4), n_workers: int = 2) -> dict:
    """Dispatch overhead of the process backend per pipeline depth
    (DESIGN.md §14).  Depth 1 is the old stop-and-wait dispatch — its
    number is the pre-pipeline baseline reproduced live."""
    out = {}
    print("# pipeline-depth sweep — process dispatch overhead")
    for d in depths:
        out[str(d)] = round(measure_dispatch_overhead(
            "process", n_workers=n_workers, pipeline_depth=d), 1)
        print(f"  depth {d}: {out[str(d)]:8.1f} us/task")
    return out


# ----------------------------------------------------- out-of-core probe
def run_out_of_core(backend: str = "process", budget: str = "400K") -> dict:
    """K-means with the working set (~1.3 MB of fragments) over a 400 KB
    per-domain budget: reports the spill/fault ledger and whether the
    bounded run matches the unbounded one bitwise (DESIGN.md §13)."""
    from repro.core import api

    def one(mem):
        rt = api.runtime_start(n_workers=2, backend=backend,
                               policy="locality", memory_budget=mem,
                               tracing=False)
        try:
            res = kmeans.run_kmeans(n_points=16000, d=10, k=4, fragments=8,
                                    max_iters=4, seed=0)
            return res, rt.stats()
        finally:
            api.runtime_stop(wait=False)

    ref, _ = one(None)
    res, stats = one(budget)
    mem = stats["memory"]
    ex = stats["executor"]
    out = {
        "backend": backend,
        "budget": budget,
        "spills": mem["spills"],
        "faults": mem["faults"],
        "spill_bytes": mem["spill_bytes"],
        "plane_spills": ex.get("plane_spills", 0),
        "plane_faults": ex.get("plane_faults", 0),
        "match": bool(np.array_equal(ref.centroids, res.centroids)
                      and ref.sse == res.sse),
    }
    print(f"out-of-core k-means [{backend}, budget {budget}]: "
          f"{out['spills']} spills / {out['faults']} faults "
          f"(plane: {out['plane_spills']}/{out['plane_faults']}), "
          f"bitwise match: {out['match']}")
    return out


# ------------------------------------------------------------- quick mode
def run_quick() -> dict:
    """CI-sized measurement set: dispatch overhead per backend, simulated
    scaling efficiency at the paper's core counts, and the out-of-core
    spill/fault ledger — the payload of ``BENCH_pr.json``."""
    from repro.core.runtime import pipeline_depth_from_env

    print("# quick bench — dispatch overhead")
    overhead = {}
    for backend in ("thread", "process"):
        overhead[backend] = round(measure_dispatch_overhead(backend), 1)
        print(f"  {backend:8s} {overhead[backend]:8.1f} us/task")
    sweep = run_depth_sweep()
    # the sweep's default-depth entry measures the same configuration as
    # the headline number: fold it in (min is the documented statistic)
    default_depth = str(pipeline_depth_from_env())
    if default_depth in sweep:
        overhead["process"] = min(overhead["process"], sweep[default_depth])
        print(f"  process (min with sweep depth {default_depth}): "
              f"{overhead['process']:.1f} us/task")
    print("# quick bench — simulated weak/strong efficiency @128 cores")
    costs = {
        "knn": knn.calibrate(d=50, k=5, units=(250, 500, 1000)),
        "kmeans": kmeans.calibrate(d=50, k=8, units=(2000, 5000, 10000)),
        "linreg": linreg.calibrate(p=200, units=(500, 1000, 2000)),
    }
    dagmakers = {"knn": knn_dags, "kmeans": kmeans_dags, "linreg": linreg_dags}
    eff = {"weak": {}, "strong": {}}
    for name, maker in dagmakers.items():
        weak_fn, strong_fn = maker(costs[name])
        for mode, fn in (("weak", weak_fn), ("strong", strong_fn)):
            table = scaling_table(mode, fn, cores=(1, 128))
            eff[mode][name] = round(table[128], 3)
            print(f"  {name:7s} {mode:6s} eff@128 = {table[128]:.3f}")
    ooc = run_out_of_core()
    ooc_thread = run_out_of_core(backend="thread")
    print("# quick bench — telemetry overhead (process backend)")
    tel = measure_telemetry_overhead()
    print(f"  telemetry on {tel['on']:.1f} us/task vs off {tel['off']:.1f}")
    return {
        "dispatch_overhead_us": overhead,
        "pipeline_depth_sweep_us": {"process": sweep},
        "weak_eff@128": eff["weak"],
        "strong_eff@128": eff["strong"],
        "out_of_core": ooc,
        "out_of_core_thread": ooc_thread,
        "telemetry_overhead_us": tel,
    }


def run() -> List[Tuple[str, float, str]]:
    print("# Figs. 6/7 analogue — single-node weak/strong scaling efficiency")
    print("calibrating task cost models on this machine ...")
    costs = {
        "KNN": knn.calibrate(d=50, k=5, units=(500, 1000, 2000)),
        "KMeans": kmeans.calibrate(d=50, k=8, units=(4000, 10000, 20000)),
        "LinReg": linreg.calibrate(p=200, units=(1000, 2000, 4000)),
    }
    dagmakers = {"KNN": knn_dags, "KMeans": kmeans_dags, "LinReg": linreg_dags}
    rows: List[Tuple[str, float, str]] = []
    results = {}
    for mode_i, mode in enumerate(("weak", "strong")):
        print(f"\n== {mode} scaling ==")
        print("algo    " + "".join(f"{n:>8d}" for n in CORES))
        for name in ("KNN", "KMeans", "LinReg"):
            weak_fn, strong_fn = dagmakers[name](costs[name])
            eff = scaling_table(mode, weak_fn if mode == "weak" else strong_fn)
            results[(name, mode)] = eff
            print(f"{name:7s} " + "".join(f"{eff[n]:8.2f}" for n in CORES))
            rows.append((f"scaling/{mode}/{name.lower()}@128",
                         0.0, f"eff={eff[128]:.3f}"))
    # paper-claim checks (§5.2, Shaheen-III)
    checks = [
        ("KNN weak eff@128 > 0.70", results[("KNN", "weak")][128] > 0.70),
        ("KMeans weak eff@128 > 0.60", results[("KMeans", "weak")][128] > 0.60),
        ("LinReg weak declines with depth",
         results[("LinReg", "weak")][128] < results[("LinReg", "weak")][16]),
        ("KNN strong eff@64 > 0.80", results[("KNN", "strong")][64] > 0.80),
    ]
    print("\npaper-claim validation:")
    for label, ok in checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {label}")
    rows.append(("scaling/claims_passed", 0.0,
                 f"{sum(ok for _, ok in checks)}/{len(checks)}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="sim",
                    choices=("sim", "thread", "process", "both"),
                    help="'sim' replays calibrated DAGs through the "
                         "discrete-event simulator (paper Figs. 6/7); "
                         "'thread'/'process'/'both' measure real strong "
                         "scaling of the executor backends")
    ap.add_argument("--workers", default="1,2,4,8",
                    help="comma list of worker counts for the backend axis")
    ap.add_argument("--tasks", type=int, default=32)
    ap.add_argument("--units", type=int, default=10,
                    help="per-task CPU work, in 10k-iteration units")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: dispatch overhead, eff@128, "
                         "out-of-core ledger (pairs with --json)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the quick-mode measurements as JSON "
                         "(merged into BENCH_pr.json by bench_gate.py)")
    ap.add_argument("--out-of-core", action="store_true",
                    help="only run the out-of-core k-means probe")
    args = ap.parse_args()
    if args.out_of_core:
        run_out_of_core()
        return
    if args.quick:
        payload = {"single_node": run_quick()}
        if args.json:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"wrote {args.json}")
        return
    if args.backend == "sim":
        run()
        return
    backends = ("thread", "process") if args.backend == "both" else (args.backend,)
    cores = tuple(int(c) for c in args.workers.split(","))
    rows = run_backend_axis(backends, cores, n_tasks=args.tasks,
                            units=args.units)
    print("\n# CSV summary")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
