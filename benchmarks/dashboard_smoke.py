"""CI smoke test for the live telemetry plane (DESIGN.md §17).

Boots a 3-agent LocalCluster with the dashboard on an ephemeral port,
runs a small fan-out of real tasks, then polls every HTTP endpoint and
asserts the cross-endpoint consistency the acceptance criteria name:
the status view reports all nodes heartbeating, the task ring contains
the run's lifecycle events, and the transfer matrix sums match the p2p
/ relay byte ledgers.  Exits non-zero on any violation so the
cluster-smoke CI job fails loudly.

    PYTHONPATH=src python benchmarks/dashboard_smoke.py
"""
from __future__ import annotations

import json
import sys
import time
from urllib.request import urlopen

import numpy as np

from repro.core import api
from repro.cluster.cluster import LocalCluster

N_AGENTS = 3
HEARTBEAT_S = 0.2


def _get(url: str):
    with urlopen(url, timeout=10) as resp:
        if resp.status != 200:
            raise AssertionError(f"{url}: HTTP {resp.status}")
        return json.loads(resp.read())


def _chunk(i):
    return np.full(4096, i, dtype=np.float64)


def _merge(*parts):
    return float(sum(p.sum() for p in parts))


def main() -> int:
    failures = []

    def check(label, ok, detail=""):
        print(f"  [{'ok' if ok else 'FAIL'}] {label}" +
              (f" ({detail})" if detail else ""))
        if not ok:
            failures.append(label)

    with LocalCluster(n_agents=N_AGENTS, workers_per_node=1) as cluster:
        cluster.heartbeat_s = HEARTBEAT_S
        rt = api.runtime_start(backend="cluster", cluster=cluster,
                               dashboard_port=0)
        try:
            url = rt.dashboard.url
            print(f"dashboard at {url}")
            # fan-out -> merge so results move between nodes (p2p traffic)
            chunks = [api.task(_chunk, name="chunk")(i) for i in range(9)]
            total = api.task(_merge, name="merge")(*chunks)
            got = api.wait_on(total)
            check("task result", got == float(sum(i * 4096 for i in range(9))),
                  f"got {got}")
            time.sleep(HEARTBEAT_S * 3)   # let every agent beat a few times

            st = _get(url + "api/status")
            check("status backend", st.get("backend") == "cluster")
            check("status telemetry enabled", st.get("telemetry_enabled"))
            nodes = st.get("nodes", {})
            check(f"all {N_AGENTS} nodes heartbeating",
                  sorted(nodes) == [str(i) for i in range(N_AGENTS)],
                  f"nodes={sorted(nodes)}")
            check("heartbeat payloads carry plane stats",
                  all("plane_entries" in n for n in nodes.values()))
            check("tasks done counted",
                  st.get("tasks", {}).get("done", 0) >= 10,
                  f"done={st.get('tasks', {}).get('done')}")

            tk = _get(url + "api/tasks")
            kinds = {e["kind"] for e in tk["events"]}
            check("ring has full lifecycle",
                  {"submit", "dispatch", "done"} <= kinds, f"kinds={kinds}")
            check("ring watermark advances", tk["last_seq"] > 0)

            tr = _get(url + "api/transfers")
            mat = tr.get("matrix", [])
            mat_p2p = sum(e["bytes"] for e in mat if e["src"] >= 0)
            mat_relay = sum(e["bytes"] for e in mat if e["src"] < 0)
            check("matrix p2p sum matches ledger",
                  mat_p2p == tr["p2p_bytes"],
                  f"{mat_p2p} vs {tr['p2p_bytes']}")
            check("matrix relay sum matches ledger",
                  mat_relay == tr["scheduler_relay_bytes"],
                  f"{mat_relay} vs {tr['scheduler_relay_bytes']}")
            check("p2p traffic observed", tr["p2p_bytes"] > 0)

            with urlopen(url + "api/trace", timeout=10) as resp:
                trace = json.loads(resp.read())
            check("chrome trace has task events",
                  any(e.get("ph") == "X" for e in trace["traceEvents"]))
            with urlopen(url, timeout=10) as resp:
                page = resp.read().decode()
            check("dashboard page served", "Task stream" in page)
        finally:
            api.runtime_stop(wait=False)

    if failures:
        print(f"\ndashboard smoke FAILED: {failures}")
        return 1
    print("\ndashboard smoke passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
