"""Paper Figs. 8 & 9: multi-node weak/strong scaling (up to 32 nodes).

Same calibrated-DES methodology as the single-node bench, with the
distributed machine model: 64 workers per node, inter-node transport
(bandwidth + latency) and serialization at the measured codec throughput —
the paper's file-based parameter passing between address spaces.

Validation targets (§5.3): KNN weak efficiency ≥ ~78% at 32 nodes; K-means
moderate (≥ ~60%); strong-scaling efficiency degrades for all three at 32
nodes (paper: 28-56%).

``--live`` additionally runs the REAL multi-node path (DESIGN.md §12): a
``LocalCluster`` of TCP node agents executes the same KNN tile pipeline at
each agent count, and the measured DAG is replayed through the simulator
on a matching machine model — measured vs simulated efficiency side by
side validates the DES against real wire/dispatch costs.

    PYTHONPATH=src python benchmarks/scaling_multi_node.py --live \
        [--agents 1,2] [--wpn 2]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import List, Tuple

from repro.algorithms import kmeans, knn, linreg
from repro.core.simulator import CostModel, MachineModel, replay_graph, simulate

NODES = (1, 2, 4, 8, 16, 32)
WPN = 64  # workers per node

# The paper's tasks execute in R (single-threaded, interpreted around BLAS);
# our calibration runs numpy.  The R/numpy slowdown for these fragment
# kernels is O(50x) (paper Fig. 8: ~1e3 s/node weak KNN vs our ~20 s of
# numpy work/node).  Task durations are scaled by this factor so the
# master-dispatch and transport fractions match the paper's regime —
# without it the simulated master is 50x more prominent than COMPSs' was.
R_SLOWDOWN = 50.0


def _scale_costs(costs):
    def s(cm: CostModel) -> CostModel:
        return CostModel(cm.a * R_SLOWDOWN, cm.b * R_SLOWDOWN, cm.name)
    return type(costs)(**{f.name: s(getattr(costs, f.name))
                          for f in dataclasses.fields(costs)})


def _machine(nodes: int) -> MachineModel:
    return MachineModel(
        n_nodes=nodes, workers_per_node=WPN,
        bandwidth_Bps=25e9,        # slingshot-class per-node
        latency_s=5e-6,
        ser_Bps=2e9,               # measured raw-codec throughput
        dispatch_overhead_s=1e-3,  # COMPSs master per-task staging cost
        worker_init_s=120.0,       # per-worker startup (paper §5.4) —
                                   # amortized in weak runs, not in strong
    )


def run() -> List[Tuple[str, float, str]]:
    print("# Figs. 8/9 analogue — multi-node weak/strong scaling efficiency")
    print("calibrating task cost models ...")
    kc = _scale_costs(knn.calibrate(d=50, k=5, units=(500, 1000, 2000)))
    mc = _scale_costs(kmeans.calibrate(d=50, k=8, units=(4000, 10000, 20000)))
    lc = _scale_costs(linreg.calibrate(p=200, units=(1000, 2000, 4000)))

    def knn_weak(n):  # paper: test 1,016,000 x 50 per node, train 8000
        return knn.dag_spec(kc, n_train=8000, n_test=1_000_000 * n, d=50,
                            k=5, train_fragments=8, test_blocks=WPN * n)

    def knn_strong(n):  # paper: test 32,760,000 x 50 total
        return knn.dag_spec(kc, n_train=8000, n_test=32_760_000, d=50, k=5,
                            train_fragments=8, test_blocks=WPN * 32)

    def km_weak(n):  # paper: 38,182,528 x 100 per node
        return kmeans.dag_spec(mc, n_points=38_000_000 * n, d=50, k=8,
                               fragments=WPN * n, iterations=5)

    def km_strong(n):  # paper: 1,221,840,896 x 100 total
        return kmeans.dag_spec(mc, n_points=1_221_840_896, d=50, k=8,
                               fragments=WPN * 32, iterations=5)

    def lr_weak(n):  # paper: 2,560,000 x 1000 per node
        return linreg.dag_spec(lc, n_rows=2_560_000 * n, p=200,
                               n_pred=640_000 * n, fragments=WPN * n,
                               pred_blocks=WPN * n)

    def lr_strong(n):  # paper: 81,920,000 x 1000 total
        return linreg.dag_spec(lc, n_rows=81_920_000, p=200,
                               n_pred=20_480_000, fragments=WPN * 32,
                               pred_blocks=WPN * 32)

    algos = {"KNN": (knn_weak, knn_strong), "KMeans": (km_weak, km_strong),
             "LinReg": (lr_weak, lr_strong)}
    rows: List[Tuple[str, float, str]] = []
    results = {}
    for mode_i, mode in enumerate(("weak", "strong")):
        print(f"\n== {mode} scaling (x{WPN} workers/node) ==")
        print("algo    " + "".join(f"{n:>8d}" for n in NODES))
        for name, (weak_fn, strong_fn) in algos.items():
            fn = weak_fn if mode == "weak" else strong_fn
            t1 = simulate(fn(1), _machine(1)).makespan
            eff = {}
            for n in NODES:
                tn = simulate(fn(n), _machine(n)).makespan
                eff[n] = (t1 / tn) if mode == "weak" else (t1 / (n * tn))
            results[(name, mode)] = eff
            print(f"{name:7s} " + "".join(f"{eff[n]:8.2f}" for n in NODES))
            rows.append((f"scaling_multi/{mode}/{name.lower()}@32",
                         0.0, f"eff={eff[32]:.3f}"))
    checks = [
        ("KNN weak eff@32 >= 0.70 (paper: 78-95%)",
         results[("KNN", "weak")][32] >= 0.70),
        ("KMeans weak eff@32 >= 0.55 (paper: 61-64%)",
         results[("KMeans", "weak")][32] >= 0.55),
        ("KNN strong eff@32 in paper band 0.30-0.75 (paper: 44-56%)",
         0.30 <= results[("KNN", "strong")][32] <= 0.75),
        ("strong scaling degrades at 32 nodes (paper: 28-70%)",
         all(results[(a, "strong")][32] < 0.85 for a in ("KNN", "KMeans",
                                                         "LinReg"))),
    ]
    print("\npaper-claim validation:")
    for label, ok in checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {label}")
    rows.append(("scaling_multi/claims_passed", 0.0,
                 f"{sum(ok for _, ok in checks)}/{len(checks)}"))
    return rows


def _bsum(a):
    return float(a.sum())


# --------------------------------------------------------------- live mode
def _localhost_machine(n_agents: int, wpn: int) -> MachineModel:
    """A machine model matching the LocalCluster path: loopback TCP
    transport, raw-codec serialization, measured-scale dispatch cost."""
    return MachineModel(
        n_nodes=n_agents, workers_per_node=wpn,
        bandwidth_Bps=4e9,          # loopback TCP, one copy per side
        latency_s=60e-6,
        ser_Bps=2e9,                # raw codec measured throughput
        dispatch_overhead_s=1.2e-3,  # TCP request/response per task
    )


def run_live(agent_counts=(1, 2), wpn: int = 2, json_path: str = None,
             trace_path: str = None) -> List[Tuple[str, float, str]]:
    """Measured vs simulated efficiency on real TCP node agents.

    ``trace_path`` writes the largest run's task timeline as Chrome-trace
    JSON (DESIGN.md §17) — open in Perfetto / chrome://tracing; CI uploads
    it as an artifact so every bench run leaves an inspectable timeline."""
    from repro.core import api

    print(f"# live multi-node scaling — LocalCluster, {wpn} workers/agent")
    print(f"{'agents':>7} {'measured_s':>11} {'sim_s':>8} "
          f"{'meas_eff':>9} {'sim_eff':>8}")
    rows: List[Tuple[str, float, str]] = []
    measured = {}
    simulated = {}
    for n in agent_counts:
        api.runtime_start(backend="cluster", n_agents=n, workers_per_node=wpn)
        try:
            # weak scaling: test rows grow with the agent count
            knn.run_knn(n_train=800, n_test=400 * n * wpn, d=20, k=5,
                        n_classes=4, train_fragments=4,
                        test_blocks=2 * n * wpn)   # warmup + data residency
            rt = api.current_runtime()
            warm_ids = {t.task_id for t in rt.graph.nodes()}
            t0 = time.perf_counter()
            knn.run_knn(n_train=800, n_test=400 * n * wpn, d=20, k=5,
                        n_classes=4, train_fragments=4,
                        test_blocks=2 * n * wpn, seed=1)
            measured[n] = time.perf_counter() - t0
            # replay ONLY the timed run's tasks (the second run's DAG is
            # self-contained), so sim_s covers the same work measured_s did
            sim_tasks = [t for t in replay_graph(rt.graph)
                         if t.tid not in warm_ids]
            simulated[n] = simulate(sim_tasks,
                                    _localhost_machine(n, wpn)).makespan
            if trace_path and n == max(agent_counts):
                with open(trace_path, "w") as f:
                    f.write(rt.tracer.to_chrome_trace())
                print(f"wrote Chrome trace ({n} agents) to {trace_path}")
        finally:
            api.runtime_stop(wait=False)
    base = min(agent_counts)
    for n in agent_counts:
        meas_eff = measured[base] / measured[n]   # weak scaling: t1/tn
        sim_eff = simulated[base] / simulated[n]
        print(f"{n:7d} {measured[n]:11.3f} {simulated[n]:8.3f} "
              f"{meas_eff:9.2f} {sim_eff:8.2f}")
        rows.append((f"scaling_multi/live/knn@{n}", measured[n],
                     f"meas_eff={meas_eff:.3f} sim_eff={sim_eff:.3f}"))
    print("\n(meas_eff = weak-scaling efficiency t1/tn against the real "
          "agents;\n sim_eff = the same DAG replayed through the calibrated "
          "DES on a\n matching machine model — agreement validates the "
          "simulator's\n transport/dispatch assumptions at small scale)")
    if json_path:
        ooc = run_live_out_of_core(wpn=wpn)
        dp = run_data_plane(wpn=wpn)
        coll = run_collectives(wpn=wpn)
        cp = run_control_plane(wpn=wpn)
        rec = run_recovery(wpn=wpn)
        wc = run_wire_checksum(wpn=wpn)
        top = max(agent_counts)
        base = min(agent_counts)
        payload = {"multi_node": {
            "live_weak_eff": {str(n): round(measured[base] / measured[n], 3)
                              for n in agent_counts},
            "sim_weak_eff": {str(n): round(simulated[base] / simulated[n], 3)
                             for n in agent_counts},
            "measured_s": {str(n): round(measured[n], 3) for n in agent_counts},
            "agents": top,
            "out_of_core": ooc,
            "data_plane": dp,
            "collectives": coll,
            "control_plane": cp,
            "recovery": rec,
            "wire_checksum": wc,
        }}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return rows


def run_collectives(wpn: int = 1) -> dict:
    """Collectives ledger (DESIGN.md §16) on a live 3-agent cluster:
    merge-tree shape of the linreg reduction (k-ary collective vs the
    old pairwise chain) and the broadcast byte split — the value must
    cross the scheduler's own link at most ONCE, every other agent
    receives it peer-to-peer.  Gated by bench_gate.py."""
    import numpy as np

    from repro.core import api, collectives
    from repro.core.collectives import reduce_spec, spec_depth

    leaves = WPN * 2   # the 128-fragment reduction the paper's linreg runs
    out = {
        "merge_tree": {
            "leaves": leaves,
            "arity": linreg.MERGE_ARITY,
            "depth": spec_depth(reduce_spec(leaves, linreg.MERGE_ARITY),
                                leaves),
            "tasks": len(reduce_spec(leaves, linreg.MERGE_ARITY)),
            "depth_binary": spec_depth(reduce_spec(leaves, 2), leaves),
            "tasks_binary": len(reduce_spec(leaves, 2)),
        },
    }
    n_agents = 3
    rt = api.runtime_start(backend="cluster", n_agents=n_agents,
                           workers_per_node=wpn, tracing=False)
    try:
        v = np.arange(65_536, dtype=np.float64)      # 512 KiB
        shipped0 = rt.executor.bytes_shipped
        detail0 = rt.store.transfer_detail()
        fut = collectives.broadcast(v)
        api.wait_on([api.task(_bsum, name="bsum")(fut)
                     for _ in range(n_agents * 3)])
        detail = rt.store.transfer_detail()
        out["broadcast"] = {
            "agents": n_agents,
            "nbytes": int(v.nbytes),
            "scheduler_link_bytes":
                int(rt.executor.bytes_shipped - shipped0),
            "p2p_bytes": int(detail["p2p_bytes"] - detail0["p2p_bytes"]),
            "broadcasts": rt.executor.broadcasts,
        }
    finally:
        api.runtime_stop(wait=False)
    mt, bc = out["merge_tree"], out["broadcast"]
    print(f"collectives [{n_agents} agents]: {mt['leaves']}-leaf merge tree "
          f"arity {mt['arity']}: {mt['tasks']} tasks / depth {mt['depth']} "
          f"(binary: {mt['tasks_binary']}/{mt['depth_binary']}); "
          f"broadcast {bc['nbytes']} B: {bc['scheduler_link_bytes']} B over "
          f"the scheduler link, {bc['p2p_bytes']} B agent→agent")
    return out


def run_data_plane(wpn: int = 1) -> dict:
    """Scheduler-link vs peer-to-peer bytes for the KNN tile pipeline on
    a 2-agent cluster (DESIGN.md §15), with a p2p-off control run
    (RJAX_P2P=0 + RJAX_INLINE_MAX=0 = the PR-4 star topology) so the
    relay reduction is measured, not assumed.  ``scheduler_relay_bytes``
    is gated by bench_gate.py against the committed baseline."""
    from repro.core import api

    kw = dict(n_train=800, n_test=1600, d=20, k=5, n_classes=4,
              train_fragments=4, test_blocks=4)

    def one(p2p: bool) -> dict:
        env = {"RJAX_P2P": "1" if p2p else "0"}
        if not p2p:
            env["RJAX_INLINE_MAX"] = "0"
        saved = {k: os.environ.get(k) for k in env}
        os.environ.update(env)
        try:
            rt = api.runtime_start(backend="cluster", n_agents=2,
                                   workers_per_node=wpn, tracing=False)
            try:
                knn.run_knn(**kw)
                s = rt.stats()
                return {"relay": int(s["scheduler_relay_bytes"]),
                        "p2p": int(s["p2p_bytes"]),
                        "remote_results": s["executor"]["remote_results"]}
            finally:
                api.runtime_stop(wait=False)
        finally:
            for k, v in saved.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v

    on = one(True)
    off = one(False)
    out = {
        "scheduler_relay_bytes": on["relay"],
        "p2p_bytes": on["p2p"],
        "remote_results": on["remote_results"],
        "relay_bytes_no_p2p": off["relay"],
        "relay_reduction_x": round(off["relay"] / max(1, on["relay"]), 1),
    }
    print(f"data plane [knn tiles, 2 agents]: relay {on['relay']} B + "
          f"p2p {on['p2p']} B (vs {off['relay']} B all-relay without p2p "
          f"= {out['relay_reduction_x']}x less scheduler-link traffic)")
    return out


def run_control_plane(wpn: int = 1) -> dict:
    """Dispatch-overhead flatness of the async control plane (DESIGN.md
    §18): per-task wall time of a no-op fan-out at 2 vs 8 agents, and
    the scheduler-side thread count sampled mid-run.  With the single
    event-loop scheduler both must stay (near-)flat in the agent count —
    the legacy plane grew a reader thread per agent plus a dispatcher
    thread per slot.  Gated by bench_gate.py."""
    import threading

    from repro.core import api

    n_tasks, repeats = 200, 3
    out = {}
    for n_agents in (2, 8):
        api.runtime_start(backend="cluster", n_agents=n_agents,
                          workers_per_node=wpn, tracing=False)
        try:
            t = api.task(_nop, name="nop")
            # warm: agents registered, function shipped, pools forked
            api.wait_on(api.map_tasks(
                t, [(i,) for i in range(n_agents * wpn * 2)]))
            best, threads = float("inf"), 0
            for _ in range(repeats):
                t0 = time.perf_counter()
                futs = api.map_tasks(t, [(i,) for i in range(n_tasks)])
                threads = max(threads, threading.active_count())
                api.wait_on(futs)
                best = min(best, time.perf_counter() - t0)
            out[str(n_agents)] = {
                "per_task_us": round(best / n_tasks * 1e6, 1),
                "sched_threads": threads,
            }
        finally:
            api.runtime_stop(wait=False)
    r2, r8 = out["2"], out["8"]
    out["overhead_ratio_8v2"] = round(
        r8["per_task_us"] / max(r2["per_task_us"], 1e-9), 3)
    print(f"control plane [async, wpn={wpn}]: no-op dispatch "
          f"{r2['per_task_us']} us/task @2 agents -> {r8['per_task_us']} "
          f"us/task @8 agents (ratio {out['overhead_ratio_8v2']}); "
          f"scheduler threads {r2['sched_threads']} -> {r8['sched_threads']}")
    return out


def _nop(i):
    return i


def _slow_frag(i):
    import time as _t

    import numpy as np
    _t.sleep(0.15)
    return np.sin(np.arange(20000, dtype=np.float64) * 1e-4 * (i + 1))


def _frag_sum(a):
    return float(a.sum())


def run_recovery(wpn: int = 1) -> dict:
    """Bounded recovery (DESIGN.md §20): SIGKILL one of 3 agents after a
    round of costly producers lands, then time how long re-serving every
    consumer takes — with k=1 replication (consumers are redirected to
    buddy replicas, zero replicated producers re-execute) vs without
    (full §15 lineage re-execution).  ``reexecuted`` with replication on
    is gated at 0 by bench_gate.py."""
    import signal

    from repro.core import api

    n = 9

    def one(replication: int) -> dict:
        rt = api.runtime_start(backend="cluster", n_agents=3,
                               workers_per_node=wpn, tracing=False,
                               replication=replication, heartbeat_s=0.2,
                               reconnect_grace_s=0, max_retries=4)
        try:
            ex = rt.executor
            prod = api.task(_slow_frag, name="slow_frag")
            cons = api.task(_frag_sum, name="frag_sum")
            frags = prod.map([(i,) for i in range(n)])
            api.wait_on([cons(f) for f in frags], timeout=120)
            if replication:
                # replication is asynchronous: wait until the
                # fire-and-forget buddy pulls are booked before killing
                deadline = time.monotonic() + 15
                while time.monotonic() < deadline:
                    homed = [k for a in range(3)
                             for k in rt.store.homed_keys(a)]
                    with ex._stats_lock:
                        placed = bool(homed) and all(
                            ex._replicas.get(k) for k in homed)
                    if placed:
                        break
                    time.sleep(0.05)
            before = rt.graph.counters().get("retries", 0)
            os.kill(ex.cluster._procs[1].pid, signal.SIGKILL)
            t0 = time.perf_counter()
            # the respawn (which redirects store placeholders at
            # surviving replicas) must land before consumers re-resolve
            deadline = time.monotonic() + 30
            while ex.agent_restarts == 0 and time.monotonic() < deadline:
                time.sleep(0.02)
            api.wait_on([cons(f) for f in frags], timeout=120)
            return {
                "recover_s": round(time.perf_counter() - t0, 3),
                "reexecuted": int(rt.graph.counters().get("retries", 0)
                                  - before),
                "replica_hits": int(ex.replica_hits),
                "replica_bytes": int(ex.replica_bytes),
            }
        finally:
            api.runtime_stop(wait=False)

    on = one(1)
    off = one(0)
    out = {"replication_on": on, "replication_off": off}
    print(f"recovery [3 agents, SIGKILL mid-run]: replication on -> "
          f"{on['recover_s']}s to re-serve, {on['reexecuted']} re-executed "
          f"({on['replica_hits']} replica hits, {on['replica_bytes']} B "
          f"replicated); off -> {off['recover_s']}s, "
          f"{off['reexecuted']} re-executed from lineage")
    return out


def run_wire_checksum(wpn: int = 1) -> dict:
    """CRC32 frame-trailer overhead (DESIGN.md §20): the same KNN tile
    pipeline with and without ``RJAX_WIRE_CHECKSUM``, same box, same run
    — bench_gate.py bounds the on/off wall-clock ratio."""
    from repro.cluster import protocol
    from repro.core import api

    kw = dict(n_train=800, n_test=1600, d=20, k=5, n_classes=4,
              train_fragments=4, test_blocks=4)

    def one(on: bool) -> float:
        saved = os.environ.get("RJAX_WIRE_CHECKSUM")
        os.environ["RJAX_WIRE_CHECKSUM"] = "1" if on else "0"
        protocol.refresh_checksum()
        try:
            api.runtime_start(backend="cluster", n_agents=2,
                              workers_per_node=wpn, tracing=False)
            try:
                knn.run_knn(**kw)          # warm: agents up, fn shipped
                best = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    knn.run_knn(**kw, seed=1)
                    best = min(best, time.perf_counter() - t0)
                return best
            finally:
                api.runtime_stop(wait=False)
        finally:
            if saved is None:
                os.environ.pop("RJAX_WIRE_CHECKSUM", None)
            else:
                os.environ["RJAX_WIRE_CHECKSUM"] = saved
            protocol.refresh_checksum()

    off = one(False)
    on = one(True)
    out = {"off_s": round(off, 3), "on_s": round(on, 3),
           "overhead_ratio": round(on / max(off, 1e-9), 3)}
    print(f"wire checksum [knn tiles, 2 agents]: off {out['off_s']}s -> "
          f"on {out['on_s']}s (ratio {out['overhead_ratio']})")
    return out


def run_live_out_of_core(wpn: int = 1, budget: str = "400K") -> dict:
    """Bounded-plane run on the real cluster: K-means whose fragment set
    exceeds the per-node budget must finish, spill on both the scheduler
    store and the node agents, and match the unbounded run bitwise."""
    from repro.algorithms import kmeans
    from repro.core import api

    def one(mem):
        rt = api.runtime_start(backend="cluster", n_agents=2,
                               workers_per_node=wpn, policy="locality",
                               memory_budget=mem, tracing=False)
        try:
            res = kmeans.run_kmeans(n_points=16000, d=10, k=4, fragments=8,
                                    max_iters=4, seed=0)
            return res, rt.stats(), rt.executor.agent_stats()
        finally:
            api.runtime_stop(wait=False)

    import numpy as np
    ref, _, _ = one(None)
    res, stats, agents = one(budget)
    mem = stats["memory"]
    out = {
        "budget": budget,
        "spills": mem["spills"],
        "faults": mem["faults"],
        "node_spills": sum((s or {}).get("plane_spills", 0) for s in agents),
        "node_faults": sum((s or {}).get("plane_faults", 0) for s in agents),
        "match": bool(np.array_equal(ref.centroids, res.centroids)
                      and ref.sse == res.sse),
    }
    print(f"out-of-core k-means [cluster, budget {budget}]: "
          f"store {out['spills']}/{out['faults']}, "
          f"nodes {out['node_spills']}/{out['node_faults']}, "
          f"bitwise match: {out['match']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="run the real LocalCluster path and compare with "
                         "the simulator")
    ap.add_argument("--agents", default="1,2",
                    help="comma-separated agent counts for --live")
    ap.add_argument("--wpn", type=int, default=2,
                    help="worker processes per agent for --live")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized --live run: 1 worker/agent, "
                         "plus the out-of-core ledger")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write --live measurements as JSON (merged into "
                         "BENCH_pr.json by bench_gate.py)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write the largest --live run's task timeline as "
                         "Chrome-trace JSON (open in Perfetto)")
    opts = ap.parse_args()
    if opts.live:
        wpn = 1 if opts.quick else opts.wpn
        run_live(tuple(int(x) for x in opts.agents.split(",")), wpn=wpn,
                 json_path=opts.json, trace_path=opts.trace)
    else:
        run()
