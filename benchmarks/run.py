"""Benchmark driver — one module per paper table/figure (+ the roofline).

Prints a ``name,us_per_call,derived`` CSV summary at the end (harness
contract); each module also prints its human-readable table.

  serialization_bench   — paper Table 1
  scaling_single_node   — paper Figs. 6 (weak) & 7 (strong)
  scaling_multi_node    — paper Figs. 8 (weak) & 9 (strong)
  trace_analysis        — paper Fig. 10
  roofline              — §Roofline from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: serialization,scaling1,scalingN,trace,roofline")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from . import (roofline, scaling_multi_node, scaling_single_node,
                   serialization_bench, trace_analysis)
    benches = [
        ("serialization", serialization_bench.run),
        ("scaling1", scaling_single_node.run),
        ("scalingN", scaling_multi_node.run),
        ("trace", trace_analysis.run),
        ("roofline", roofline.run),
    ]
    rows = []
    failed = False
    for name, fn in benches:
        if want and name not in want:
            continue
        print(f"\n{'=' * 72}\n[{name}]\n{'=' * 72}")
        try:
            rows.extend(fn() or [])
        except Exception:
            failed = True
            traceback.print_exc()
    print("\n# CSV summary")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
