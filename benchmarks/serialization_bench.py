"""Paper Table 1: serialization/deserialization times per codec × block size.

The paper benchmarked nine R serializers on square double blocks and chose
RMVL (low-overhead binary, memory-mappable).  Same methodology for the
Python/JAX codecs; the measured winner (``raw``, with the ``mmap`` variant
winning deserialization outright via zero-copy reconstruction) is the
runtime's default — reproducing the paper's conclusion in this ecosystem.
"""
from __future__ import annotations

from repro.core.serialization import benchmark_codecs


def run(sizes=(1024, 2048, 4096)) -> list[tuple[str, float, str]]:
    res = benchmark_codecs(sizes=sizes, repeats=3)
    rows = []
    header = "codec      " + "".join(f"{s}S(ms)  {s}D(ms)  " for s in sizes)
    print("# Table 1 analogue — serialize (S) / deserialize (D), square f64 blocks")
    print(header)
    for codec, per in sorted(res.items()):
        line = f"{codec:10s} "
        for s in sizes:
            t_s, t_d = per[s]
            line += f"{t_s*1e3:8.2f} {t_d*1e3:8.2f} "
        print(line)
        biggest = sizes[-1]
        t_s, t_d = per[biggest]
        rows.append((f"serialization/{codec}_{biggest}",
                     (t_s + t_d) * 1e6,
                     f"S={t_s*1e3:.2f}ms D={t_d*1e3:.2f}ms"))
    # the paper's conclusion: the low-overhead binary codec wins
    raw_total = sum(res["raw"][sizes[-1]])
    pkl_total = sum(res["pickle"][sizes[-1]])
    print(f"-> raw/pickle total-time ratio @ {sizes[-1]}: "
          f"{raw_total / pkl_total:.2f} (<1 reproduces the paper's "
          f"low-overhead-binary-wins conclusion)")
    return rows


if __name__ == "__main__":
    run()
