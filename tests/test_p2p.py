"""Peer-to-peer cluster data plane (DESIGN.md §15).

Covers the §15 invariants end-to-end against real TCP agents: results
stay node-resident (the scheduler sees descriptors, not bytes), small
results ride the reply inline (``RJAX_INLINE_MAX``), consumers on other
nodes pull straight from the producer's data plane, gathers materialize
on demand, the transfer ledger attributes movement to its true source,
and a producer crashing before its result was fetched re-executes from
graph lineage.  The 3-agent smoke at the bottom is the CI `cluster-smoke`
entry: producer on node A, consumers on B/C, gather at the end.
"""
import os
import signal
import time

import numpy as np

from repro.core import api
from repro.core.futures import RemoteValue

BIG = 4096       # float64 elements = 32 KiB, well above RJAX_INLINE_MAX
SMALL = 64       # 512 B, well below it


def _cluster(n_agents=2, wpn=1, **kw):
    return api.runtime_start(backend="cluster", n_agents=n_agents,
                             workers_per_node=wpn, **kw)


def gen_big(n):
    return np.arange(n, dtype=np.float64)


def gen_small(n):
    return np.ones(n, dtype=np.float64)


def consume(a):
    return float(a.sum())


def test_results_stay_node_resident_and_gather_materializes():
    rt = _cluster()
    try:
        part = api.task(gen_big, name="gen")(BIG)
        api.barrier()
        rv = rt.store.get_nowait(part.key, materialize=False)
        assert isinstance(rv, RemoteValue)
        assert rv.nbytes == BIG * 8
        assert rv.addr is not None and rv.node in (0, 1)
        # residency metadata points at the producing node, not the
        # scheduler — this is what locality now scores
        assert rv.node in rt.store.locations(part.key)
        # nothing crossed the scheduler link for this result
        assert rt.executor.relay_result_bytes == 0
        assert rt.executor.deferred_result_bytes == BIG * 8
        # gather materializes on demand, straight from the node plane
        arr = api.wait_on(part)
        np.testing.assert_array_equal(arr, gen_big(BIG))
        detail = rt.store.transfer_detail()
        assert detail["gather_bytes"] == BIG * 8
        # after materialization the store holds the real value
        assert isinstance(rt.store.get_nowait(part.key, materialize=False),
                          np.ndarray)
    finally:
        api.runtime_stop(wait=False)


def test_small_results_ride_the_reply_inline():
    rt = _cluster()
    try:
        part = api.task(gen_small, name="gen_small")(SMALL)
        api.barrier()
        # below RJAX_INLINE_MAX: the reply carried the bytes, no
        # descriptor, no token round-trip
        v = rt.store.get_nowait(part.key, materialize=False)
        assert isinstance(v, np.ndarray)
        assert rt.executor.remote_results == 0
        np.testing.assert_array_equal(api.wait_on(part), gen_small(SMALL))
    finally:
        api.runtime_stop(wait=False)


def test_inline_max_zero_defers_everything(monkeypatch):
    monkeypatch.setenv("RJAX_INLINE_MAX", "0")
    rt = _cluster()
    try:
        part = api.task(gen_small, name="gen_small")(SMALL)
        api.barrier()
        assert isinstance(rt.store.get_nowait(part.key, materialize=False),
                          RemoteValue)
        np.testing.assert_array_equal(api.wait_on(part), gen_small(SMALL))
    finally:
        api.runtime_stop(wait=False)


def test_p2p_kill_switch_restores_relay(monkeypatch):
    monkeypatch.setenv("RJAX_P2P", "0")
    rt = _cluster()
    try:
        part = api.task(gen_big, name="gen")(BIG)
        api.barrier()
        assert isinstance(rt.store.get_nowait(part.key, materialize=False),
                          np.ndarray)
        assert rt.executor.relay_result_bytes == BIG * 8
        assert rt.executor.remote_results == 0
    finally:
        api.runtime_stop(wait=False)


def test_cross_node_consumers_pull_peer_to_peer():
    rt = _cluster(n_agents=2, wpn=1)
    try:
        part = api.task(gen_big, name="gen")(BIG)
        api.barrier()
        outs = [api.task(consume, name="consume")(part) for _ in range(8)]
        assert api.wait_on(outs) == [float(np.arange(BIG).sum())] * 8
        stats = rt.stats()
        # with one worker per agent and eight ready consumers, both nodes
        # ran some — the non-producing node pulled the datum exactly once
        assert stats["p2p_bytes"] == BIG * 8
        detail = stats["data_plane"]
        rv_home = [n for n, b in detail["p2p_by_source"].items() if b]
        assert len(rv_home) == 1    # attributed to the actual source node
        assert rt.executor.fetches == 1
        assert rt.executor.fetch_bytes == BIG * 8
        # the result bytes never crossed the scheduler's link
        assert rt.executor.relay_result_bytes == 0
        agent_stats = [s for s in rt.executor.agent_stats() if s]
        assert sum(s["p2p_fetches"] for s in agent_stats) == 1
        assert sum(s["p2p_serves"] for s in agent_stats) >= 1
    finally:
        api.runtime_stop(wait=False)


def test_tuple_datum_is_cached_at_datum_level():
    """A tuple-valued datum (the KNN fragment shape) is shipped to a node
    at most once — datum-level Put/Ref, new in §15."""
    rt = _cluster(n_agents=2, wpn=1)
    try:
        def gen_pair(n):
            return np.arange(n, dtype=np.float64), np.ones(n)

        def use_pair(p):
            x, y = p
            return float(x.sum() + y.sum())

        pair = api.task(gen_pair, name="gen_pair")(BIG)
        api.barrier()
        assert isinstance(rt.store.get_nowait(pair.key, materialize=False),
                          RemoteValue)
        outs = [api.task(use_pair, name="use_pair")(pair) for _ in range(8)]
        expect = float(np.arange(BIG).sum() + BIG)
        assert api.wait_on(outs) == [expect] * 8
        # one peer pull for the non-producing node, refs ever after
        assert rt.executor.fetches <= 1
        assert rt.executor.puts == 0
        assert rt.executor.refs >= 6
    finally:
        api.runtime_stop(wait=False)


def test_knn_pipeline_bitwise_equal_to_thread_backend():
    from repro.algorithms import knn

    kw = dict(n_train=600, n_test=400, d=16, k=3, n_classes=3,
              train_fragments=4, test_blocks=4)
    api.runtime_start(backend="thread", n_workers=4)
    try:
        expect = knn.run_knn(**kw).predictions
    finally:
        api.runtime_stop(wait=False)
    rt = _cluster(n_agents=2, wpn=1)
    try:
        got = knn.run_knn(**kw).predictions
        stats = rt.stats()
    finally:
        api.runtime_stop(wait=False)
    np.testing.assert_array_equal(got, expect)
    # intermediates stayed out of the scheduler's link
    assert stats["executor"]["remote_results"] > 0


def test_producer_crash_before_fetch_reexecutes_from_lineage(tmp_path):
    """SIGKILL the producing agent while a consumer on another node holds
    an unfetched RemoteValue: the producer re-executes from graph
    lineage (one retry), the consumer completes with bytes bitwise-equal
    to the thread backend, and the dead node's ledgers are reset."""
    api.runtime_start(backend="thread", n_workers=2)
    try:
        expect = api.wait_on(api.task(gen_big, name="gen")(BIG)).copy()
    finally:
        api.runtime_stop(wait=False)

    rt = _cluster(n_agents=2, wpn=1)
    try:
        part = api.task(gen_big, name="gen")(BIG)
        api.barrier()
        rv = rt.store.get_nowait(part.key, materialize=False)
        assert isinstance(rv, RemoteValue)
        home = rv.node
        # the consumer exists (holds the future) but has not fetched yet
        proc = rt.cluster._procs[home]
        os.kill(proc.pid, signal.SIGKILL)
        cons = api.task(consume, name="consume", max_retries=4)(part)
        got = api.wait_on(cons, timeout=90)
        assert got == float(expect.sum())
        # the producer ran again (lineage re-execution counts as a retry)
        assert rt.stats()["retries"] >= 1
        assert rt.executor.agent_restarts >= 1
        # gather of the recomputed datum is bitwise-equal to thread
        np.testing.assert_array_equal(api.wait_on(part, timeout=90), expect)
        # residency/byte ledgers were reset and rebuilt: every location
        # recorded for the datum is a live node holding real bytes
        locs = rt.store.locations(part.key)
        assert locs, "recomputed datum has no recorded residency"
        for n in range(rt.executor.n_agents):
            assert rt.store.node_bytes(n) >= 0
    finally:
        api.runtime_stop(wait=False)


def test_out_of_core_under_p2p(tmp_path):
    """§13 still governs the p2p plane: with a 400 K per-node budget the
    K-means working set spills/faults on the NODE planes (the scheduler
    store holds descriptors, not bytes) and matches the unbounded run."""
    from repro.algorithms import kmeans

    kw = dict(n_points=16000, d=10, k=4, fragments=8, max_iters=4, seed=0)
    _cluster(n_agents=2, wpn=1, policy="locality", tracing=False)
    try:
        ref = kmeans.run_kmeans(**kw)
    finally:
        api.runtime_stop(wait=False)
    rt = _cluster(n_agents=2, wpn=1, policy="locality",
                  memory_budget="400K", spill_dir=str(tmp_path),
                  tracing=False)
    try:
        res = kmeans.run_kmeans(**kw)
        agents = [s for s in rt.executor.agent_stats() if s]
    finally:
        api.runtime_stop(wait=False)
    node_spills = sum(s.get("plane_spills", 0) for s in agents)
    node_faults = sum(s.get("plane_faults", 0) for s in agents)
    assert node_spills > 0 and node_faults > 0
    assert np.array_equal(ref.centroids, res.centroids)
    assert ref.sse == res.sse


def test_runtime_stats_exposes_data_plane_split():
    api.runtime_start(backend="thread", n_workers=2)
    try:
        api.wait_on(api.task(gen_small, name="gen_small")(SMALL))
        s = api.runtime_stats()
        assert "scheduler_relay_bytes" in s and "p2p_bytes" in s
        assert s["p2p_bytes"] == 0
        assert set(s["data_plane"]) >= {"scheduler_relay_bytes", "p2p_bytes",
                                        "p2p_by_source", "gather_bytes"}
    finally:
        api.runtime_stop(wait=False)


def test_producer_crash_recovers_under_default_retries():
    """With the default max_retries=0 a consumer whose INPUT vanished
    with a dead node must still recover: pre-§15 a crash after the
    producer completed could never hurt consumers (the bytes were on the
    scheduler), so lost-input failures get their own bounded retry
    allowance instead of consuming the user-facing budget."""
    rt = _cluster(n_agents=2, wpn=1)   # max_retries defaults to 0
    try:
        part = api.task(gen_big, name="gen")(BIG)
        api.barrier()
        rv = rt.store.get_nowait(part.key, materialize=False)
        assert isinstance(rv, RemoteValue)
        restarts0 = rt.executor.agent_restarts
        os.kill(rt.cluster._procs[rv.node].pid, signal.SIGKILL)
        # let the on_close recovery replace the agent first: a submit
        # racing the undetected-dead channel fails as a plain (non-
        # lost-input) WorkerCrashedError, which max_retries=0 does not
        # cover — that is the pre-§15 convention, not what this test is
        # about
        deadline = time.time() + 30
        while time.time() < deadline \
                and rt.executor.agent_restarts == restarts0:
            time.sleep(0.05)
        cons = api.task(consume, name="consume")(part)   # no max_retries
        assert api.wait_on(cons, timeout=90) == float(np.arange(BIG).sum())
    finally:
        api.runtime_stop(wait=False)


def test_resurrect_rearms_edges_to_pending_children():
    """Graph-level lineage invariant: resurrecting a DONE parent must
    re-arm its released edges to still-PENDING children, or its second
    completion double-decrements and releases them while other parents
    are still running."""
    from repro.core.dag import TaskGraph, TaskNode, TaskState

    g = TaskGraph()

    def node(name, deps=(), out=()):
        return TaskNode(task_id=g.next_task_id(), name=name, fn=None,
                        args=(), kwargs={}, dep_keys=set(deps),
                        out_keys=list(out))

    a = node("A", out=[(1, 1)])
    b = node("B", out=[(2, 1)])
    g.add_task(a)
    g.add_task(b)
    g.claim_running(a.task_id, 0, 0)
    g.claim_running(b.task_id, 1, 1)
    c = node("C", deps=[(1, 1), (2, 1)], out=[(3, 1)])
    g.add_task(c)
    assert c.unresolved == 2
    g.mark_done(a.task_id)              # C: 2 -> 1
    assert c.unresolved == 1
    assert g.resurrect(a.task_id)       # A's output was lost: re-run it
    assert c.unresolved == 2            # edge re-armed
    g.claim_running(a.task_id, 0, 0)
    assert g.mark_done(a.task_id) == []  # B still running: C stays PENDING
    assert c.state == TaskState.PENDING
    assert g.mark_done(b.task_id) == [c.task_id]
    assert c.state == TaskState.READY


# ------------------------------------------------------- CI 3-agent smoke
def test_three_agent_p2p_smoke():
    """Producer on node A, consumers spread over B and C, gather at the
    end — the smallest topology where peer pulls, residency refs and the
    scheduler's metadata-only role all show up at once."""
    rt = _cluster(n_agents=3, wpn=1)
    try:
        part = api.task(gen_big, name="gen")(BIG)
        api.barrier()
        outs = [api.task(consume, name="consume")(part) for _ in range(9)]
        assert api.wait_on(outs, timeout=90) == \
            [float(np.arange(BIG).sum())] * 9
        stats = rt.stats()
        # at least one of the two non-producing nodes pulled peer-to-peer
        assert stats["p2p_bytes"] >= BIG * 8
        assert stats["executor"]["relay_result_bytes"] == 0
        np.testing.assert_array_equal(api.wait_on(part), gen_big(BIG))
    finally:
        api.runtime_stop(wait=False)
