"""Bounded recovery (DESIGN.md §20): session resumption + replicas.

The two layers under test, separately and against each other:

* **Session resumption** — a transient scheduler↔agent disconnect parks
  the node for the grace window; the agent re-dials with its session
  token, the residency manifest reconciles against the scheduler's
  generation ledger, and the job finishes with ZERO task re-executions
  (the pre-§20 runtime would have respawned the agent and replayed
  lineage).  A liveness kill (SIGSTOP) must still take the respawn path:
  the process is wedged, not partitioned.
* **Replicated intermediates** — with ``RJAX_REPLICATION=k`` armed,
  expensive node-resident results get buddy copies over the p2p plane;
  on real node death the store redirects placeholders at survivors and
  only unreplicated keys pay lineage re-execution.

The default path (both knobs off) must behave exactly as before.
"""
import os
import signal
import socket
import time

import numpy as np
import pytest

from repro.core import api
from repro.cluster.agent import NodePlane


# ----------------------------------------------------------- task bodies
def produce(i: int):
    import numpy as np
    return np.sin(np.arange(4000, dtype=np.float64) * 0.001 * (i + 1))


def consume(a):
    import numpy as np
    return float(np.sqrt(np.abs(a)).sum())


def slow_produce(i: int):
    import time

    import numpy as np
    time.sleep(0.25)
    return np.cos(np.arange(20000, dtype=np.float64) * 0.0005 * (i + 1))


def tiny(i: int) -> int:
    return i * 2


def reference(n: int):
    return [consume(produce(i)) for i in range(n)]


def sever(rt, a: int):
    """Break agent ``a``'s control connection without touching the
    process: both read loops observe EOF — exactly what a transient
    network partition's reset looks like."""
    ch = rt.executor._channels[a]
    assert ch is not None and not ch.closed
    ch.sock.shutdown(socket.SHUT_RDWR)
    return ch


# ------------------------------------------------ node-plane generations
def test_node_plane_generations_and_manifest():
    """Every residency mark bumps the key's generation exactly once, and
    the manifest reports (key, generation, nbytes) for resident data
    only — the agent half of the §20 reconciliation contract."""
    plane = NodePlane()
    k1, k2 = (1, 0), (2, 0)
    assert plane.note_mark(k1) == 1
    assert plane.note_mark(k1) == 2
    assert plane.note_mark(k2) == 1
    a = np.arange(16, dtype=np.float64)
    plane.store(k1, a)
    m = {tuple(key): (gen, nb) for key, gen, nb in plane.manifest()}
    # k2 was marked but its bytes never landed: not in the manifest
    assert set(m) == {k1}
    assert m[k1] == (2, a.nbytes)
    # a pending peer fetch is not manifest-resident either
    assert plane.begin_fetch(k2)
    assert {tuple(key) for key, _, _ in plane.manifest()} == {k1}


def test_default_path_resumption_disabled():
    """``reconnect_grace_s=0``: the executor never arms resumption and
    the recovery counters stay at their PR-9 zeros."""
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=1,
                           reconnect_grace_s=0) as rt:
        assert not rt.executor.resumption
        t = api.task(tiny, name="tiny")
        assert api.wait_on(t.map([(i,) for i in range(8)]),
                           timeout=60) == [i * 2 for i in range(8)]
        s = rt.executor.stats()
        assert s["reconnects"] == 0
        assert s["replica_bytes"] == 0 and s["replica_hits"] == 0


# -------------------------------------------------- resumption acceptance
@pytest.mark.chaos
def test_reconnect_mid_pipeline_zero_reexecution_bitwise():
    """Sever agent 1's control socket mid-pipeline: the session resumes
    inside the grace window, no respawn happens, no task re-executes,
    and every result is bitwise-identical to the reference."""
    n = 24
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2,
                           reconnect_grace_s=5.0, max_retries=2) as rt:
        prod = api.task(produce, name="produce")
        cons = api.task(consume, name="consume")
        futs = [cons(prod(i)) for i in range(n)]
        time.sleep(0.4)   # let dispatch spread over both agents
        sever(rt, 1)
        results = api.wait_on(futs, timeout=120)
        ex = rt.executor
        assert ex.reconnects >= 1
        assert ex.agent_restarts == 0
        assert rt.graph.counters().get("retries", 0) == 0
        # the residency ledger survived: a fresh round on the same
        # runtime still resolves (and the resumed agent still serves)
        chk = api.wait_on(cons(prod(0)), timeout=60)
        assert chk == reference(1)[0]
    assert results == reference(n)


def produce_small(i: int):
    """Below the inline threshold: the result rides the reply inline and
    lives scheduler-side, so consuming it ships a keyed ``Put``."""
    import numpy as np
    return np.arange(500, dtype=np.float64) * (i + 1)


@pytest.mark.chaos
def test_resume_reconciles_manifest_strikes_stale_keys():
    """The reconciliation rule, end-to-end: a Put key whose
    scheduler-side generation was perturbed (standing in for a mark that
    died on the partitioned wire) is struck from the residency set on
    resume — it re-ships on next use, costing zero re-executions — while
    every agreeing key survives."""
    with api.runtime_start(backend="cluster", n_agents=1, workers_per_node=1,
                           reconnect_grace_s=5.0) as rt:
        ps = api.task(produce_small, name="ps")
        cons = api.task(consume, name="consume")
        srcs = ps.map([(i,) for i in range(3)])
        out = api.wait_on([cons(s) for s in srcs], timeout=60)
        assert out == [consume(produce_small(i)) for i in range(3)]
        ex = rt.executor
        by_key = {s.key: i for i, s in enumerate(srcs)}
        with ex._order_locks[0]:
            resident = set(ex._resident[0])
            put_resident = sorted(resident & set(by_key))
            assert len(put_resident) == 3, "Put inputs should be resident"
            victim = put_resident[0]
            ex._res_gen[0][victim] += 1   # the agent never saw this mark
        sever(rt, 0)
        deadline = time.monotonic() + 10
        while ex.reconnects == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert ex.reconnects == 1
        with ex._order_locks[0]:
            after = set(ex._resident[0])
        assert victim not in after            # exactly the stale key struck
        assert resident - {victim} <= after   # agreeing keys survived
        assert rt.graph.counters().get("retries", 0) == 0
        # struck ⇒ re-shipped on next use, still bitwise-correct
        i = by_key[victim]
        assert api.wait_on(cons(srcs[i]), timeout=60) \
            == consume(produce_small(i))
        assert rt.graph.counters().get("retries", 0) == 0


@pytest.mark.chaos
def test_sigstop_takes_respawn_path_not_resume():
    """A wedged process (SIGSTOP) is DEAD to the failure detector: the
    liveness kill must bypass the park-and-resume path and respawn —
    while a plain socket sever on the same config resumes.  The two
    recovery paths stay distinct."""
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2,
                           heartbeat_s=0.2, suspicion_s=0.6,
                           reconnect_grace_s=5.0, max_retries=4) as rt:
        t = api.task(consume, name="consume")
        futs = [t(produce(i)) for i in range(16)]
        time.sleep(0.4)
        victim = rt.executor.cluster._procs[1]
        os.kill(victim.pid, signal.SIGSTOP)
        results = api.wait_on(futs, timeout=120)
        ex = rt.executor
        assert ex.liveness_kills >= 1
        # the respawn runs on the recovery pool and only counts once the
        # replacement's handshake lands — poll for it
        deadline = time.monotonic() + 30.0
        while ex.agent_restarts < 1 and time.monotonic() < deadline:
            time.sleep(0.1)
        assert ex.agent_restarts >= 1
        assert ex.reconnects == 0
    assert results == [consume(produce(i)) for i in range(16)]

    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2,
                           heartbeat_s=0.2, reconnect_grace_s=5.0,
                           max_retries=2) as rt:
        futs = [api.task(consume, name="consume")(produce(i))
                for i in range(16)]
        time.sleep(0.3)
        sever(rt, 1)
        results = api.wait_on(futs, timeout=120)
        ex = rt.executor
        assert ex.reconnects >= 1
        assert ex.agent_restarts == 0
    assert results == [consume(produce(i)) for i in range(16)]


# ------------------------------------------------- replication acceptance
@pytest.mark.chaos
def test_replica_hit_recovery_zero_reexecution():
    """Replication on: SIGKILL the agent homing replicated results —
    consumers are served from buddy replicas, the replicated producers
    never re-execute, and results stay bitwise-identical."""
    n = 6
    with api.runtime_start(backend="cluster", n_agents=3, workers_per_node=1,
                           replication=1, reconnect_grace_s=0,
                           heartbeat_s=0.2, max_retries=4) as rt:
        # fill the duration profile with near-zero costs so the slow
        # producers decisively clear the fleet-mean threshold
        api.wait_on(api.task(tiny, name="tiny").map(
            [(i,) for i in range(12)]), timeout=60)
        prod = api.task(slow_produce, name="slow_produce", returns=1)
        frags = prod.map([(i,) for i in range(n)])
        api.wait_on([api.task(consume, name="consume")(f) for f in frags],
                    timeout=120)
        ex = rt.executor
        # replication is fire-and-forget: wait for every homed result to
        # have at least one booked replica
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline:
            homed = [k for a in range(3) for k in rt.store.homed_keys(a)]
            with ex._stats_lock:
                covered = bool(homed) and all(ex._replicas.get(k)
                                              for k in homed)
            if covered:
                break
            time.sleep(0.1)
        assert covered, "replicas were never fully placed"
        assert ex.replica_bytes > 0
        retries_before = rt.graph.counters().get("retries", 0)
        victim = rt.executor.cluster._procs[1]
        os.kill(victim.pid, signal.SIGKILL)
        # wait for the respawn (which redirects placeholders at the
        # surviving replicas) before consuming again: node-1 frags must
        # be served from their replicas, not re-executed from lineage
        deadline = time.monotonic() + 30
        while ex.agent_restarts == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert ex.agent_restarts >= 1
        out = api.wait_on([api.task(consume, name="consume")(f)
                           for f in frags], timeout=120)
        assert ex.replica_hits > 0
        assert rt.graph.counters().get("retries", 0) == retries_before
        assert ex.agent_restarts >= 1
    assert out == [consume(slow_produce(i)) for i in range(n)]


@pytest.mark.chaos
def test_unreplicated_keys_still_resurrect_via_lineage():
    """Replication off: the same kill pays lineage re-execution — the
    §15 path is intact underneath the new layer, and correctness never
    depended on replicas being there."""
    n = 6
    with api.runtime_start(backend="cluster", n_agents=3, workers_per_node=1,
                           replication=0, reconnect_grace_s=0,
                           heartbeat_s=0.2, max_retries=4) as rt:
        prod = api.task(slow_produce, name="slow_produce")
        frags = prod.map([(i,) for i in range(n)])
        api.wait_on([api.task(consume, name="consume")(f) for f in frags],
                    timeout=120)
        ex = rt.executor
        assert ex.replica_bytes == 0
        victim = rt.executor.cluster._procs[1]
        os.kill(victim.pid, signal.SIGKILL)
        out = api.wait_on([api.task(consume, name="consume")(f)
                           for f in frags], timeout=120)
        assert ex.replica_hits == 0
        assert rt.graph.counters().get("retries", 0) > 0
    assert out == [consume(slow_produce(i)) for i in range(n)]


# ----------------------------------------------------- telemetry surface
def test_recovery_counters_in_executor_stats_schema():
    """The three recovery counters ride ``EXECUTOR_STAT_KEYS``: cluster
    reports them live, thread/process read 0 through normalization —
    the three-backend parity contract."""
    from repro.core.telemetry import EXECUTOR_STAT_KEYS, \
        normalize_executor_stats
    for key in ("reconnects", "replica_bytes", "replica_hits"):
        assert key in EXECUTOR_STAT_KEYS
    norm = normalize_executor_stats({"backend": "thread"})
    assert norm["reconnects"] == 0
    assert norm["replica_bytes"] == 0 and norm["replica_hits"] == 0


@pytest.mark.chaos
def test_disconnected_state_surfaces_in_api_status():
    """While parked, ``/api/status`` shows the node as ``disconnected``
    (or already ``reconnecting``), and rows carry a replica count."""
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=1,
                           heartbeat_s=0.2, reconnect_grace_s=8.0,
                           telemetry=True) as rt:
        t = api.task(tiny, name="tiny")
        assert api.wait_on(t(3), timeout=60) == 6
        ex = rt.executor
        # pause the agent so the sever stays open long enough to observe
        victim = rt.executor.cluster._procs[1]
        os.kill(victim.pid, signal.SIGSTOP)
        try:
            sever(rt, 1)
            seen = None
            deadline = time.monotonic() + 6
            while time.monotonic() < deadline:
                view = ex.liveness().get(1, {})
                if view.get("state") in ("disconnected", "reconnecting"):
                    seen = view["state"]
                    break
                time.sleep(0.05)
            assert seen in ("disconnected", "reconnecting")
            snap = rt.telemetry.snapshot_status(rt)
            node1 = snap["nodes"].get("1", {})
            assert node1.get("state") in ("disconnected", "reconnecting")
            assert "replicas" in node1
        finally:
            os.kill(victim.pid, signal.SIGCONT)
        # resumed (or respawned after grace): either way the runtime
        # still serves
        assert api.wait_on(t(4), timeout=60) == 8
