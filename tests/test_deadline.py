"""Task deadlines (DESIGN.md §19): a body that overruns ``deadline_s``
has its worker killed and the attempt fails retryable
(``DeadlineExceededError``) — enforced by the process backend's
head-of-queue monitor and, on the cluster backend, by the agent-side
watchdog.  The hang-once pattern (marker file) proves the retry then
completes normally."""
import os

import pytest

from repro.core import api
from repro.core.futures import TaskFailedError


def hang_once(marker: str, result: int):
    """Sleeps 'forever' on the first attempt, instant on the retry."""
    import os
    import time
    if not os.path.exists(marker):
        with open(marker, "w"):
            pass
        time.sleep(60)
    return result


def hang_always():
    import time
    time.sleep(60)


def test_process_deadline_kills_and_retry_completes(tmp_path):
    """Runtime-default deadline (``runtime_start(deadline_s=)``): the
    wedged first attempt is killed by the pool's deadline monitor, the
    retry completes, and the kill is ledgered."""
    marker = str(tmp_path / "hung")
    with api.runtime_start(n_workers=2, backend="process",
                           deadline_s=1.0, max_retries=1) as rt:
        t = api.task(hang_once, name="hang_once")
        assert api.wait_on(t(marker, 42), timeout=60) == 42
        assert rt.executor.stats()["deadline_kills"] >= 1
    assert os.path.exists(marker)


def test_process_deadline_exhausted_surfaces_deadline_error():
    with api.runtime_start(n_workers=2, backend="process") as rt:
        f = rt.submit(hang_always, (), {}, name="hang_always",
                      deadline_s=0.5, max_retries=0)
        with pytest.raises(TaskFailedError) as exc:
            api.wait_on(f, timeout=60)
        assert "deadline" in str(exc.value).lower()


def test_per_call_deadline_overrides_runtime_default(tmp_path):
    """submit(deadline_s=) wins over the runtime default: here the
    runtime default is generous and the per-call one is what kills."""
    marker = str(tmp_path / "hung")
    with api.runtime_start(n_workers=2, backend="process",
                           deadline_s=120.0) as rt:
        f = rt.submit(hang_once, (marker, 7), {}, name="hang_once",
                      deadline_s=1.0, max_retries=1)
        assert api.wait_on(f, timeout=60) == 7
        assert rt.executor.stats()["deadline_kills"] >= 1


def test_thread_backend_ignores_deadline_gracefully():
    """The thread backend cannot kill a body (same address space); a
    deadline on a well-behaved task must be a no-op, not an error."""
    with api.runtime_start(n_workers=2, backend="thread", deadline_s=5.0):
        t = api.task(lambda x: x * 2, name="dbl")
        assert api.wait_on(t(21), timeout=30) == 42


def test_cluster_agent_watchdog_kills_and_retry_completes(tmp_path):
    """Cluster backend: the per-task deadline rides the task message;
    the agent's watchdog kills the wedged pool worker and ships back a
    retryable ``DeadlineExceededError`` — the agent itself survives (no
    respawn) and the retry completes."""
    marker = str(tmp_path / "hung")
    with api.runtime_start(backend="cluster", n_agents=2,
                           workers_per_node=2, max_retries=1) as rt:
        t = api.task(hang_once, name="hang_once", deadline_s=1.5,
                     max_retries=1)
        assert api.wait_on(t(marker, 99), timeout=90) == 99
        # killed a pool worker, not the agent: no agent respawn happened
        assert rt.executor.stats()["agent_restarts"] == 0
