"""Executor backends and the shared-memory object plane (DESIGN.md §11)."""
import os

import numpy as np
import pytest

from repro.core import api
from repro.core.executors import (
    SHM_MIN_BYTES,
    ProcessExecutor,
    ThreadExecutor,
    WorkerCrashedError,
    make_executor,
)
from repro.core.futures import TaskFailedError

BIG = max(SHM_MIN_BYTES // 8, 4096)  # float64 elements → comfortably planed


@pytest.fixture()
def prt():
    r = api.runtime_start(n_workers=2, backend="process")
    yield r
    api.runtime_stop(wait=False)


def test_make_executor_validates_backend():
    with pytest.raises(ValueError):
        make_executor("carrier-pigeon", 2)
    assert isinstance(make_executor("thread", 2), ThreadExecutor)
    assert isinstance(make_executor("process", 2), ProcessExecutor)


def test_thread_executor_is_a_plain_call():
    ex = ThreadExecutor(1)
    assert ex.invoke(0, lambda a, b=1: a + b, (2,), {"b": 3}) == 5


def test_big_array_roundtrip_uses_the_plane(prt):
    gen = api.task(lambda n: np.arange(n, dtype=np.float64), name="gen")
    out = api.wait_on(gen(BIG))
    np.testing.assert_array_equal(out, np.arange(BIG, dtype=np.float64))
    stats = prt.stats()["executor"]
    assert stats["backend"] == "process"
    assert stats["bytes_planed"] >= BIG * 8


def test_datum_is_planed_once_for_many_consumers(prt):
    gen = api.task(lambda n: np.ones(n), name="gen")
    total = api.task(lambda a: float(np.sum(a)), name="total")
    part = gen(BIG)
    outs = [total(part) for _ in range(6)]
    assert api.wait_on(outs) == [float(BIG)] * 6
    stats = prt.stats()["executor"]
    # one copy into the plane, many refs over the pipes
    assert stats["bytes_planed"] <= BIG * 8 + 1024
    assert stats["refs_shipped"] >= 6


def test_result_segments_are_aliased_not_recopied(prt):
    gen = api.task(lambda n: np.ones(n), name="gen")
    bump = api.task(lambda a: a + 1, name="bump")
    a = gen(BIG)
    api.wait_on(a)          # result adopted + aliased to its datum key
    before = prt.stats()["executor"]["bytes_planed"]
    outs = [bump(a) for _ in range(4)]
    api.barrier()
    api.wait_on(outs)
    after = prt.stats()["executor"]["bytes_planed"]
    # shipping `a` four more times must not copy it again; only the four
    # new results enter the plane
    assert after - before <= 4 * BIG * 8 + 1024


def test_plane_inputs_are_read_only_views(prt):
    def mutate(a):
        a[0] = -1.0   # in-place write on a plane-resident input
        return True

    gen = api.task(lambda n: np.zeros(n), name="gen")
    a = gen(BIG)
    f = api.task(mutate, name="mutate")(a)
    with pytest.raises(TaskFailedError) as exc_info:
        api.wait_on(f)
    assert isinstance(exc_info.value.cause, ValueError)  # read-only ndarray
    # and the shared copy is intact
    np.testing.assert_array_equal(api.wait_on(a)[:3], np.zeros(3))


def test_small_values_skip_the_plane(prt):
    add = api.task(lambda x, y: x + y, name="add")
    assert api.wait_on(add(np.float64(2.0), np.float64(3.0))) == 5.0
    assert prt.stats()["executor"]["bytes_planed"] == 0


def test_unsupported_dtypes_fall_back_to_pickle(prt):
    mk = api.task(lambda n: np.full(n, 1 + 2j, dtype=np.complex128), name="mkc")
    out = api.wait_on(mk(BIG))
    assert out.dtype == np.complex128 and out[0] == 1 + 2j


def test_noncontiguous_inputs_are_handled(prt):
    sum_t = api.task(lambda a: float(np.sum(a)), name="sumt")
    arr = np.ones((256, 256), dtype=np.float64)[:, ::2]  # strided view
    assert api.wait_on(sum_t(arr)) == float(256 * 128)


def test_lambdas_and_closures_cross_the_boundary(prt):
    offset = 17
    t = api.task(lambda x: x + offset, name="closured")
    assert api.wait_on(t(5)) == 22


def _crash_once(flag_path):
    if not os.path.exists(flag_path):
        with open(flag_path, "w") as f:
            f.write("crashed")
        os._exit(17)   # simulate a segfault: no exception, just death
    return "recovered"


def test_worker_crash_is_retryable_and_worker_respawns(prt, tmp_path):
    flag = str(tmp_path / "crashflag")
    f = api.task(_crash_once, max_retries=2)(flag)
    assert api.wait_on(f) == "recovered"
    assert prt.stats()["executor"]["worker_restarts"] >= 1


def test_worker_crash_without_retries_fails_task(prt):
    f = api.task(lambda: os._exit(3), name="die", max_retries=0)()
    with pytest.raises(TaskFailedError) as exc_info:
        api.wait_on(f)
    assert isinstance(exc_info.value.cause, WorkerCrashedError)


def test_transfer_ledger_records_cross_domain_reads(prt):
    gen = api.task(lambda n: np.ones(n), name="gen")
    s = api.task(lambda a, b: float(np.sum(a) + np.sum(b)), name="s")
    parts = [gen(BIG) for _ in range(4)]
    outs = [s(parts[i], parts[(i + 1) % 4]) for i in range(4)]
    api.wait_on(outs)
    transfers, transfer_bytes = prt.store.transfer_stats()
    # with 2 single-process domains, at least one datum crossed domains
    assert transfers >= 1
    assert transfer_bytes >= BIG * 8


def _spin(units):
    acc = 0
    for i in range(units * 10_000):
        acc += (i * i) ^ (acc >> 3)
    return acc


def _measure(backend, n_workers, n_tasks, units):
    import time

    from repro.core.runtime import Runtime
    rt = Runtime(n_workers=n_workers, backend=backend, tracing=False)
    try:
        rt.wait_on(rt.submit(_spin, (1,), name="warm"))
        t0 = time.perf_counter()
        for _ in range(n_tasks):
            rt.submit(_spin, (units,), name="spin")
        rt.barrier()
        return time.perf_counter() - t0
    finally:
        rt.stop(wait=False)


@pytest.mark.slow
def test_process_backend_outscales_threads_on_gil_bound_work():
    """Strong-scaling acceptance: CPU-bound pure-Python tasks at 8 workers.

    The nominal bar is 1.5x (threads serialize on the GIL; processes use
    all cores).  Containers with throttled/shared vCPUs cannot physically
    reach it, so the bound self-calibrates to 70% of the machine's measured
    parallel capacity, capped at the nominal 1.5x; walls are best-of-2 to
    ride out scheduler noise when the suite runs under load."""
    import multiprocessing as mp
    import time

    def burn(sec, q):
        t_end = time.perf_counter() + sec
        n = 0
        while time.perf_counter() < t_end:
            for _ in range(10_000):
                n += 1
        q.put(n)

    ctx = mp.get_context("fork")
    rates = {}
    for nproc in (1, 2):
        q = ctx.Queue()
        ps = [ctx.Process(target=burn, args=(2.0, q)) for _ in range(nproc)]
        t0 = time.perf_counter()
        [p.start() for p in ps]
        total = sum(q.get() for _ in ps)
        [p.join() for p in ps]
        rates[nproc] = total / (time.perf_counter() - t0)
    capacity = rates[2] / rates[1]

    wall_thread = min(_measure("thread", 8, n_tasks=32, units=8)
                      for _ in range(2))
    wall_process = min(_measure("process", 8, n_tasks=32, units=8)
                       for _ in range(2))
    speedup = wall_thread / wall_process
    bound = min(1.5, 0.7 * capacity)
    assert speedup >= bound, (
        f"process speedup {speedup:.2f}x below bound {bound:.2f}x "
        f"(machine parallel capacity {capacity:.2f}x)")


def test_backend_shows_up_in_stats():
    r = api.runtime_start(n_workers=2, backend="thread")
    try:
        t = api.task(lambda: 1, name="one")
        api.wait_on(t())
        assert r.stats()["executor"]["backend"] == "thread"
    finally:
        api.runtime_stop()
