"""Roofline analysis helpers: HLO collective parsing + term math."""
import pytest

from repro.distributed.analysis import (
    Roofline,
    active_params,
    model_flops,
    parse_collectives,
)
from repro.configs import get_config

HLO_SAMPLE = """
HloModule jit_step
%fused (x: f32[128,256]) -> f32[128,256] {
  ...
}
ENTRY %main {
  %ag = bf16[16,4096,512]{2,1,0} all-gather(bf16[16,4096,32]{2,1,0} %p0), replica_groups={{0,1}}, dimensions={2}
  %ar = f32[1024,1024]{1,0} all-reduce(f32[1024,1024]{1,0} %p1), to_apply=%add
  %rs = f32[512,64]{1,0} reduce-scatter(f32[512,1024]{1,0} %p2), dimensions={1}
  %cp = bf16[8,128]{1,0} collective-permute(bf16[8,128]{1,0} %p3), source_target_pairs={{0,1}}
  %a2a = f32[64,64]{1,0} all-to-all(f32[64,64]{1,0} %p4), dimensions={0}
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(HLO_SAMPLE)
    assert st.count == 5
    assert set(st.by_kind) == {"all-gather", "all-reduce", "reduce-scatter",
                               "all-to-all", "collective-permute"}
    # all-gather result: 16*4096*512*2 bytes
    assert st.by_kind["all-gather"] == 16 * 4096 * 512 * 2
    # all-reduce double-counted (reduce + broadcast halves)
    assert st.by_kind["all-reduce"] == 2 * 1024 * 1024 * 4
    assert st.total_bytes == sum(st.by_kind.values())


def test_parse_ignores_non_collectives():
    st = parse_collectives("%x = f32[8,8] add(f32[8,8] %a, f32[8,8] %b)")
    assert st.count == 0 and st.total_bytes == 0


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                 hlo_flops=256 * 197e12,        # exactly 1s of compute
                 hlo_bytes=256 * 819e9 * 0.5,   # 0.5s of memory
                 collective_bytes=256 * 50e9 * 0.25,
                 model_flops_total=256 * 197e12 * 0.8).finalize()
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_ratio == pytest.approx(0.8)


def test_active_params_moe_discounts_experts():
    dense = get_config("granite-3-2b")
    assert active_params(dense) == pytest.approx(2.63e9, rel=0.05)
    moe = get_config("deepseek-moe-16b")
    total = 16.9e9
    act = active_params(moe)
    assert act < total * 0.3  # top-6 of 64 + shared + backbone
    assert act > 1.5e9


def test_model_flops_decode_counts_new_tokens_only():
    cfg = get_config("granite-3-2b")
    n = active_params(cfg)
    assert model_flops(cfg, "train", 256, 4096) == pytest.approx(
        6 * n * 256 * 4096)
    assert model_flops(cfg, "decode", 128, 32768) == pytest.approx(
        2 * n * 128)
