"""Distribution layer: sharding rule logic (host-side) + an 8-device
pjit/shard_map integration test run in a subprocess (device count is
process-global, so the forced-host-device test cannot share this process).
"""
import os
import subprocess
import sys
import textwrap

import pytest

# minutes of JAX compile+run on CPU: opt-in via `-m slow` (see pytest.ini)
pytestmark = pytest.mark.slow


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_spec_for_divisibility_and_axis_reuse():
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import ShardingRules, spec_for, default_rules
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = default_rules(mesh)
    # divisible: sharded; non-divisible: replicated
    assert spec_for((16, 8), ("embed", "heads"), rules, mesh) == P("data", "model")
    assert spec_for((16, 6), ("embed", "heads"), rules, mesh) == P("data", None)
    assert spec_for((3, 8), ("embed", "heads"), rules, mesh) == P(None, "model")
    # the same mesh axis is never used twice
    s = spec_for((8, 8), ("heads", "mlp"), rules, mesh)
    assert s == P("model", None)
    print("OK")
    """
    assert "OK" in run_sub(code, devices=8)


def test_train_and_decode_on_8_forced_devices():
    code = """
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config, SMOKE_SHAPES, make_batch
    from repro.distributed.steps import make_train_step, make_decode_step
    from repro.models.lm import init_params
    from repro.optim.adamw import adamw

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for arch in ("granite-3-2b", "deepseek-moe-16b", "mamba2-780m"):
        cfg = get_config(arch, reduced=True)
        shape = dataclasses.replace(SMOKE_SHAPES["train_4k"], batch=4)
        b = make_batch(cfg, shape)
        opt = adamw(1e-3)
        fn, in_sh, out_sh, don = make_train_step(
            cfg, mesh, opt, microbatches=2, sample_batch=b["batch"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = opt.init(params)
        j = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=don)
        p2, s2, m = j(params, state, b["batch"])
        assert jnp.isfinite(m["loss"]), arch
        print(arch, float(m["loss"]))
    print("OK")
    """
    assert "OK" in run_sub(code)


def test_moe_sharded_matches_local_on_4_devices():
    """EP shard_map MoE == single-shard dispatch (same capacity)."""
    code = """
    import jax, jax.numpy as jnp
    from repro.layers import moe
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    p = moe.init_moe(jax.random.PRNGKey(0), 16, 32, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    # capacity: local sees N=32 tokens on the single data shard either way
    out_l, aux_l = moe.moe_apply_local(p, x, top_k=2, capacity_factor=8.0)
    out_s, aux_s = jax.jit(lambda p, x: moe.moe_apply_sharded(
        p, x, mesh=mesh, top_k=2, data_axes=("data",),
        capacity_factor=8.0))(p, x)
    import numpy as np
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_l), atol=2e-5)
    assert abs(float(aux_s) - float(aux_l)) < 1e-5
    print("OK")
    """
    assert "OK" in run_sub(code, devices=4)


def test_tp_shard_map_equals_gspmd():
    """The §Perf shard_map-TP path computes the identical function (loss and
    grads) as the *replicated* ground truth.

    Ground truth is the unsharded forward/backward rather than the
    GSPMD-sharded baseline: on this stack (jaxlib 0.4.36 CPU) the SPMD
    partitioner miscompiles the GSPMD attention path when params carry the
    FSDP shardings and the activations enter feature-sharded over ``data``
    — the reshard it warns about with "involuntary full rematerialization"
    corrupts values (loss off by ~3e-2, grad max-diff ~0.15 vs truth;
    identical under the Shardy partitioner, so it is the partitioned HLO,
    not a jax-level transpose).  The shard_map TP path matches the
    replicated truth to ~1e-6, so it is the trusted side; the GSPMD
    baseline only gets a coarse sanity bound until the upstream fix."""
    code = """
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config, SMOKE_SHAPES, make_batch
    from repro.models.lm import loss_fn, init_params, param_axes
    from repro.distributed.sharding import (default_rules, param_pspecs,
                                            to_shardings)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = default_rules(mesh)
    for arch in ("granite-3-2b", "recurrentgemma-9b"):
        cfg = get_config(arch, reduced=True)
        shape = dataclasses.replace(SMOKE_SHAPES["train_4k"], batch=4)
        b = make_batch(cfg, shape)
        params = init_params(cfg, jax.random.PRNGKey(0))
        cfg_tp = dataclasses.replace(cfg, tp_block="shard_map")
        # replicated ground truth (single-device semantics)
        l_ref, _ = jax.jit(lambda p, bb: loss_fn(cfg, p, bb, mesh=mesh))(params, b["batch"])
        g_ref = jax.jit(jax.grad(
            lambda p: loss_fn(cfg, p, b["batch"], mesh=mesh)[0]))(params)
        # production contract: parameters carry explicit shardings
        p_sh = to_shardings(param_pspecs(param_axes(cfg), params, rules, mesh),
                            mesh)
        params_sh = jax.tree.map(jax.device_put, params, p_sh)
        l_g, _ = jax.jit(lambda p, bb: loss_fn(cfg, p, bb, mesh=mesh))(params_sh, b["batch"])
        l_t, _ = jax.jit(lambda p, bb: loss_fn(cfg_tp, p, bb, mesh=mesh))(params_sh, b["batch"])
        g_t = jax.jit(jax.grad(
            lambda p: loss_fn(cfg_tp, p, b["batch"], mesh=mesh)[0]))(params_sh)
        gd = max(float(jnp.max(jnp.abs(a - c)))
                 for a, c in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_t)))
        # coarse bound only: XLA CPU partitioner miscompile (see docstring)
        assert abs(float(l_g) - float(l_ref)) < 0.1, (arch, "gspmd fwd")
        assert abs(float(l_t) - float(l_ref)) < 1e-4, (arch, "tp fwd")
        assert gd < 1e-3, (arch, gd)
        print(arch, "tp==truth", float(l_t))
    print("OK")
    """
    assert "OK" in run_sub(code)


def test_elastic_checkpoint_restore_across_meshes():
    """Fault-tolerance at 1000-node scale means restarting on a different
    machine shape: save a sharded state on a (4,2) mesh, restore it onto a
    (2,4) mesh, and continue training — losses must continue unperturbed."""
    code = """
    import dataclasses, jax, jax.numpy as jnp, tempfile
    from repro.configs import get_config, SMOKE_SHAPES, make_batch
    from repro.checkpoint.manager import restore_checkpoint, save_checkpoint
    from repro.distributed.sharding import (default_rules, param_pspecs,
                                            to_shardings)
    from repro.distributed.steps import make_train_step
    from repro.models.lm import init_params, param_axes
    from repro.optim.adamw import adamw

    cfg = get_config("qwen3-0.6b", reduced=True)
    shape = dataclasses.replace(SMOKE_SHAPES["train_4k"], batch=8)
    b = make_batch(cfg, shape)
    opt = adamw(1e-3)

    def step_on(mesh, params, opt_state):
        fn, in_sh, out_sh, don = make_train_step(cfg, mesh, opt,
                                                 sample_batch=b["batch"])
        j = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=don)
        return j(params, opt_state, b["batch"])

    # phase 1: mesh A = (4, 2)
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    params, state, m1 = step_on(mesh_a, params, state)
    ckpt = tempfile.mkdtemp()
    save_checkpoint(ckpt, {"params": params, "opt": state}, step=1)

    # uninterrupted continuation on mesh A (the reference)
    _, _, m_ref = step_on(mesh_a, params, state)

    # phase 2: RESTART on mesh B = (2, 4) — different data/model split
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    target = {"params": params, "opt": state}
    p_sh = to_shardings(param_pspecs(param_axes(cfg), params,
                                     default_rules(mesh_b), mesh_b), mesh_b)
    restored, step = restore_checkpoint(ckpt, target)
    rp = jax.tree.map(jax.device_put, restored["params"], p_sh)
    _, _, m_b = step_on(mesh_b, rp, restored["opt"])
    assert step == 1
    assert abs(float(m_ref["loss"]) - float(m_b["loss"])) < 1e-4, (
        float(m_ref["loss"]), float(m_b["loss"]))
    print("elastic restore ok", float(m_b["loss"]))
    print("OK")
    """
    assert "OK" in run_sub(code)


def test_grad_compress_in_train_step():
    code = """
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config, SMOKE_SHAPES, make_batch
    from repro.distributed.steps import make_train_step
    from repro.models.lm import init_params
    from repro.optim.adamw import adamw
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    cfg = get_config("qwen3-0.6b", reduced=True)
    shape = dataclasses.replace(SMOKE_SHAPES["train_4k"], batch=4)
    b = make_batch(cfg, shape)
    opt = adamw(1e-3)
    fn, in_sh, out_sh, don = make_train_step(cfg, mesh, opt,
        sample_batch=b["batch"], grad_compress="int8")
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    p2, s2, m = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=don)(params, state, b["batch"])
    assert jnp.isfinite(m["loss"])
    print("OK")
    """
    assert "OK" in run_sub(code, devices=2)
