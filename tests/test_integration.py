"""End-to-end integration: train driver, serve driver, fault injection."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import api
from repro.launch.serve import serve_batch
from repro.launch.train import train_loop

# minutes of JAX compile+run on CPU: opt-in via `-m slow` (see pytest.ini)
pytestmark = pytest.mark.slow



def test_train_loop_loss_improves():
    cfg = get_config("qwen3-0.6b", reduced=True)
    out = train_loop(cfg, steps=12, batch=4, seq=24, lr=1e-3, workers=2,
                     seed=1, log_every=0)
    assert out["steps_done"] == 12
    assert all(np.isfinite(out["losses"]))
    assert min(out["losses"][-4:]) < out["losses"][0]  # learning happens


def test_train_loop_microbatched_matches_tokens():
    cfg = get_config("internlm2-1.8b", reduced=True)
    out = train_loop(cfg, steps=3, batch=4, seq=16, microbatches=2,
                     workers=2, log_every=0)
    assert out["steps_done"] == 3
    assert all(np.isfinite(out["losses"]))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-780m",
                                  "recurrentgemma-9b", "musicgen-medium"])
def test_serve_batch_generates(arch):
    cfg = get_config(arch, reduced=True)
    out = serve_batch(cfg, batch=2, prompt_len=12, gen_len=5)
    assert out["tokens"].shape == (2, 5)
    assert np.all(out["tokens"] >= 0) and np.all(out["tokens"] < cfg.vocab_size)


def test_task_failure_is_retried_in_pipeline():
    """A flaky data task recovers via runtime resubmission — the paper's
    fault-tolerance mechanism in the training pipeline."""
    api.runtime_start(n_workers=2, max_retries=3)
    try:
        attempts = {"n": 0}

        def flaky_source(step):
            attempts["n"] += 1
            if attempts["n"] % 2 == 1:
                raise IOError("storage hiccup")
            return np.full((2, 2), step)

        t = api.task(flaky_source, name="flaky_source")
        outs = api.wait_on([t(s) for s in range(4)])
        assert [int(o[0, 0]) for o in outs] == [0, 1, 2, 3]
        stats = api.current_runtime().stats()
        assert stats["retries"] >= 1
    finally:
        api.runtime_stop()
