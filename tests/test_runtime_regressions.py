"""Regression tests for known-delicate Runtime paths.

These pin behaviours that are easy to break when refactoring the
completion paths: INOUT version-renaming snapshots, the speculation
duplicate-completion race in ``_claim_completion``, and the multi-output
arity-mismatch failure path.
"""
import numpy as np
import pytest

from repro.core import api
from repro.core.dag import TaskNode, TaskState
from repro.core.futures import TaskFailedError

BACKENDS = ("thread", "process")


@pytest.mark.parametrize("backend", BACKENDS)
def test_inout_snapshot_reader_sees_pre_rename_version(backend):
    """A task submitted *before* an INOUT rename must read the old version
    even when it executes after the rename happened (COMPSs renaming)."""
    rt = api.runtime_start(n_workers=2, backend=backend)
    try:
        mk = api.task(lambda: np.zeros(4), name="mk")
        buf = mk()
        v1 = buf.version

        # reader submitted first: snapshots (data_id, v1)
        reader = api.task(lambda a: float(np.sum(a)), name="reader")(buf)

        rt.submit(lambda x: x + 1, (buf,), name="bump", returns=0, inout=[buf])
        assert buf.version == v1 + 1

        # a reader submitted *after* the rename sees the new contents
        late_reader = api.task(lambda a: float(np.sum(a)), name="late")(buf)

        assert api.wait_on(reader) == 0.0        # pre-rename contents
        assert api.wait_on(late_reader) == 4.0   # post-rename contents
        assert api.wait_on(buf).tolist() == [1.0] * 4
    finally:
        api.runtime_stop()


def test_chained_inout_renames_version_per_writer():
    rt = api.runtime_start(n_workers=2)
    try:
        mk = api.task(lambda: np.zeros(2), name="mk")
        buf = mk()
        versions = [buf.version]
        for _ in range(3):
            rt.submit(lambda x: x + 1, (buf,), name="bump", returns=0, inout=[buf])
            versions.append(buf.version)
        assert versions == [1, 2, 3, 4]
        np.testing.assert_array_equal(api.wait_on(buf), np.full(2, 3.0))
    finally:
        api.runtime_stop()


def test_claim_completion_is_exactly_once():
    """The speculation race: primary and clone both finish; only the first
    claim publishes, the loser is discarded as CANCELLED."""
    rt = api.runtime_start(n_workers=2)
    try:
        f = api.task(lambda: 7, name="seven")()
        assert api.wait_on(f) == 7
        primary = rt.graph.get(f.producer_task)

        # the primary already claimed its logical completion
        assert rt._claim_completion(primary) is False

        # a late speculative clone of the same logical task must lose
        clone = TaskNode(task_id=rt.graph.next_task_id(), name="seven(spec)",
                         fn=primary.fn, args=primary.args, kwargs=primary.kwargs,
                         dep_keys=set(primary.dep_keys), out_keys=[],
                         speculative_of=primary.task_id, speculatable=False)
        rt.graph.add_task(clone)
        assert rt._claim_completion(clone) is False

        with rt._inflight_cond:
            rt._inflight += 1
        rt._finish_success(clone, 999, node_id=0)   # duplicate completion
        assert rt.graph.get(clone.task_id).state == TaskState.CANCELLED
        assert api.wait_on(f) == 7                   # value not clobbered
        rt.barrier(timeout=5.0)                      # accounting balanced
    finally:
        api.runtime_stop()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bad_result", [5, (1, 2, 3), [1]])
def test_multi_output_arity_mismatch_fails_all_outputs(backend, bad_result):
    """A task declaring N outputs but returning something else publishes
    TaskFailedError to *every* out key — no waiter may hang."""
    api.runtime_start(n_workers=2, backend=backend)
    try:
        t = api.task(lambda r: r, returns=2, name="badarity")
        hi, lo = t(bad_result)
        for fut in (hi, lo):
            with pytest.raises(TaskFailedError) as exc_info:
                api.wait_on(fut, timeout=10.0)
            assert isinstance(exc_info.value.cause, TypeError)
        api.barrier(timeout=5.0)  # must not hang
        states = [n.state for n in api.current_runtime().graph.nodes()]
        assert TaskState.FAILED in states
    finally:
        api.runtime_stop(wait=False)


def test_child_of_two_outputs_of_one_task_releases_once():
    """Regression: a child reading *two outputs of the same producer* must
    count one unresolved edge — double-counting left it PENDING forever."""
    api.runtime_start(n_workers=2)
    try:
        t = api.task(lambda: (3, 4), returns=2, name="pair")
        hi, lo = t()
        add = api.task(lambda a, b: a + b, name="add")
        assert api.wait_on(add(hi, lo), timeout=10.0) == 7
    finally:
        api.runtime_stop()


def test_dependent_submitted_after_producer_failed_fails_fast():
    """Regression: wiring an edge to an already-FAILED producer (whose
    release ran before the child existed) must not block the child."""
    api.runtime_start(n_workers=2)
    try:
        boom = api.task(lambda: 1 / 0, name="boom")
        g = boom()
        api.barrier()  # guarantee the producer is FAILED before we submit
        child = api.task(lambda x: x, name="reader")(g)
        with pytest.raises(TaskFailedError):
            api.wait_on(child, timeout=10.0)
        api.barrier(timeout=5.0)
    finally:
        api.runtime_stop(wait=False)


def test_arity_mismatch_poisons_dependents():
    api.runtime_start(n_workers=2)
    try:
        t = api.task(lambda: 1, returns=2, name="badarity")
        hi, lo = t()
        add = api.task(lambda a, b: a + b, name="add")
        child = add(hi, lo)
        with pytest.raises(TaskFailedError):
            api.wait_on(child, timeout=10.0)
        api.barrier(timeout=5.0)
    finally:
        api.runtime_stop(wait=False)
