"""Checkpoint/restart: roundtrip, bf16, GC, determinism across restart."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
                   "c": [jnp.zeros(3, jnp.int32), jnp.ones(1)]},
    }


def test_roundtrip_with_bf16(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), tree, step=7)
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_selected_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        m.save(tree, s)
    assert m.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000003", "step_00000004"]


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), {"a": jnp.ones(3)}, step=1)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.ones(3), "b": jnp.ones(2)})


@pytest.mark.slow
def test_restart_determinism(tmp_path):
    """Train 3+3 steps with a restart == train 6 straight (same seed)."""
    from repro.configs import get_config
    from repro.launch.train import train_loop

    cfg = get_config("qwen3-0.6b", reduced=True)
    d1 = tmp_path / "a"
    full = train_loop(cfg, steps=6, batch=2, seq=16, workers=2, seed=3,
                      log_every=0)
    train_loop(cfg, steps=3, batch=2, seq=16, workers=2, seed=3,
               ckpt_dir=str(d1), ckpt_every=3, log_every=0)
    part2 = train_loop(cfg, steps=6, batch=2, seq=16, workers=2, seed=3,
                       ckpt_dir=str(d1), restore=True, log_every=0)
    assert part2["restored_from"] == 3
    assert part2["losses"][-1] == pytest.approx(full["losses"][-1], abs=1e-4)


def test_elastic_restore_onto_sharding(tmp_path):
    """Restore with explicit shardings (single-device NamedSharding here;
    the same code path reshards onto any mesh)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), tree, step=1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = restore_checkpoint(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
