"""Liveness failure detection over the heartbeat plane (DESIGN.md §19).

The headline property: an agent that *wedges without dying* (SIGSTOP —
the TCP connection stays open, so before this layer the job hung
forever) is detected by beat age alone, its channel is closed, and the
existing respawn/lineage recovery finishes the job with bitwise-identical
results.  Plus: the detector's verdicts surface in ``/api/status``, and
conservative settings never false-kill a healthy cluster."""
import os
import signal
import time

import pytest

from repro.core import api


def work(i: int) -> float:
    """Deterministic, small-result body (results ride the reply inline:
    no peer pulls can block on a frozen node's data plane)."""
    import time

    import numpy as np
    time.sleep(0.1)
    a = np.arange(200, dtype=np.float64) * (i + 1)
    return float(np.sqrt(a).sum())


def expected(i: int) -> float:
    import numpy as np
    a = np.arange(200, dtype=np.float64) * (i + 1)
    return float(np.sqrt(a).sum())


@pytest.mark.chaos
def test_sigstop_agent_detected_and_job_completes_bitwise():
    """SIGSTOP an agent mid-run: no TCP disconnect ever happens, yet the
    failure detector declares it dead within the suspicion window, the
    channel close drives the normal respawn path, and every result is
    bitwise-identical to the reference."""
    n_tasks = 60
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2,
                           heartbeat_s=0.2, suspicion_s=0.6,
                           max_retries=4) as rt:
        t = api.task(work, name="work", max_retries=4)
        futures = t.map([(i,) for i in range(n_tasks)])
        time.sleep(0.5)   # let dispatch spread over both agents
        victim = rt.executor.cluster._procs[1]
        assert victim is not None and victim.poll() is None
        os.kill(victim.pid, signal.SIGSTOP)
        t_stop = time.monotonic()
        results = api.wait_on(futures, timeout=120)
        ex = rt.executor
        # detected by liveness (beat age), not by a disconnect
        assert ex.liveness_kills >= 1
        assert ex.agent_restarts >= 1
        detect_window = time.monotonic() - t_stop
        assert detect_window < 60, "detection took implausibly long"
    assert results == [expected(i) for i in range(n_tasks)]


def test_no_false_kills_with_default_settings():
    """Conservative (default) liveness settings on a healthy cluster:
    zero kills, zero restarts — the detector must never create the
    failures it exists to catch."""
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2,
                           heartbeat_s=0.2) as rt:
        t = api.task(work, name="work")
        out = api.wait_on(t.map([(i,) for i in range(12)]), timeout=60)
        assert out == [expected(i) for i in range(12)]
        assert rt.executor.liveness_kills == 0
        assert rt.executor.agent_restarts == 0
        states = {v["state"] for v in rt.executor.liveness().values()}
        assert states == {"alive"}


def test_liveness_surfaces_in_api_status():
    """``/api/status`` node entries carry the detector's verdict and the
    beat age it is based on."""
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=1,
                           heartbeat_s=0.2, telemetry=True) as rt:
        t = api.task(lambda x: x + 1, name="inc")
        assert api.wait_on(t(1), timeout=60) == 2
        deadline = time.monotonic() + 10
        snap = {}
        while time.monotonic() < deadline:
            snap = rt.telemetry.snapshot_status(rt)
            nodes = snap.get("nodes", {})
            if {"0", "1"} <= set(nodes) and all(
                    "state" in n for n in nodes.values()):
                break
            time.sleep(0.1)
        nodes = snap["nodes"]
        assert {"0", "1"} <= set(nodes)
        for n in nodes.values():
            assert n["state"] == "alive"
            assert n["beat_age_s"] is not None and n["beat_age_s"] < 5.0


def test_liveness_disabled_runs_clean():
    """``liveness=False`` (RJAX_LIVENESS=0): no detector thread, no
    kills, everything still works."""
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=1,
                           liveness=False) as rt:
        t = api.task(lambda x: x * 3, name="tri")
        assert api.wait_on(t(5), timeout=60) == 15
        assert rt.executor.liveness_kills == 0
