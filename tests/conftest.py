"""Shared test fixtures + a dependency-free ``hypothesis`` fallback.

The property tests (test_serialization, test_simulator, scheduler policy
tests) are written against the hypothesis API.  When the real library is
installed it is used unchanged; otherwise a tiny deterministic shim is
registered in ``sys.modules`` *before* test modules import it, so the
suite collects and runs green in minimal environments.  The shim supports
exactly the subset this repo uses: ``@given`` with keyword strategies,
``@settings(max_examples=, deadline=)``, and the ``integers`` / ``floats``
/ ``lists`` / ``sampled_from`` / ``data`` strategies.
"""
import random
import sys
import types

import numpy as np
import pytest

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elements.sample(rng) for _ in range(rng.randint(min_size, max_size))
        ])

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    class _DataProxy:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.sample(self._rng)

    _DATA_SENTINEL = object()

    def data():
        s = _Strategy(lambda rng: _DataProxy(rng))
        s._is_data = True
        return s

    def settings(max_examples=50, deadline=None, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def runner(*fargs, **fkwargs):
                n = getattr(fn, "_shim_max_examples",
                            getattr(runner, "_shim_max_examples", 25))
                for i in range(n):
                    rng = random.Random(0xC0FFEE + i)
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*fargs, **drawn, **fkwargs)
            # no __wrapped__: pytest would unwrap and read the strategy
            # parameters as fixture requests
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.floats = floats
    _st.lists = lists
    _st.sampled_from = sampled_from
    _st.data = data

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__is_rjax_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)
