"""The unified RuntimeConfig contract (DESIGN.md §18).

Three properties pinned here:

1. **No orphan knobs** — every ``RJAX_*`` env var mentioned anywhere in
   ``src/`` is declared as a :class:`RuntimeConfig` field, so the README
   knob table (generated from the dataclass) is complete by construction.
2. **One precedence rule** — explicit > env > welcome > default, via the
   single ``resolve()`` implementation every consumer routes through.
3. **API compatibility** — old ``runtime_start(**kwargs)`` call sites run
   unmodified, ``config=`` composes with kwargs, unknown kwargs raise
   ``TypeError``, and the returned runtime is a context manager that
   stops on exit (exceptions included).
"""
import argparse
import os
import re
import subprocess
import sys

import pytest

from repro.core import api
from repro.core.config import (RuntimeConfig, add_agent_cli_args,
                               declared_env_knobs, knob_table, parse_bool,
                               resolve)

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
README = os.path.join(os.path.dirname(__file__), os.pardir, "README.md")


# ------------------------------------------------------------- orphan knobs
def _knobs_in_source():
    found = set()
    for dirpath, dirnames, filenames in os.walk(SRC):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            with open(os.path.join(dirpath, fn)) as fh:
                found.update(re.findall(r"RJAX_[A-Z0-9_]+", fh.read()))
    return found


def test_every_env_knob_in_src_is_declared():
    declared = set(declared_env_knobs())
    orphans = _knobs_in_source() - declared
    assert not orphans, (
        f"undeclared RJAX_* knob(s) in src/: {sorted(orphans)} — add them "
        f"to repro.core.config.RuntimeConfig so the generated README table "
        f"and the precedence rule cover them")


def test_declared_knobs_are_actually_read_somewhere():
    dead = set(declared_env_knobs()) - _knobs_in_source()
    assert not dead, f"RuntimeConfig declares unused env knob(s): {sorted(dead)}"


def test_readme_knob_table_is_in_sync():
    """README's table between the knob-table markers is byte-identical to
    the generated one (regenerate: ``python -m repro.core.config``)."""
    text = open(README).read()
    m = re.search(r"<!-- knob-table:begin -->\n(.*?)\n<!-- knob-table:end -->",
                  text, flags=re.S)
    assert m, "README.md lost its knob-table markers"
    assert m.group(1) == knob_table(), (
        "README knob table is stale — regenerate it with "
        "`PYTHONPATH=src python -m repro.core.config` and paste between "
        "the markers")


def test_knob_table_cli_prints_the_table():
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.config"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), check=True).stdout
    assert knob_table() in out


# --------------------------------------------------------------- precedence
def test_resolve_precedence_explicit_env_welcome_default(monkeypatch):
    monkeypatch.delenv("RJAX_TEST_KNOB", raising=False)
    assert resolve(None, "RJAX_TEST_KNOB", None, 7, int) == 7          # default
    assert resolve(None, "RJAX_TEST_KNOB", 5, 7, int) == 5             # welcome
    monkeypatch.setenv("RJAX_TEST_KNOB", "3")
    assert resolve(None, "RJAX_TEST_KNOB", 5, 7, int) == 3             # env
    assert resolve(1, "RJAX_TEST_KNOB", 5, 7, int) == 1                # explicit
    monkeypatch.setenv("RJAX_TEST_KNOB", "")   # empty env var = unset
    assert resolve(None, "RJAX_TEST_KNOB", 5, 7, int) == 5


def test_config_resolved_field_follows_env(monkeypatch):
    monkeypatch.delenv("RJAX_PIPELINE_DEPTH", raising=False)
    assert RuntimeConfig().resolved("pipeline_depth") == 4
    monkeypatch.setenv("RJAX_PIPELINE_DEPTH", "9")
    assert RuntimeConfig().resolved("pipeline_depth") == 9
    assert RuntimeConfig(pipeline_depth=2).resolved("pipeline_depth") == 2


def test_parse_bool_spellings():
    for false in ("0", "false", "OFF", "no", "", None, False):
        assert parse_bool(false) is False
    for true in ("1", "true", "ON", "yes", True):
        assert parse_bool(true) is True


# ------------------------------------------------------------ merged / shim
def test_merged_kwargs_override_config():
    cfg = RuntimeConfig(n_workers=2, backend="process")
    out = cfg.merged(n_workers=6)
    assert out.n_workers == 6 and out.backend == "process"
    assert cfg.n_workers == 2   # original untouched


def test_unknown_kwarg_raises_typeerror():
    with pytest.raises(TypeError, match="pipelin_depth"):
        RuntimeConfig().merged(pipelin_depth=8)
    with pytest.raises(TypeError, match="known knobs"):
        api.runtime_start(definitely_not_a_knob=1)


def test_runtime_kwargs_omits_unset_fields():
    assert RuntimeConfig().runtime_kwargs() == {}
    out = RuntimeConfig(n_workers=3, policy="lifo").runtime_kwargs()
    assert out == {"n_workers": 3, "policy": "lifo"}


# ------------------------------------------------- runtime_start integration
def test_old_kwarg_call_sites_run_unmodified():
    rt = api.runtime_start(n_workers=2, backend="thread", policy="fifo",
                           max_retries=1, tracing=False)
    try:
        assert api.wait_on(api.task(lambda x: x * 2)(21)) == 42
        assert rt.executor.n_workers == 2
    finally:
        api.runtime_stop()


def test_config_object_and_kwargs_compose():
    cfg = RuntimeConfig(backend="thread", n_workers=1)
    rt = api.runtime_start(config=cfg, n_workers=3)   # kwarg wins
    try:
        assert rt.executor.n_workers == 3
    finally:
        api.runtime_stop()


def test_runtime_start_is_a_context_manager():
    with api.runtime_start(n_workers=2) as rt:
        assert api.wait_on(api.task(lambda: "in")( )) == "in"
    assert rt._stopped
    # the module-level current runtime was released too
    with pytest.raises(RuntimeError):
        api.current_runtime()


def test_context_manager_stops_on_exception():
    class Boom(Exception):
        pass
    with pytest.raises(Boom):
        with api.runtime_start(n_workers=2) as rt:
            raise Boom()
    assert rt._stopped
    # and a fresh runtime can start afterwards
    with api.runtime_start(n_workers=1):
        pass


def test_explicit_stop_inside_with_block_is_fine():
    with api.runtime_start(n_workers=1) as rt:
        api.runtime_stop()
    assert rt._stopped


# ----------------------------------------------------------------- agent CLI
def test_agent_cli_mirrors_runtimeconfig_fields():
    p = argparse.ArgumentParser()
    add_agent_cli_args(p)
    flags = {a.option_strings[0] for a in p._actions if a.option_strings}
    assert {"--memory-budget", "--mp-context",
            "--inline-max", "--heartbeat-s"} <= flags
    args = p.parse_args(["--memory-budget", "256M", "--heartbeat-s", "0.5"])
    assert args.memory_budget == "256M"
    assert args.heartbeat_s == "0.5"
    assert args.inline_max is None          # unset → env/welcome tier


def test_agent_build_arg_parser_has_topology_and_knob_flags():
    from repro.cluster.agent import build_arg_parser
    p = build_arg_parser()
    flags = {s for a in p._actions for s in a.option_strings}
    assert {"--connect", "--workers", "--node-id",
            "--memory-budget", "--mp-context",
            "--inline-max", "--heartbeat-s"} <= flags
