"""Core task-runtime semantics (the paper's §3 behaviours).

Every test here runs against **both executor backends** (``thread`` and
``process``, see repro/core/executors.py): the runtime's user-visible
semantics — dependency order, fault propagation, INOUT renaming,
speculation, tracing — are backend-independent.  Tests that used to
observe side effects through shared closures now observe them through the
filesystem (O_APPEND writes are atomic for these sizes), which holds in
both address-space models.
"""
import os
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.dag import TaskState
from repro.core.futures import TaskFailedError

BACKENDS = ("thread", "process")


@pytest.fixture(params=BACKENDS)
def rt(request):
    r = api.runtime_start(n_workers=4, backend=request.param)
    yield r
    api.runtime_stop(wait=False)


def _append(path, tag, dep=None):
    with open(path, "a") as f:
        f.write(f"{tag}\n")
    return tag


def test_fig2_add_four_numbers(rt):
    """The paper's Fig. 2 program."""
    add = api.task(lambda x, y: x + y, name="add")
    r1 = add(4, 5)
    r2 = add(6, 7)
    r3 = add(r1, r2)
    assert api.wait_on(r3) == 22


def test_dependency_order_is_respected(rt, tmp_path):
    log = str(tmp_path / "order.log")
    t = api.task(_append)
    a = t(log, "a")
    b = t(log, "b", dep=a)
    c = t(log, "c", dep=b)
    api.wait_on(c)
    seen = open(log).read().split()
    assert seen.index("a") < seen.index("b") < seen.index("c")


def test_wide_fanout_barrier(rt):
    t = api.task(lambda i: i * i, name="sq")
    futs = [t(i) for i in range(50)]
    api.barrier()
    assert all(f.done() for f in futs)
    assert api.wait_on(futs) == [i * i for i in range(50)]


def test_nested_future_args(rt):
    t = api.task(lambda xs: sum(xs["vals"]), name="sum")
    mk = api.task(lambda i: i, name="mk")
    futs = {"vals": [mk(i) for i in range(5)]}
    assert api.wait_on(t(futs)) == 10


def _flaky(counter_path, x):
    # attempts are counted in the filesystem: visible to the submitting
    # process no matter which address space ran the body
    with open(counter_path, "a") as f:
        f.write("x")
    if os.path.getsize(counter_path) < 3:
        raise ValueError("transient")
    return x


def test_retry_then_success(rt, tmp_path):
    counter = str(tmp_path / "attempts")
    f = api.task(_flaky, max_retries=5)(counter, 42)
    assert api.wait_on(f) == 42
    assert os.path.getsize(counter) == 3


def test_permanent_failure_propagates(rt):
    def boom():
        raise RuntimeError("dead")

    add = api.task(lambda x, y: x + y, name="add")
    g = api.task(boom)()
    h = add(g, 1)
    i = add(h, 1)  # transitive dependent
    with pytest.raises(TaskFailedError):
        api.wait_on(i)
    api.barrier()  # must not hang
    states = {n.name: n.state for n in api.current_runtime().graph.nodes()}
    assert states["boom"] == TaskState.FAILED


def test_exception_type_survives_the_backend(rt):
    """The original exception class crosses the address-space boundary."""
    def typed_boom():
        raise KeyError("missing-widget")

    f = api.task(typed_boom)()
    with pytest.raises(TaskFailedError) as exc_info:
        api.wait_on(f)
    assert isinstance(exc_info.value.cause, KeyError)


def test_multiple_returns(rt):
    t = api.task(lambda x: (x + 1, x - 1), returns=2, name="pm")
    hi, lo = t(10)
    assert api.wait_on(hi) == 11 and api.wait_on(lo) == 9


@pytest.mark.parametrize("backend", BACKENDS)
def test_inout_versioning(backend):
    """COMPSs renaming: an INOUT arg gets a new dXvY version."""
    rt = api.runtime_start(n_workers=2, backend=backend)
    try:
        mk = api.task(lambda: np.zeros(3), name="mk")
        buf = mk()
        v1 = buf.version

        def bump(x):
            return x + 1

        rt.submit(bump, (buf,), name="bump", returns=0, inout=[buf])
        assert buf.version == v1 + 1
        out = api.wait_on(buf)
        np.testing.assert_array_equal(out, np.ones(3))
    finally:
        api.runtime_stop()


@pytest.mark.parametrize("backend", BACKENDS)
def test_numpy_payloads_and_locality_policy(backend):
    api.runtime_start(n_workers=4, workers_per_node=2, policy="locality",
                      backend=backend)
    try:
        gen = api.task(lambda n: np.arange(n, dtype=np.float64), name="gen")
        s = api.task(lambda a, b: float(np.sum(a) + np.sum(b)), name="s")
        parts = [gen(100) for _ in range(8)]
        outs = [s(parts[i], parts[(i + 1) % 8]) for i in range(8)]
        total = sum(api.wait_on(outs))
        assert total == pytest.approx(2 * 8 * (99 * 100 / 2))
    finally:
        api.runtime_stop()


@pytest.mark.parametrize("backend", BACKENDS)
def test_worksteal_policy_completes(backend):
    api.runtime_start(n_workers=4, policy="worksteal", backend=backend)
    try:
        t = api.task(lambda i: i, name="id")
        assert sorted(api.wait_on([t(i) for i in range(40)])) == list(range(40))
    finally:
        api.runtime_stop()


@pytest.mark.parametrize("backend", BACKENDS)
def test_speculation_duplicates_straggler(backend):
    api.runtime_start(n_workers=4, speculation=True, speculation_factor=2.0,
                      backend=backend)
    try:
        def work(i, delay):
            time.sleep(delay)
            return i

        t = api.task(work, name="work")
        [t(i, 0.02) for i in range(6)]
        straggler = t(99, 1.0)  # way beyond 2x median
        assert api.wait_on(straggler) == 99
        api.barrier()
        stats = api.current_runtime().stats()
        assert stats["speculative"] >= 1
    finally:
        api.runtime_stop(wait=False)


def test_dot_export_matches_paper_dag(rt):
    add = api.task(lambda x, y: x + y, name="add")
    r1, r2 = add(1, 2), add(3, 4)
    r3 = add(r1, r2)
    api.wait_on(r3)
    dot = api.current_runtime().graph.to_dot()
    assert "main" in dot and "sync" in dot
    assert dot.count("add") >= 3
    assert "d" in dot and "v" in dot  # dXvY edge labels


def test_tracer_utilization_and_gantt(rt):
    t = api.task(lambda: time.sleep(0.01), name="sleep")
    for _ in range(8):
        t()
    api.barrier()
    tr = api.current_runtime().tracer
    assert 0.0 < tr.utilization(4) <= 1.0
    g = tr.ascii_gantt(width=40)
    assert "w00" in g
    prv = tr.to_prv()
    assert prv.startswith("#Paraver")


def test_barrier_timeout(rt):
    t = api.task(lambda: time.sleep(1.0), name="slow", speculatable=False)
    t()
    with pytest.raises(TimeoutError):
        api.barrier(timeout=0.05)
