"""Pipelined dispatch plane (DESIGN.md §14).

Crash semantics with depth > 1 in flight, depth-1 equivalence with the
old stop-and-wait dispatch, batched submission (submit_many/map_tasks),
and the O(1) bookkeeping satellites (graph counters, queue_len, graph
retention).
"""
import os
import signal
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.dag import TaskGraph, TaskNode, TaskState
from repro.core.futures import ObjectStore
from repro.core.scheduler import Scheduler

BIG = 4096  # float64 elements — comfortably above the shm/wire floors


def _slow_crash_once(flag_path, value):
    """First run: linger so siblings pile up in the pipeline, then die
    taking the whole worker with us.  Retry: return normally."""
    if not os.path.exists(flag_path):
        with open(flag_path, "w") as f:
            f.write("x")
        time.sleep(0.4)
        os._exit(17)
    return np.arange(BIG, dtype=np.float64) * value


def _slow_kill_agent_once(flag_path, value):
    if not os.path.exists(flag_path):
        with open(flag_path, "w") as f:
            f.write("x")
        time.sleep(0.4)
        os.kill(os.getppid(), signal.SIGKILL)   # the node agent
    return np.arange(BIG, dtype=np.float64) * value


def _mul(value):
    return np.arange(BIG, dtype=np.float64) * value


def _thread_reference(values):
    api.runtime_start(n_workers=2, backend="thread")
    try:
        outs = api.map_tasks(api.task(_mul, name="mul"), [(v,) for v in values])
        return api.wait_on(outs)
    finally:
        api.runtime_stop()


# --------------------------------------------------- crash semantics, depth>1
def test_process_worker_crash_with_depth_inflight_retries_all(tmp_path):
    """SIGKILL-style worker death with depth tasks in flight: every one of
    them retries exactly once and the final results match the thread
    backend bitwise."""
    flag = str(tmp_path / "crash")
    values = [2, 3, 5, 7]
    rt = api.runtime_start(n_workers=1, backend="process", pipeline_depth=4,
                           max_retries=1)
    try:
        crash_t = api.task(_slow_crash_once, name="crash")
        mul_t = api.task(_mul, name="mul")
        f0 = crash_t(flag, values[0])
        rest = api.map_tasks(mul_t, [(v,) for v in values[1:]])
        outs = api.wait_on([f0, *rest], timeout=60)
        assert rt.executor.worker_restarts >= 1
        # every task that was in flight when the worker died ran exactly
        # twice (one crash-failed attempt + one successful retry)
        attempts = sorted(n.attempts for n in rt.graph.nodes())
        assert attempts == [2, 2, 2, 2], attempts
        assert rt.stats()["retries"] == 4
    finally:
        api.runtime_stop(wait=False)
    for got, want in zip(outs, _thread_reference(values)):
        np.testing.assert_array_equal(got, want)


def test_cluster_agent_crash_with_depth_inflight_retries_all(tmp_path):
    """SIGKILL a node agent with depth tasks streamed to its slot: all of
    them come back as retryable crashes, the agent respawns, and results
    match the thread backend bitwise."""
    flag = str(tmp_path / "agentcrash")
    values = [2, 3, 5, 7]
    rt = api.runtime_start(backend="cluster", n_agents=1, workers_per_node=1,
                           pipeline_depth=4, max_retries=1)
    try:
        crash_t = api.task(_slow_kill_agent_once, name="crash")
        mul_t = api.task(_mul, name="mul")
        f0 = crash_t(flag, values[0])
        rest = api.map_tasks(mul_t, [(v,) for v in values[1:]])
        outs = api.wait_on([f0, *rest], timeout=90)
        assert rt.executor.agent_restarts >= 1
        attempts = sorted(n.attempts for n in rt.graph.nodes())
        assert attempts == [2, 2, 2, 2], attempts
    finally:
        api.runtime_stop(wait=False)
    for got, want in zip(outs, _thread_reference(values)):
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------------- depth-1 equivalence
def test_depth1_process_matches_thread_bitwise():
    """A depth-1 pipeline is stop-and-wait: same results, same retry
    counts, same task accounting as the thread backend."""
    from repro.algorithms import kmeans

    results = {}
    for backend, depth in (("thread", 1), ("process", 1), ("process", 4)):
        api.runtime_start(n_workers=2, backend=backend, pipeline_depth=depth)
        try:
            res = kmeans.run_kmeans(n_points=4000, d=6, k=3, fragments=4,
                                    max_iters=3, seed=0)
            stats = api.current_runtime().stats()
            results[(backend, depth)] = (res, stats)
        finally:
            api.runtime_stop()
    ref, ref_stats = results[("thread", 1)]
    for key in (("process", 1), ("process", 4)):
        got, got_stats = results[key]
        np.testing.assert_array_equal(got.centroids, ref.centroids)
        assert got.sse == ref.sse
        assert got_stats["tasks_submitted"] == ref_stats["tasks_submitted"]
        assert got_stats["tasks_done"] == ref_stats["tasks_done"]
        assert got_stats["retries"] == ref_stats["retries"] == 0


def test_depth1_cluster_preserves_residency_ledger():
    """Depth 1 on the cluster backend keeps the send-once/reuse-many wire
    property exactly as before the pipeline existed."""
    rt = api.runtime_start(backend="cluster", n_agents=2, workers_per_node=1,
                           pipeline_depth=1)
    try:
        ex = rt.executor
        assert ex.pipeline_depth == 1
        gen = api.task(lambda n: np.ones(n), name="gen")
        tot = api.task(lambda a: float(np.sum(a)), name="tot")
        part = gen(BIG)
        api.wait_on(part)
        puts0, refs0 = ex.puts, ex.refs
        outs = [tot(part) for _ in range(10)]
        assert api.wait_on(outs) == [float(BIG)] * 10
        new_puts = ex.puts - puts0
        assert new_puts <= 1
        assert ex.refs - refs0 >= 10 - new_puts
        assert rt.stats()["retries"] == 0
    finally:
        api.runtime_stop(wait=False)


def test_descriptor_fast_path_used_for_keyed_ndarray_args():
    """The compact binary descriptor replaces the pickle frame once the
    function is cached and every argument is a planed keyed ndarray."""
    rt = api.runtime_start(n_workers=2, backend="process")
    try:
        gen = api.task(lambda n: np.ones(n), name="gen")
        dot = api.task(lambda a, b: float(a @ b), name="dot")
        x, y = gen(BIG), gen(BIG)
        api.wait_on([x, y])
        outs = [dot(x, y) for _ in range(6)]
        assert api.wait_on(outs) == [float(BIG)] * 6
        # each worker's first `dot` ships the fn body (pickle path); every
        # later all-keyed call rides the descriptor
        assert rt.executor.stats()["descriptor_sends"] >= 4
    finally:
        api.runtime_stop()


# -------------------------------------------------------- batched submission
def test_map_tasks_matches_loop_submission():
    api.runtime_start(n_workers=2)
    try:
        add = api.task(lambda x, y: x + y, name="add")
        batched = api.map_tasks(add, [(i, 2 * i) for i in range(20)])
        looped = [add(i, 2 * i) for i in range(20)]
        assert api.wait_on(batched) == api.wait_on(looped)
        # dependencies across a batch are discovered exactly like submit's
        chained = api.map_tasks(add, [(f, 1) for f in batched])
        assert api.wait_on(chained) == [3 * i + 1 for i in range(20)]
        # and TaskFunction.map is the same thing
        assert api.wait_on(add.map([(1, 2), (3, 4)])) == [3, 7]
    finally:
        api.runtime_stop()


def test_submit_many_multi_returns_and_empty():
    rt = api.runtime_start(n_workers=2)
    try:
        assert rt.submit_many(lambda: 1, []) == []
        pairs = rt.submit_many(lambda a: (a, -a), [(i,) for i in range(5)],
                               name="pair", returns=2)
        vals = api.wait_on(pairs)
        assert vals == [(i, -i) for i in range(5)]
    finally:
        api.runtime_stop()


# -------------------------------------------------- O(1) bookkeeping satellites
def test_stats_counters_match_graph_ground_truth():
    rt = api.runtime_start(n_workers=2, backend="thread")
    try:
        ok = api.task(lambda x: x, name="ok")
        boom = api.task(lambda: 1 / 0, name="boom", max_retries=0)
        api.wait_on(api.map_tasks(ok, [(i,) for i in range(9)]))
        b = boom()
        with pytest.raises(Exception):
            api.wait_on(b)
        api.barrier()
        s = rt.stats()
        nodes = rt.graph.nodes()
        assert s["tasks_submitted"] == len(nodes) == 10
        assert s["tasks_done"] == sum(n.state == TaskState.DONE for n in nodes)
        assert s["tasks_failed"] == 1
        assert s["retries"] == sum(max(0, n.attempts - 1) for n in nodes)
        assert s["total_work_s"] == pytest.approx(
            sum(n.duration for n in nodes if n.state == TaskState.DONE))
    finally:
        api.runtime_stop(wait=False)


def test_graph_retention_prunes_terminal_nodes_but_not_counters():
    rt = api.runtime_start(n_workers=2, backend="thread")
    try:
        rt.graph.retain = 8
        ok = api.task(lambda x: x * 2, name="ok")
        outs = api.map_tasks(ok, [(i,) for i in range(40)])
        assert api.wait_on(outs) == [2 * i for i in range(40)]
        api.barrier()
        assert len(rt.graph.nodes()) <= 8
        s = rt.stats()
        assert s["tasks_submitted"] == 40 and s["tasks_done"] == 40
        # late dependents of pruned producers still run (no ghost edges)
        late = ok(outs[0])
        assert api.wait_on(late) == 0
    finally:
        api.runtime_stop()


def test_graph_retain_env_knob():
    """RJAX_GRAPH_RETAIN is read at import time — verify in a clean
    interpreter (reloading the module in-process would re-mint the
    TaskState enum under live classes)."""
    import subprocess
    import sys

    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.core.dag import GRAPH_RETAIN, TaskGraph; "
         "print(GRAPH_RETAIN, TaskGraph().retain)"],
        env={**os.environ, "RJAX_GRAPH_RETAIN": "16",
             "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", "")},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=60)
    assert out.stdout.split() == ["16", "16"], (out.stdout, out.stderr)


def test_queue_len_is_incrementally_maintained():
    graph, store = TaskGraph(), ObjectStore()
    sched = Scheduler(graph, store, policy="worksteal")

    def add_ready():
        tid = graph.next_task_id()
        graph.add_task(TaskNode(task_id=tid, name=f"t{tid}", fn=lambda: None,
                                args=(), kwargs={}))
        return tid

    assert sched.queue_len() == 0
    a, b, c = add_ready(), add_ready(), add_ready()
    sched.push(a, preferred_worker=0)
    sched.push(b, preferred_worker=1)
    sched.push_many([c])
    assert sched.queue_len() == 3
    assert sched.take(2, timeout=0.1) == c     # global first
    assert sched.queue_len() == 2
    assert sched.take(0, timeout=0.1) == a     # own queue
    assert sched.take(2, timeout=0.1) == b     # steal
    assert sched.queue_len() == 0
    assert sched.take(2, timeout=0.05) is None
    assert sched.queue_len() == 0


def test_locality_cache_invalidated_by_residency_change():
    """The per-node placement cache must not serve stale scores after a
    datum's residency changes (note_location bumps the store epoch)."""
    graph, store = TaskGraph(), ObjectStore()
    sched = Scheduler(graph, store, policy="locality", workers_per_node=1)
    key_a, key_b = (store.new_data_id(), 1), (store.new_data_id(), 1)
    store.put(key_a, np.zeros(1 << 20, dtype=np.uint8), node=1)
    store.put(key_b, np.zeros(1 << 20, dtype=np.uint8), node=1)
    tids = []
    for key in (key_a, key_b):
        tid = graph.next_task_id()
        graph.add_task(TaskNode(task_id=tid, name=f"t{tid}", fn=lambda: None,
                                args=(), kwargs={}, dep_keys={key}))
        tids.append(tid)
    sched.push_many(tids)
    # warm node 0's cache: neither task is local there
    assert sched._select_locality(0) is not None
    sched._queue.appendleft(tids[0])  # put it back
    sched._qsize = 2
    # key_b's bytes move to node 0 → epoch bump → cache rebuilt → task b wins
    store.note_location(key_b, 0)
    assert sched.take(0, timeout=0.1) == tids[1]


def test_speculation_still_fires_with_indexed_scans():
    """The speculation monitor now reads the running index + duration
    history instead of scanning the graph; it must still clone a
    straggler."""
    import threading

    from repro.core.fault import SpeculationConfig
    from repro.core.runtime import Runtime

    gate = threading.Event()

    def maybe_slow(i):
        if i == 7:           # one straggler; its clone won't block
            gate.wait(timeout=20.0)
        return i

    rt = Runtime(n_workers=2, backend="thread",
                 speculation=SpeculationConfig(enabled=True, factor=2.0,
                                               min_seconds=0.05,
                                               poll_interval=0.05))
    api._runtime = rt   # route the api helpers at this runtime
    try:
        t = api.task(maybe_slow, name="maybe_slow")
        fast = api.map_tasks(t, [(i,) for i in range(7)])
        api.wait_on(fast)
        slow = t(7)
        deadline = time.time() + 10.0
        while time.time() < deadline and rt.stats()["speculative"] == 0:
            time.sleep(0.05)
        gate.set()
        assert api.wait_on(slow, timeout=20.0) == 7
        assert rt.stats()["speculative"] >= 1
    finally:
        gate.set()
        api.runtime_stop(wait=False)
        api._runtime = None


def _raise_on_unpickle():
    raise ValueError("boom on unpickle")


class _BadUnpickle:
    """Pickles fine, explodes when the worker deserializes it."""

    def __reduce__(self):
        return (_raise_on_unpickle, ())


def test_worker_side_unpickle_failure_costs_one_task_not_the_worker():
    """An argument that raises during worker-side deserialization must
    produce a per-task error reply — not kill the worker and drag its
    pipelined siblings into a crash/retry loop."""
    rt = api.runtime_start(n_workers=1, backend="process", pipeline_depth=4)
    try:
        ok = api.task(lambda x: x + 1, name="ok")
        bad = api.task(lambda o: o, name="bad", max_retries=0)
        good_before = ok(1)
        poisoned = bad(_BadUnpickle())
        good_after = ok(2)
        from repro.core.futures import TaskFailedError
        with pytest.raises(TaskFailedError) as exc_info:
            api.wait_on(poisoned, timeout=30)
        assert isinstance(exc_info.value.cause, ValueError)
        assert api.wait_on([good_before, good_after], timeout=30) == [2, 3]
        assert rt.executor.worker_restarts == 0
    finally:
        api.runtime_stop(wait=False)
