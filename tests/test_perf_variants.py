"""§Perf variant correctness: the optimization levers must not change the
computed function beyond dtype tolerance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import LMConfig, init_params, loss_fn

# minutes of JAX compile+run on CPU: opt-in via `-m slow` (see pytest.ini)
pytestmark = pytest.mark.slow



def tiny(**kw):
    base = dict(name="t", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab_size=97, attn_impl="chunked", attn_chunk=4)
    base.update(kw)
    return LMConfig(**base)


def batch_for(cfg, B=2, S=8):
    k = jax.random.PRNGKey(0)
    return {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((B, S)),
    }


@pytest.mark.parametrize("kw", [
    {},                                           # GQA
    {"n_kv_heads": 1},                            # MQA
    {"n_kv_heads": 4},                            # MHA
    {"qk_norm": True},
])
def test_bf16_scores_close_to_fp32(kw):
    cfg32 = tiny(**kw)
    cfg16 = dataclasses.replace(cfg32, attn_scores_bf16=True)
    params = init_params(cfg32, jax.random.PRNGKey(1))
    b = batch_for(cfg32)
    l32, _ = loss_fn(cfg32, params, b)
    l16, _ = loss_fn(cfg16, params, b)
    assert float(l32) == pytest.approx(float(l16), abs=3e-2)
    g32 = jax.grad(lambda p: loss_fn(cfg32, p, b)[0])(params)
    g16 = jax.grad(lambda p: loss_fn(cfg16, p, b)[0])(params)
    for a, c in zip(jax.tree.leaves(g32), jax.tree.leaves(g16)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=0.05)


def test_tp_gqa_kv_gather_mapping():
    """The replicated-KV TP branch gathers each local q head's *global*
    kv-group: verify the index arithmetic over every (H, K, tp) layout the
    assigned archs use."""
    cases = [
        (48, 1, 16),   # granite-20b MQA
        (16, 8, 16),   # qwen3-0.6b (kv not divisible by tp -> replicated)
        (32, 8, 16),   # granite-3-2b
        (16, 1, 16),   # recurrentgemma
        (64, 4, 16),   # qwen3-moe
        (8, 2, 4),     # the reduced-config regression case
    ]
    for H, K, tp in cases:
        if H % tp:
            continue
        G = H // K
        H_l = H // tp
        for d in range(tp):
            gidx = (d * H_l + np.arange(H_l)) // G
            expect = [(d * H_l + j) // G for j in range(H_l)]
            np.testing.assert_array_equal(gidx, expect)
            assert np.all(gidx < K)


def test_tp_block_requires_divisible_heads():
    """musicgen-style fallback: 24 heads on tp=16 must NOT take the TP path
    (the config guard in models.lm); verified by the loss being identical
    with and without the flag on a single device (where TP never engages)."""
    cfg = tiny(n_heads=4, n_kv_heads=4)
    cfg_tp = dataclasses.replace(cfg, tp_block="shard_map")
    params = init_params(cfg, jax.random.PRNGKey(1))
    b = batch_for(cfg)
    l0, _ = loss_fn(cfg, params, b)
    l1, _ = loss_fn(cfg_tp, params, b)  # mesh=None -> GSPMD path
    assert float(l0) == float(l1)


def test_ssm_chunk_is_a_pure_performance_knob():
    cfg_a = tiny(block_pattern=("ssd",), ssm_state=16, ssm_headdim=8,
                 ssm_chunk=8, n_heads=0, n_kv_heads=0, d_ff=0)
    cfg_b = dataclasses.replace(cfg_a, ssm_chunk=2)
    params = init_params(cfg_a, jax.random.PRNGKey(1))
    b = batch_for(cfg_a)
    la, _ = loss_fn(cfg_a, params, b)
    lb, _ = loss_fn(cfg_b, params, b)
    assert float(la) == pytest.approx(float(lb), abs=1e-5)
