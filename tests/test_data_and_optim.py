"""Data pipeline, optimizer, gradient compression."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import api
from repro.data.pipeline import DataPipeline, synth_batch
from repro.optim.adamw import adamw, clip_by_global_norm, cosine_schedule
from repro.optim.compress import compressed_gradients, init_error_feedback


def test_synth_batch_deterministic():
    cfg = get_config("qwen3-0.6b", reduced=True)
    a = synth_batch(cfg, 4, 16, step=3, seed=7)
    b = synth_batch(cfg, 4, 16, step=3, seed=7)
    c = synth_batch(cfg, 4, 16, step=4, seed=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert np.all(a["tokens"] >= 0) and np.all(a["tokens"] < cfg.vocab_size)
    # targets are next tokens
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])
    assert np.all(a["loss_mask"][:, -1] == 0)


def test_vlm_batch_masks_prefix():
    cfg = get_config("internvl2-26b", reduced=True)
    b = synth_batch(cfg, 2, 16, step=0)
    p = b["prefix_embeds"].shape[1]
    assert np.all(b["loss_mask"][:, :p] == 0)
    assert b["tokens"].shape[1] + p == 16


def test_pipeline_prefetch_with_runtime():
    cfg = get_config("qwen3-0.6b", reduced=True)
    api.runtime_start(n_workers=2)
    try:
        pipe = DataPipeline(cfg, 4, 16, prefetch_depth=2)
        b0 = pipe.get()
        b1 = pipe.get()
        direct = synth_batch(cfg, 4, 16, step=0)
        np.testing.assert_array_equal(b0["tokens"], direct["tokens"])
        assert not np.array_equal(b0["tokens"], b1["tokens"])
    finally:
        api.runtime_stop()


def test_adamw_reduces_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(120):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_bf16_moments():
    opt = adamw(0.01, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    params2, state2, _ = opt.update({"w": jnp.ones(4)}, state, params)
    assert state2.mu["w"].dtype == jnp.bfloat16
    assert jnp.all(jnp.isfinite(params2["w"]))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(55)) < float(lr(20))


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_compression_error_feedback_converges(codec):
    """EF accumulates what compression dropped; over steps the mean
    reconstructed gradient approaches the true gradient."""
    g_true = {"w": jnp.array([0.5, -0.25, 0.125, 1.0])}
    ef = init_error_feedback(g_true)
    acc = jnp.zeros(4)
    for _ in range(50):
        rec, ef = compressed_gradients(g_true, ef, codec=codec, topk_frac=0.25)
        acc = acc + rec["w"]
    mean = acc / 50
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g_true["w"]),
                               atol=0.05)


def test_int8_compression_bounded_error_single_step():
    g = {"w": jnp.linspace(-1, 1, 256)}
    rec, ef = compressed_gradients(g, None, codec="int8")
    err = float(jnp.max(jnp.abs(rec["w"] - g["w"])))
    assert err <= 1.0 / 127.0 + 1e-6
