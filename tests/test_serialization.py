"""Serialization codecs (paper §3.3.3 / Table 1 methodology)."""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.serialization import (
    MmapCodec,
    benchmark_codecs,
    deserialize,
    serialize,
)

DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8, np.uint16,
          np.float16]


@pytest.mark.parametrize("codec", ["pickle", "npy", "raw"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_roundtrip_array(codec, dtype):
    arr = (np.random.standard_normal((7, 13)) * 10).astype(dtype)
    out = deserialize(serialize(arr, codec), codec)
    np.testing.assert_array_equal(np.asarray(out), arr)


@pytest.mark.parametrize("codec", ["pickle", "npy", "raw"])
def test_roundtrip_non_array_falls_back(codec):
    obj = {"a": [1, 2, 3], "b": "hello"}
    assert deserialize(serialize(obj, codec), codec) == obj


def test_mmap_codec_zero_copy(tmp_path):
    arr = np.random.standard_normal((64, 64))
    mc = MmapCodec()
    p = str(tmp_path / "x.rjx")
    mc.ser_to_file(arr, p)
    view = mc.de_from_file(p)
    assert isinstance(view, np.memmap)
    np.testing.assert_array_equal(np.asarray(view), arr)


@settings(max_examples=25, deadline=None)
@given(
    shape=st.lists(st.integers(1, 8), min_size=1, max_size=3),
    dtype=st.sampled_from(["f4", "f8", "i4", "i8", "u1"]),
)
def test_raw_codec_roundtrip_property(shape, dtype):
    arr = np.random.standard_normal(tuple(shape)).astype(np.dtype(dtype))
    out = deserialize(serialize(arr, "raw"), "raw")
    np.testing.assert_array_equal(np.asarray(out), arr)
    assert out.dtype == arr.dtype and out.shape == arr.shape


def test_benchmark_codecs_table1_shape():
    res = benchmark_codecs(sizes=(64, 128), repeats=1)
    assert set(res) >= {"pickle", "npy", "raw", "mmap"}
    for codec, per_size in res.items():
        for size, (s, d) in per_size.items():
            assert s >= 0 and d >= 0


# ------------------------------------------------ raw codec: non-contiguous
@pytest.mark.parametrize("make", [
    lambda: np.array(3.5),                                      # 0-d
    lambda: np.asfortranarray(np.arange(12.0).reshape(3, 4)),   # F-order
    lambda: np.arange(64.0).reshape(8, 8)[:, ::2],              # strided view
    lambda: np.arange(60.0).reshape(3, 4, 5)[::2, 1:, ::-1],    # neg stride
], ids=["zero-d", "fortran", "strided", "negstride"])
def test_raw_codec_copy_on_encode_non_contiguous(make):
    """Sliced/transposed inputs must round-trip via copy-on-encode, not
    raise — they cross the wire as raw-codec frames in the cluster
    backend."""
    arr = make()
    out = deserialize(serialize(arr, "raw"), "raw")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape


# --------------------------------------------------- mmap codec: lifecycle
def test_mmap_owned_view_unlinks_file_on_gc(tmp_path):
    import gc

    arr = np.random.standard_normal((32, 32))
    mc = MmapCodec()
    p = str(tmp_path / "owned.rjx")
    mc.ser_to_file(arr, p)
    view = mc.de_from_file(p, owned=True)
    np.testing.assert_array_equal(np.asarray(view), arr)
    assert os.path.exists(p)        # pinned while the view lives
    del view
    gc.collect()
    assert not os.path.exists(p)    # cleanup tied to the returned object


def test_mmap_unowned_view_leaves_user_file_alone(tmp_path):
    import gc

    arr = np.ones((8, 8))
    mc = MmapCodec()
    p = str(tmp_path / "keep.rjx")
    mc.ser_to_file(arr, p)
    _view = mc.de_from_file(p)
    del _view
    gc.collect()
    assert os.path.exists(p)


def test_mmap_spill_roundtrip_does_not_accumulate_files(tmp_path):
    """Regression: deserialized memmap views used to pin their temp files
    with no unlink path, so the file count grew with every round trip."""
    import gc

    mc = MmapCodec()
    spill_dir = str(tmp_path)
    for i in range(10):
        arr = np.full((64, 64), float(i))
        view = mc.spill(arr, dir=spill_dir)
        assert isinstance(view, np.memmap)
        np.testing.assert_array_equal(np.asarray(view), arr)
        del view
    gc.collect()
    assert os.listdir(spill_dir) == []
