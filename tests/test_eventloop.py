"""The async control plane's event loop (DESIGN.md §18).

Unit level: :class:`AsyncAgentChannel` against a raw socketpair peer —
coalesced write batching, strict FIFO (the Put-before-Ref wire
invariant), partial-read reassembly, request/callback routing, failure
semantics.  Integration level: the scheduler side of a LocalCluster
runs O(1) threads regardless of agent count, and (slow-marked) a
64-agent cluster completes a fan-out DAG with heartbeats from every
node.
"""
import socket
import threading
import time

import pytest

from repro.cluster import protocol
from repro.cluster.eventloop import AsyncAgentChannel, IOLoop
from repro.cluster.protocol import ConnectionClosed, recv_msg, send_msg


# ---------------------------------------------------------------- harness
@pytest.fixture
def io():
    loop = IOLoop(name="test-io")
    yield loop
    loop.stop()


@pytest.fixture
def pair(io):
    """(channel, raw peer socket) over a socketpair."""
    a, b = socket.socketpair()
    ch = AsyncAgentChannel(a, node_id=0, hello={"op": "hello"}, io=io)
    yield ch, b
    ch.close()
    try:
        b.close()
    except OSError:
        pass


def _echo_server(sock, n, transform=None):
    """Reply to n requests, echoing the mid (the agent side's contract)."""
    for _ in range(n):
        meta, frames = recv_msg(sock)
        reply = {"op": "reply", "mid": meta.get("mid")}
        if transform:
            reply.update(transform(meta))
        send_msg(sock, reply)


# ------------------------------------------------------------- round trips
def test_request_roundtrip(pair):
    ch, peer = pair
    t = threading.Thread(target=_echo_server, args=(peer, 1), daemon=True)
    t.start()
    meta, frames = ch.request({"op": "ping"}, timeout=10)
    assert meta["op"] == "reply" and meta["mid"] == 1
    t.join()


def test_request_async_overlap(pair):
    ch, peer = pair
    t = threading.Thread(target=_echo_server, args=(peer, 8), daemon=True)
    t.start()
    waits = [ch.request_async({"op": "ping", "i": i}) for i in range(8)]
    mids = sorted(w(timeout=10)[0]["mid"] for w in waits)
    assert mids == list(range(1, 9))
    t.join()


def test_request_cb_called_exactly_once(pair):
    ch, peer = pair
    t = threading.Thread(target=_echo_server, args=(peer, 1), daemon=True)
    t.start()
    hits = []
    done = threading.Event()
    ch.request_cb({"op": "ping"}, (),
                  lambda meta, frames, err: (hits.append((meta, err)),
                                             done.set()))
    assert done.wait(10)
    time.sleep(0.05)
    assert len(hits) == 1 and hits[0][1] is None
    t.join()


def test_frames_cross_both_ways(pair):
    import numpy as np
    from repro.cluster.protocol import array_frame, frame_to_array
    ch, peer = pair
    arr = np.arange(2048, dtype=np.float64)

    def server():
        meta, frames = recv_msg(peer)
        got = frame_to_array(frames[0])
        send_msg(peer, {"op": "reply", "mid": meta["mid"]},
                 frames=[array_frame(got * 2)])

    t = threading.Thread(target=server, daemon=True)
    t.start()
    meta, frames = ch.request({"op": "mul"}, frames=[array_frame(arr)],
                              timeout=10)
    out = frame_to_array(frames[0])
    assert (out == arr * 2).all()
    t.join()


# --------------------------------------------------------------- batching
def test_posts_preserve_fifo_and_coalesce(pair):
    """N small posts enqueued while the loop is busy drain as a handful
    of coalesced socket writes — in exact enqueue order."""
    ch, peer = pair
    n = 50
    ch.post({"op": "warm"})          # forces the loop tasks to exist
    time.sleep(0.1)
    ch.io.call_soon(time.sleep, 0.3)  # hold the loop: posts pile up
    for i in range(n):
        ch.post({"op": "seq", "i": i})
    got = [recv_msg(peer)[0] for _ in range(n + 1)]
    assert [m["i"] for m in got[1:]] == list(range(n))
    assert ch.msgs_sent == n + 1
    # the pile-up drained in far fewer writes than messages
    assert ch.writes <= 1 + n // 4, (ch.writes, ch.msgs_sent)


def test_put_before_ref_order_under_interleaved_writers(pair):
    """Concurrent enqueuers: each writer's own Put→Ref sequence arrives
    in its enqueue order (the §12 wire-FIFO invariant the executor's
    per-agent order locks rely on)."""
    ch, peer = pair
    writers, per = 4, 25
    total = writers * per

    def writer(w):
        for i in range(per):
            ch.post({"op": "put" if i % 2 == 0 else "ref", "w": w, "i": i})

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(writers)]
    for t in threads:
        t.start()
    seen = {w: [] for w in range(writers)}
    for _ in range(total):
        m = recv_msg(peer)[0]
        seen[m["w"]].append(m["i"])
    for t in threads:
        t.join()
    for w in range(writers):
        assert seen[w] == list(range(per)), f"writer {w} reordered"


def test_large_message_bypasses_coalescing(pair):
    """A message above the coalesce cutover is written per-part (no
    giant batch buffer) but still lands in FIFO position."""
    import numpy as np
    from repro.cluster.protocol import array_frame, frame_to_array
    ch, peer = pair
    big = np.arange(protocol.WIRE_COALESCE_MAX, dtype=np.uint8)
    ch.post({"op": "small", "i": 0})
    ch.post({"op": "big"}, frames=[array_frame(big)])
    ch.post({"op": "small", "i": 1})
    metas = []
    for _ in range(3):
        meta, frames = recv_msg(peer)
        metas.append(meta["op"])
        if meta["op"] == "big":
            assert (frame_to_array(frames[0]) == big).all()
    assert metas == ["small", "big", "small"]


# ----------------------------------------------------------- partial reads
def test_trickled_reply_is_reassembled(pair):
    """The reply arrives one byte at a time: the loop's exact-read path
    must reassemble header, lengths, meta and frames correctly."""
    import io as _io
    ch, peer = pair

    def server():
        meta, _ = recv_msg(peer)
        buf = _io.BytesIO()
        send_msg(_FakeSock(buf), {"op": "reply", "mid": meta["mid"],
                                  "payload": "x" * 3000})
        blob = buf.getvalue()
        for i in range(0, len(blob), 7):       # drip-feed 7-byte chunks
            peer.sendall(blob[i:i + 7])
            time.sleep(0.0005)

    class _FakeSock:
        def __init__(self, buf):
            self.buf = buf

        def sendall(self, b):
            self.buf.write(b)

    t = threading.Thread(target=server, daemon=True)
    t.start()
    meta, _ = ch.request({"op": "ping"}, timeout=30)
    assert meta["payload"] == "x" * 3000
    t.join()


# ------------------------------------------------------------ failure paths
def test_peer_close_fails_pending_requests(pair):
    ch, peer = pair
    w = ch.request_async({"op": "never-answered"})
    closed = threading.Event()
    ch.on_close = closed.set
    peer.close()
    with pytest.raises(ConnectionClosed):
        w(timeout=10)
    assert closed.wait(10)
    assert ch.closed
    with pytest.raises(ConnectionClosed):
        ch.post({"op": "late"})


def test_close_fails_callbacks_with_error(pair):
    ch, peer = pair
    t = threading.Thread(target=_echo_server, args=(peer, 1), daemon=True)
    t.start()
    ch.request({"op": "warm"}, timeout=10)    # channel fully up
    errs = []
    done = threading.Event()
    ch.request_cb({"op": "doomed"}, (),
                  lambda meta, frames, err: (errs.append(err), done.set()))
    ch.close()
    assert done.wait(10)
    assert isinstance(errs[0], ConnectionClosed)
    t.join()


def test_request_timeout_names_the_op(pair):
    ch, peer = pair
    with pytest.raises(TimeoutError, match="silent"):
        ch.request({"op": "silent"}, timeout=0.2)


def test_ioloop_stop_is_idempotent():
    loop = IOLoop(name="idem-io")
    loop.stop()
    loop.stop()
    assert not loop.call_soon(lambda: None)   # dead loop refuses work


# ------------------------------------------------- scheduler thread budget
def _run_cluster_count_threads(n_agents):
    from repro.core import api

    def bump(x):
        return x + 1

    with api.runtime_start(backend="cluster", n_agents=n_agents,
                           workers_per_node=1, tracing=False):
        t = api.task(bump)
        futs = [t(i) for i in range(n_agents * 3)]
        api.barrier()
        mid_run = threading.active_count()
        assert sorted(api.wait_on(futs)) == sorted(
            i + 1 for i in range(n_agents * 3))
    return mid_run


def test_scheduler_thread_count_is_flat_in_agent_count():
    """The tentpole regression guard: scheduler-side threads must not
    scale with agents (legacy: reader-thread/agent + dispatcher/slot)."""
    at2 = _run_cluster_count_threads(2)
    at4 = _run_cluster_count_threads(4)
    # identical budget, small tolerance for transient helper threads
    # (a recovery-pool worker, a telemetry timer) racing the sample
    assert at4 <= at2 + 1, (at2, at4)


# -------------------------------------------------------- 64-agent smoke
@pytest.mark.slow
def test_sixty_four_agent_smoke():
    """One scheduler, 64 agents: register, heartbeat, run a fan-out +
    reduce DAG, all on a single event-loop thread."""
    from repro.core import api

    def leaf(i):
        return i

    n = 64
    with api.runtime_start(backend="cluster", n_agents=n,
                           workers_per_node=1) as rt:
        t = api.task(leaf)
        futs = [t(i) for i in range(n * 2)]
        api.barrier(timeout=300)
        assert sorted(api.wait_on(futs)) == sorted(list(range(n * 2)) )
        # every node's heartbeat reached the telemetry plane via on_push
        deadline = time.time() + 60
        while time.time() < deadline:
            hb = rt.telemetry.nodes()
            if len(hb) >= n:
                break
            time.sleep(0.5)
        assert len(hb) >= n
        # O(1) scheduler threads even at 64 agents
        assert threading.active_count() < 16, sorted(
            th.name for th in threading.enumerate())
