"""Live telemetry plane (DESIGN.md §17): the task-lifecycle ring,
tracer hardening + Chrome-trace export, heartbeats on a live
LocalCluster, dashboard endpoints, stats-schema parity, and the
node×node transfer matrix."""
import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core import api
from repro.core.futures import ObjectStore, RemoteValue
from repro.core.telemetry import (
    EXECUTOR_STAT_KEYS,
    TelemetryHub,
    heartbeat_interval,
    normalize_executor_stats,
)
from repro.core.tracing import TaskStream, TraceEvent, Tracer


def _get_json(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        assert resp.status == 200
        return json.loads(resp.read())


# ---------------------------------------------------------------- TaskStream
class TestTaskStream:
    def test_seq_and_since(self):
        s = TaskStream(capacity=16)
        for i in range(5):
            s.append("submit", task=i)
        assert s.last_seq == 5
        evs = s.since(0)
        assert [e["seq"] for e in evs] == [1, 2, 3, 4, 5]
        assert all(e["kind"] == "submit" for e in evs)
        # watermark semantics: strictly greater
        assert [e["seq"] for e in s.since(3)] == [4, 5]
        assert s.since(5) == []

    def test_eviction_and_dropped(self):
        s = TaskStream(capacity=8)
        for i in range(20):
            s.append("dispatch", task=i)
        assert len(s) == 8
        assert s.dropped == 12
        evs = s.since(0)
        # only the newest `capacity` events survive, in order
        assert [e["task"] for e in evs] == list(range(12, 20))
        assert s.last_seq == 20

    def test_limit_returns_newest(self):
        s = TaskStream(capacity=64)
        for i in range(10):
            s.append("done", task=i)
        evs = s.since(0, limit=3)
        assert [e["task"] for e in evs] == [7, 8, 9]

    def test_extend_batches(self):
        s = TaskStream(capacity=64)
        s.extend("submit", [{"task": i} for i in range(4)])
        assert s.last_seq == 4
        assert [e["task"] for e in s.since(0)] == [0, 1, 2, 3]

    def test_concurrent_appends_keep_unique_seqs(self):
        s = TaskStream(capacity=4096)

        def hammer():
            for i in range(500):
                s.append("done", task=i)

        threads = [threading.Thread(target=hammer) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        seqs = [e["seq"] for e in s.since(0)]
        assert len(seqs) == len(set(seqs)) == 2000
        assert s.last_seq == 2000


# ------------------------------------------------------------ tracer exports
class TestTracerHardening:
    def _tracer_with(self, events):
        tr = Tracer(enabled=True)
        for e in events:
            tr.record(e)
        return tr

    def test_record_thread_safe(self):
        tr = Tracer(enabled=True)

        def hammer(w):
            for i in range(400):
                t = tr.t_start + i * 1e-6
                tr.record(TraceEvent("task", "f", w, 0, t, t + 1e-6,
                                     task_id=i))

        threads = [threading.Thread(target=hammer, args=(w,))
                   for w in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(tr.events("task")) == 8 * 400

    def test_prv_zero_duration_and_out_of_order(self):
        t0 = time.perf_counter()
        tr = self._tracer_with([
            # out of submission order, one zero-duration, one skewed
            TraceEvent("task", "b", 1, 0, t0 + 2e-3, t0 + 2e-3),
            TraceEvent("task", "a", 0, 0, t0 + 1e-3, t0 + 3e-3),
            TraceEvent("task", "c", 0, 0, t0 - 1e-3, t0 - 2e-3),
        ])
        tr.t_start = t0
        lines = tr.to_prv().splitlines()
        assert lines[0].startswith("#Paraver")
        recs = [ln.split(":") for ln in lines[1:]]
        starts = [int(r[5]) for r in recs]
        ends = [int(r[6]) for r in recs]
        assert starts == sorted(starts)          # ordered records
        assert all(e >= s >= 0 for s, e in zip(starts, ends))

    def test_ascii_gantt_degenerate_events(self):
        t0 = time.perf_counter()
        tr = self._tracer_with([
            TraceEvent("task", "z", 0, 0, t0, t0),          # zero duration
            TraceEvent("task", "z", 1, 0, t0 + 1e-3, t0),   # negative span
        ])
        out = tr.ascii_gantt(width=2)   # width clamp path too
        assert "w000" in out and "w001" in out

    def test_ascii_gantt_single_instant(self):
        # every event at the same instant: span would be zero
        t0 = time.perf_counter()
        tr = self._tracer_with(
            [TraceEvent("task", "f", w, 0, t0, t0) for w in range(3)])
        assert "(empty trace)" not in tr.ascii_gantt()

    def test_chrome_trace_round_trips_event_count(self):
        t0 = time.perf_counter()
        tr = self._tracer_with([
            TraceEvent("task", f"f{i}", i % 2, i % 3, t0 + i * 1e-4,
                       t0 + i * 1e-4 + 5e-5, task_id=i,
                       meta={"ok": True, "arr": np.zeros(2)})
            for i in range(10)
        ])
        doc = json.loads(tr.to_chrome_trace())   # valid JSON by parse
        assert doc["displayTimeUnit"] == "ms"
        complete = [r for r in doc["traceEvents"] if r["ph"] == "X"]
        assert len(complete) == len(tr.events())
        for r in complete:
            assert r["ts"] >= 0 and r["dur"] >= 0
            assert isinstance(r["pid"], int) and isinstance(r["tid"], int)
            assert "arr" not in r["args"]        # non-scalar meta filtered
            assert r["args"]["ok"] is True
        meta_recs = [r for r in doc["traceEvents"] if r["ph"] == "M"]
        assert {r["name"] for r in meta_recs} == {"process_name",
                                                  "thread_name"}

    def test_chrome_trace_from_live_run(self):
        api.runtime_start(n_workers=2, backend="thread")
        try:
            sq = api.task(lambda x: x * x, name="sq")
            assert api.wait_on([sq(i) for i in range(8)]) == \
                [i * i for i in range(8)]
            rt = api.current_runtime()
            doc = json.loads(rt.tracer.to_chrome_trace())
            xs = [r for r in doc["traceEvents"] if r["ph"] == "X"
                  and r["cat"] == "task"]
            assert len(xs) == len(rt.tracer.events("task")) == 8
        finally:
            api.runtime_stop()


# ----------------------------------------------------------------- the hub
class TestTelemetryHub:
    def test_heartbeat_latest_wins(self):
        hub = TelemetryHub()
        hub.note_heartbeat(0, {"plane_bytes": 1})
        hub.note_heartbeat(0, {"plane_bytes": 2})
        hub.note_heartbeat(1, {"plane_bytes": 9})
        nodes = hub.nodes()
        assert nodes[0]["count"] == 2
        assert nodes[0]["payload"] == {"plane_bytes": 2}
        assert nodes[1]["count"] == 1

    def test_inflight_balances(self):
        hub = TelemetryHub()
        t = time.perf_counter()
        hub.note_dispatch(1, "f", 0, 0, t)
        hub.note_dispatch(2, "f", 1, 0, t)
        assert hub.inflight() == {0: 2}
        hub.note_task(1, "f", 0, 0, t, t, t + 1e-3, ok=True, retried=False)
        assert hub.inflight() == {0: 1}
        hub.note_task(2, "f", 1, 0, t, None, t + 1e-3, ok=False,
                      retried=False)
        assert hub.inflight() == {}
        kinds = [e["kind"] for e in hub.stream.since(0)]
        assert kinds == ["dispatch", "dispatch", "done", "fail"]

    def test_heartbeat_interval_precedence(self, monkeypatch):
        monkeypatch.delenv("RJAX_HEARTBEAT_S", raising=False)
        assert heartbeat_interval(None) == 1.0
        assert heartbeat_interval(0.25) == 0.25
        assert heartbeat_interval(0) == 0.0          # welcome disables
        monkeypatch.setenv("RJAX_HEARTBEAT_S", "0.5")
        assert heartbeat_interval(0.25) == 0.5       # env wins
        monkeypatch.setenv("RJAX_HEARTBEAT_S", "0")
        assert heartbeat_interval(0.25) == 0.0       # env "0" disables
        monkeypatch.setenv("RJAX_HEARTBEAT_S", "bogus")
        assert heartbeat_interval(0.25) == 0.25      # bad env falls through

    def test_in_process_sampler_process_backend(self):
        rt = api.runtime_start(n_workers=2, backend="process",
                               telemetry=True)
        try:
            rt.telemetry.sample_local(rt)   # deterministic tick
            nodes = rt.telemetry.nodes()
            assert "local" in nodes
            payload = nodes["local"]["payload"]
            assert payload["backend"] == "process"
            assert "store_bytes_used" in payload   # memory-ledger gauge
        finally:
            api.runtime_stop()


# --------------------------------------------------------- stats key parity
class TestStatsParity:
    def test_normalize_fills_missing_keys(self):
        out = normalize_executor_stats({"backend": "thread"})
        for k in EXECUTOR_STAT_KEYS:
            assert out[k] == 0
        assert out["p2p"] is False and out["backend"] == "thread"

    @pytest.mark.parametrize("backend,kw", [
        ("thread", {}),
        ("process", {}),
        ("cluster", {"n_agents": 2, "workers_per_node": 1}),
    ])
    def test_runtime_stats_uniform_schema(self, backend, kw):
        api.runtime_start(n_workers=2, backend=backend, **kw)
        try:
            ex = api.runtime_stats()["executor"]
        finally:
            api.runtime_stop()
        expected = set(EXECUTOR_STAT_KEYS) | {"backend", "p2p"}
        assert expected <= set(ex.keys()), \
            f"{backend} missing {expected - set(ex.keys())}"

    def test_key_parity_across_backends(self):
        keysets = {}
        for backend, kw in [("thread", {}), ("process", {}),
                            ("cluster", {"n_agents": 2,
                                         "workers_per_node": 1})]:
            api.runtime_start(n_workers=2, backend=backend, **kw)
            try:
                keysets[backend] = frozenset(
                    api.runtime_stats()["executor"])
            finally:
                api.runtime_stop()
        assert keysets["thread"] == keysets["process"] == keysets["cluster"]


# --------------------------------------------------------- transfer matrix
class TestTransferMatrix:
    def test_relay_and_p2p_attribution(self):
        st = ObjectStore()
        k1, k2 = (1, 1), (2, 1)
        st.put(k1, np.zeros(128), node=0)          # resident on node 0
        st.note_location(k1, 1)                    # pulled by node 1: relay
        st.put(k2, RemoteValue(token=7, node=2, addr="h:1", nbytes=1024),
               node=2)
        st.note_location(k2, 0, source=2)          # explicit peer source
        rows = {(e["src"], e["dst"]): e["bytes"] for e in st.transfer_matrix()}
        assert rows == {(-1, 1): 1024, (2, 0): 1024}
        d = st.transfer_detail()
        assert sum(b for (s, _), b in rows.items() if s >= 0) == d["p2p_bytes"]
        assert sum(b for (s, _), b in rows.items() if s < 0) == \
            d["scheduler_relay_bytes"]
        assert d["matrix"] == st.transfer_matrix()

    def test_reattribute_moves_matrix_cell(self):
        st = ObjectStore()
        k = (1, 1)
        st.put(k, np.zeros(128), node=0)
        st.note_location(k, 1)                     # booked as relay first
        st.reattribute_to_p2p(k, 0, dest=1)        # transport was p2p
        rows = {(e["src"], e["dst"]): e["bytes"] for e in st.transfer_matrix()}
        assert rows == {(0, 1): 1024}
        d = st.transfer_detail()
        assert d["scheduler_relay_bytes"] == 0
        assert d["p2p_bytes"] == 1024


# ------------------------------------------------ live cluster + dashboard
@pytest.fixture(scope="module")
def dash_rt():
    from repro.cluster import LocalCluster
    cluster = LocalCluster(n_agents=3, workers_per_node=1)
    cluster.heartbeat_s = 0.2   # fast beats for the test
    r = api.runtime_start(backend="cluster", cluster=cluster,
                          dashboard_port=0)
    yield r
    api.runtime_stop(wait=False)


class TestLiveDashboard:
    def _run_some_tasks(self):
        gen = api.task(
            lambda s, n: np.random.default_rng(s).standard_normal(n),
            name="gen")
        tot = api.task(lambda a, b: float(np.sum(a) + np.sum(b)),
                       name="tot")
        frags = [gen(i, 4096) for i in range(6)]
        outs = [tot(frags[i], frags[(i + 1) % 6]) for i in range(6)]
        api.wait_on(outs)

    def test_heartbeats_arrive_from_every_agent(self, dash_rt):
        self._run_some_tasks()
        deadline = time.time() + 10
        while time.time() < deadline:
            nodes = dash_rt.telemetry.nodes()
            if len(nodes) == 3 and all(e["count"] >= 2
                                       for e in nodes.values()):
                break
            time.sleep(0.1)
        nodes = dash_rt.telemetry.nodes()
        assert sorted(nodes) == [0, 1, 2]
        for ent in nodes.values():
            assert ent["count"] >= 2                  # periodic, not one-shot
            payload = ent["payload"]
            assert "plane_entries" in payload         # node-plane ledger
            assert "p2p_fetches" in payload           # p2p ledger
            assert "queued" in payload                # credit depth

    def test_api_status(self, dash_rt):
        st = _get_json(dash_rt.dashboard.url + "api/status")
        assert st["backend"] == "cluster"
        assert st["n_workers"] == 3
        assert sorted(st["nodes"]) == ["0", "1", "2"]
        for n in st["nodes"].values():
            assert n["heartbeats"] >= 1
            assert "plane_bytes" in n                 # memory gauge source
        assert st["ring"]["seq"] > 0

    def test_api_tasks_streams_ring(self, dash_rt):
        self._run_some_tasks()
        tk = _get_json(dash_rt.dashboard.url + "api/tasks?since=0")
        kinds = {e["kind"] for e in tk["events"]}
        assert {"submit", "dispatch", "done"} <= kinds
        assert tk["last_seq"] == dash_rt.telemetry.stream.last_seq
        # incremental polling: nothing new past the watermark
        again = _get_json(dash_rt.dashboard.url +
                          f"api/tasks?since={tk['last_seq']}")
        assert again["events"] == []
        done = [e for e in tk["events"] if e["kind"] == "done"]
        assert all(e["t1"] >= e["t0"] for e in done)
        # fetch/stall gap is derivable: t_run recorded for clean runs
        assert any(e.get("t_run") is not None for e in done)

    def test_api_transfers_matches_ledger(self, dash_rt):
        self._run_some_tasks()
        tr = _get_json(dash_rt.dashboard.url + "api/transfers")
        d = dash_rt.store.transfer_detail()
        assert tr["p2p_bytes"] == d["p2p_bytes"]
        assert tr["scheduler_relay_bytes"] == d["scheduler_relay_bytes"]
        mat_p2p = sum(e["bytes"] for e in tr["matrix"] if e["src"] >= 0)
        mat_relay = sum(e["bytes"] for e in tr["matrix"] if e["src"] < 0)
        assert mat_p2p == tr["p2p_bytes"]
        assert mat_relay == tr["scheduler_relay_bytes"]
        # ring traffic between 3 nodes: the matrix must show real p2p cells
        assert mat_p2p > 0

    def test_api_trace_and_page(self, dash_rt):
        doc = _get_json(dash_rt.dashboard.url + "api/trace")
        assert len([r for r in doc["traceEvents"] if r["ph"] == "X"]) > 0
        with urllib.request.urlopen(dash_rt.dashboard.url,
                                    timeout=10) as resp:
            assert resp.status == 200
            assert b"Task stream" in resp.read()

    def test_api_trace_404(self, dash_rt):
        import urllib.error
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(dash_rt.dashboard.url + "nope",
                                   timeout=10)
