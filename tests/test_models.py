"""Model-layer behaviour: family forward/grad, prefill+decode consistency,
remat equivalence, MoE dispatch vs dense reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import moe, rglru
from repro.models.lm import LMConfig, forward, init_params, loss_fn

# minutes of JAX compile+run on CPU: opt-in via `-m slow` (see pytest.ini)
pytestmark = pytest.mark.slow



def tiny(name, **kw):
    base = dict(name=name, n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab_size=97, cache_dtype=jnp.float32)
    base.update(kw)
    return LMConfig(**base)


FAMILIES = {
    "dense": tiny("dense"),
    "mqa_qknorm": tiny("mqa", n_kv_heads=1, qk_norm=True),
    "gelu": tiny("gelu", mlp_gated=False),
    "moe": tiny("moe", block_pattern=("moe",), n_experts=8, top_k=2,
                d_ff_expert=16, n_shared_experts=2, moe_capacity_factor=4.0),
    "ssd": tiny("ssd", block_pattern=("ssd",), ssm_state=16, ssm_headdim=8,
                ssm_chunk=4),
    "hybrid": tiny("hybrid", n_layers=7,
                   block_pattern=("rglru", "rglru", "local_attn"),
                   rnn_width=32, local_window=4),
    "vlm": tiny("vlm", input_mode="prefix_embeds", prefix_len=3),
    "audio": tiny("audio", input_mode="embeds", vocab_size=64),
}


def make_batch(cfg, B=2, S=8, key=jax.random.PRNGKey(0)):
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    elif cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        batch["prefix_embeds"] = jax.random.normal(key, (B, cfg.prefix_len,
                                                         cfg.d_model))
        batch["tokens"] = jax.random.randint(key, (B, S - cfg.prefix_len), 0,
                                             cfg.vocab_size)
    batch["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch["loss_mask"] = jnp.ones((B, S))
    return batch


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_train_grad_finite(fam):
    cfg = FAMILIES[fam]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_prefill_decode_matches_full_forward(fam):
    cfg = FAMILIES[fam]
    B, S = 2, 8
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)
    logits_full, _, _ = forward(cfg, params, batch)
    if cfg.input_mode == "prefix_embeds":
        pre = {"prefix_embeds": batch["prefix_embeds"],
               "tokens": batch["tokens"][:, :-1]}
        dec = {"tokens": batch["tokens"][:, -1:]}
    elif cfg.input_mode == "embeds":
        pre = {"embeds": batch["embeds"][:, :S - 1]}
        dec = {"embeds": batch["embeds"][:, -1:]}
    else:
        pre = {"tokens": batch["tokens"][:, :S - 1]}
        dec = {"tokens": batch["tokens"][:, -1:]}
    logits_pre, caches, _ = forward(cfg, params, pre, make_cache_len=S + 2)
    logits_dec, _, _ = forward(cfg, params, dec, caches=caches,
                               pos_offset=S - 1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1:]), atol=5e-4)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, :S - 1]), atol=5e-4)


@pytest.mark.parametrize("fam", ["dense", "moe", "ssd", "hybrid"])
@pytest.mark.parametrize("remat", ["full", "dots"])
def test_remat_equivalence(fam, remat):
    cfg = FAMILIES[fam]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    l0, _ = loss_fn(cfg, params, batch, remat="none")
    l1, _ = loss_fn(cfg, params, batch, remat=remat)
    assert float(l0) == pytest.approx(float(l1), abs=1e-5)


@pytest.mark.parametrize("fam", ["dense", "ssd", "hybrid"])
def test_unrolled_equals_scanned(fam):
    """The dry-run probe path computes the same function."""
    cfg = FAMILIES[fam]
    cfg_u = dataclasses.replace(cfg, scan_layers=False, unroll_scans=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    l0, _ = loss_fn(cfg, params, batch)
    l1, _ = loss_fn(cfg_u, params, batch)
    assert float(l0) == pytest.approx(float(l1), abs=1e-5)


def test_moe_dispatch_matches_dense_reference():
    p = moe.init_moe(jax.random.PRNGKey(0), 16, 32, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    out_d, aux_d = moe.moe_apply_local(p, x, top_k=2, capacity_factor=8.0)
    out_r, aux_r = moe.moe_reference(p, x, top_k=2)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_r), atol=1e-5)
    assert float(aux_d) == pytest.approx(float(aux_r))


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 some tokens drop; output stays finite and within the
    convex hull scale of the no-drop output."""
    p = moe.init_moe(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    out_tight, _ = moe.moe_apply_local(p, x, top_k=2, capacity_factor=1.0)
    out_loose, _ = moe.moe_apply_local(p, x, top_k=2, capacity_factor=8.0)
    assert jnp.all(jnp.isfinite(out_tight))
    assert float(jnp.linalg.norm(out_tight)) <= float(
        jnp.linalg.norm(out_loose)) * 1.5 + 1e-3


def test_moe_grads_flow_to_router_and_experts():
    p = moe.init_moe(jax.random.PRNGKey(0), 16, 32, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))

    def loss(p):
        out, aux = moe.moe_apply_local(p, x, top_k=2, capacity_factor=8.0)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["w_router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_gate"]))) > 0


def test_ssd_chunk_invariance():
    """Different chunk sizes compute the same function."""
    cfg8 = tiny("s8", block_pattern=("ssd",), ssm_state=16, ssm_headdim=8,
                ssm_chunk=8)
    cfg4 = dataclasses.replace(cfg8, ssm_chunk=4)
    params = init_params(cfg8, jax.random.PRNGKey(0))
    batch = make_batch(cfg8)
    l8, _ = loss_fn(cfg8, params, batch)
    l4, _ = loss_fn(cfg4, params, batch)
    assert float(l8) == pytest.approx(float(l4), abs=1e-5)


def test_rglru_state_continuation():
    """Scanning a sequence in two halves with carried state == one scan."""
    p = rglru.init_rglru(jax.random.PRNGKey(0), 16, 24)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 24))
    y_full, h_full = rglru.rglru_scan(p, u)
    y1, h1 = rglru.rglru_scan(p, u[:, :6])
    y2, h2 = rglru.rglru_scan(p, u[:, 6:], h0=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-5)


def test_loss_mask_zeroes_positions():
    cfg = FAMILIES["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    batch["loss_mask"] = jnp.zeros_like(batch["loss_mask"]).at[:, 0].set(1.0)
    loss_masked, m = loss_fn(cfg, params, batch)
    assert float(m["tokens"]) == 2.0
