"""Scheduler policy unit tests (paper §3.1).

The ``locality`` selection does a ``rotate(-i)/popleft/rotate(i)`` dance
to extract the best-scoring task from a bounded window — the property
worth pinning is that every *non-selected* task keeps its queue position.
``worksteal`` must steal FIFO (oldest first) from the longest victim
queue while owners pop LIFO.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dag import TaskGraph, TaskNode
from repro.core.futures import ObjectStore
from repro.core.scheduler import Scheduler


def _mk_sched(policy, workers_per_node=1):
    graph = TaskGraph()
    store = ObjectStore()
    return Scheduler(graph, store, policy=policy,
                     workers_per_node=workers_per_node), graph, store


def _add_task(graph, store, dep_nbytes_by_node):
    """One task whose inputs live on the given nodes with given sizes.
    ``dep_nbytes_by_node``: list of (node, nbytes)."""
    tid = graph.next_task_id()
    dep_keys = set()
    for node, nbytes in dep_nbytes_by_node:
        did = store.new_data_id()
        key = (did, 1)
        store.put(key, np.zeros(max(0, nbytes), dtype=np.uint8), node=node)
        dep_keys.add(key)
    node = TaskNode(task_id=tid, name=f"t{tid}", fn=lambda: None, args=(),
                    kwargs={}, dep_keys=dep_keys, out_keys=[])
    graph.add_task(node)
    return tid


# ------------------------------------------------------------------ locality
def test_locality_prefers_resident_bytes():
    sched, graph, store = _mk_sched("locality")
    # task A: 1 MiB on node 0; task B: 1 MiB on node 1
    a = _add_task(graph, store, [(0, 1 << 20)])
    b = _add_task(graph, store, [(1, 1 << 20)])
    sched.push_many([a, b])
    assert sched.take(1, timeout=0.1) == b   # worker 1 -> node 1
    assert sched.take(0, timeout=0.1) == a


def test_locality_scores_by_bytes_not_input_count():
    sched, graph, store = _mk_sched("locality")
    # A has 2 small inputs on node 0 (2 KiB); B has 1 big input on node 0
    # (1 MiB) and 2 small ones elsewhere: byte-weighting must pick B
    a = _add_task(graph, store, [(1, 1 << 19), (0, 1024), (0, 1024)])
    b = _add_task(graph, store, [(0, 1 << 20), (1, 1024), (1, 1024)])
    sched.push_many([a, b])
    assert sched.take(0, timeout=0.1) == b


@settings(max_examples=30, deadline=None)
@given(data=st.data(), n=st.integers(2, 20))
def test_locality_window_preserves_order_of_nonselected(data, n):
    """Property: after one take, the queue equals the original sequence
    minus the selected element, in the original order."""
    sched, graph, store = _mk_sched("locality", workers_per_node=1)
    tids = []
    for _ in range(n):
        node = data.draw(st.integers(0, 2))
        nbytes = data.draw(st.integers(0, 4096))
        tids.append(_add_task(graph, store, [(node, nbytes)]))
    sched.push_many(tids)
    worker = data.draw(st.integers(0, 2))
    picked = sched.take(worker, timeout=0.1)
    assert picked in tids
    remaining = [t for t in tids if t != picked]
    assert list(sched._queue) == remaining


def test_locality_empty_deps_score_zero_and_still_run():
    sched, graph, store = _mk_sched("locality")
    a = _add_task(graph, store, [])
    sched.push_many([a])
    assert sched.take(0, timeout=0.1) == a


# ----------------------------------------------------------------- worksteal
def test_worksteal_owner_pops_lifo():
    sched, graph, store = _mk_sched("worksteal")
    t1, t2, t3 = (_add_task(graph, store, []) for _ in range(3))
    for t in (t1, t2, t3):
        sched.push(t, preferred_worker=0)
    assert sched.take(0, timeout=0.1) == t3  # hottest last-pushed first


def test_worksteal_thief_steals_fifo_from_longest_victim():
    sched, graph, store = _mk_sched("worksteal")
    short = [_add_task(graph, store, []) for _ in range(2)]
    long = [_add_task(graph, store, []) for _ in range(5)]
    for t in short:
        sched.push(t, preferred_worker=0)
    for t in long:
        sched.push(t, preferred_worker=1)
    # worker 2 owns nothing: must steal the *oldest* task of the *longest*
    # victim queue (worker 1's)
    assert sched.take(2, timeout=0.1) == long[0]
    assert sched.take(2, timeout=0.1) == long[1]  # still FIFO from victim


def test_worksteal_prefers_global_queue_before_stealing():
    sched, graph, store = _mk_sched("worksteal")
    owned = _add_task(graph, store, [])
    shared = _add_task(graph, store, [])
    sched.push(owned, preferred_worker=0)
    sched.push(shared)  # no preferred worker -> global queue
    assert sched.take(2, timeout=0.1) == shared
    assert sched.take(2, timeout=0.1) == owned  # then steals


def test_unknown_policy_rejected():
    graph, store = TaskGraph(), ObjectStore()
    with pytest.raises(ValueError):
        Scheduler(graph, store, policy="psychic")
