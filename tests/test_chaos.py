"""Deterministic chaos injection (DESIGN.md §19).

Tier-1 covers the spec grammar, per-seam stream determinism, and the
half-open DataServer property (a frozen peer times out retryable instead
of blocking a consumer forever).  The ``chaos``-marked matrix runs a real
fragment/transform/tree-reduce pipeline on a live cluster under each
fault class with a fixed seed, asserting bitwise-identical results and a
scheduler whose ledgers still serve fresh work afterwards."""
import os
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.collectives import tree_reduce
from repro.cluster import chaos, peer, protocol
from repro.cluster.chaos import ChaosInjector, ChaosSpecError
from repro.cluster.peer import DataServer, PeerFetchError, PeerPool


@pytest.fixture(autouse=True)
def _disarm_chaos():
    """Every test leaves the process with chaos disarmed (the injector is
    a module global armed from the environment)."""
    yield
    os.environ.pop("RJAX_CHAOS", None)
    os.environ.pop("RJAX_WIRE_CHECKSUM", None)
    chaos.refresh()
    protocol.refresh_checksum()


# ------------------------------------------------------------------ parsing
def test_parse_full_grammar():
    inj = ChaosInjector.parse("1234:delay=0.02@0.3,hang=5@0.1,fetch-slow=0.2")
    assert inj.seed == 1234
    assert inj.faults["delay"] == (0.3, 0.02)
    assert inj.faults["hang"] == (0.1, 5.0)
    assert inj.faults["fetch-slow"] == (0.2, 0.2)   # default rate, arg given


def test_parse_defaults_per_fault():
    inj = ChaosInjector.parse("7:drop,freeze")
    assert inj.faults["drop"] == chaos.FAULTS["drop"]
    assert inj.faults["freeze"] == chaos.FAULTS["freeze"]


@pytest.mark.parametrize("bad", [
    "no-seed-part",            # missing colon
    "12:",                     # no clauses
    "x:delay",                 # seed not an int
    "5:frobnicate",            # unknown fault
    "5:delay=abc",             # bad number
    "5:delay@1.5",             # rate outside [0, 1]
])
def test_parse_rejects_malformed_specs(bad):
    with pytest.raises(ChaosSpecError):
        ChaosInjector.parse(bad)


def test_from_env_and_refresh(monkeypatch):
    monkeypatch.delenv("RJAX_CHAOS", raising=False)
    assert chaos.refresh() is None
    monkeypatch.setenv("RJAX_CHAOS", "9:delay@0.5")
    inj = chaos.refresh()
    assert inj is not None and inj.seed == 9
    assert chaos.INJECTOR is inj


# -------------------------------------------------------------- determinism
def test_streams_are_deterministic_per_seed():
    a = ChaosInjector.parse("42:delay=0.01@0.5")
    b = ChaosInjector.parse("42:delay=0.01@0.5")
    seq_a = [a.roll("delay", "seam-x") for _ in range(64)]
    seq_b = [b.roll("delay", "seam-x") for _ in range(64)]
    assert seq_a == seq_b
    assert any(v is not None for v in seq_a)
    assert any(v is None for v in seq_a)
    c = ChaosInjector.parse("43:delay=0.01@0.5")
    assert [c.roll("delay", "seam-x") for _ in range(64)] != seq_a


def test_streams_are_independent_per_scope():
    """Draining one seam's stream never perturbs another's sequence —
    the property that makes runs replayable even when seams interleave
    differently."""
    a = ChaosInjector.parse("42:delay@0.5")
    b = ChaosInjector.parse("42:delay@0.5")
    want_y = [b.roll("delay", "y") for _ in range(32)]
    for _ in range(1000):            # drain an unrelated scope first
        a.roll("delay", "x")
    assert [a.roll("delay", "y") for _ in range(32)] == want_y


def test_unconfigured_fault_never_fires():
    inj = ChaosInjector.parse("1:delay@1.0")
    assert inj.roll("hang", "s") is None
    assert not inj.sleep("freeze", "s")


# ------------------------------------------- half-open peer (satellite test)
def test_frozen_data_server_times_out_retryable(monkeypatch):
    """A DataServer connection that accepts the fetch and never answers
    (network-partition half-open) must surface as a retryable
    ``PeerFetchError`` carrying ``lost_input`` within the fetch timeout —
    never block the consumer forever."""
    monkeypatch.setenv("RJAX_CHAOS", "7:freeze@1.0")
    chaos.refresh()
    monkeypatch.setattr(peer, "PEER_FETCH_TIMEOUT", 1.5)
    value = np.arange(64, dtype=np.float64)
    server = DataServer(lambda key, token: value, host="127.0.0.1")
    pool = PeerPool(label="chaos-test")
    try:
        t0 = time.monotonic()
        with pytest.raises(PeerFetchError) as exc:
            pool.fetch(f"127.0.0.1:{server.port}", (1, 1), None)
        elapsed = time.monotonic() - t0
        assert elapsed < 10.0, f"blocked {elapsed:.1f}s, expected ~1.5s"
        assert exc.value.lost_input
    finally:
        pool.close()
        server.close()
    # disarmed, the same pull succeeds (the seam, not the server, froze)
    os.environ.pop("RJAX_CHAOS", None)
    chaos.refresh()
    server2 = DataServer(lambda key, token: value, host="127.0.0.1")
    pool2 = PeerPool(label="chaos-test2")
    try:
        got = pool2.fetch(f"127.0.0.1:{server2.port}", (1, 1), None)
        np.testing.assert_array_equal(got, value)
    finally:
        pool2.close()
        server2.close()


# ------------------------------------------------------------- chaos matrix
FRAGS = 8


def gen_frag(i: int):
    import numpy as np
    return np.sin(np.arange(2000, dtype=np.float64) * 0.001 * (i + 1))


def xform(a):
    import numpy as np
    return np.sqrt(np.abs(a)) + a


def merge(a, b):
    return a + b


def reference_result():
    """Client-side fold with the same balanced tree shape the runtime
    uses, so float summation order — and therefore bits — match."""
    return tree_reduce([xform(gen_frag(i)) for i in range(FRAGS)], merge)


# (id, RJAX_CHAOS spec, runtime kwargs) — every fault class, fixed seeds
MATRIX = [
    ("delay", "1234:delay=0.02@0.4", {}),
    ("drop", "1234:drop@0.5", {"heartbeat_s": 0.2}),
    ("stall", "1234:stall=0.1@0.4", {}),
    ("fetch-slow", "1234:fetch-slow=0.1@0.5", {}),
    ("hang", "1234:hang=3@0.2",
     {"deadline_s": 1.5, "max_retries": 4}),
    ("freeze", "1234:freeze@0.4", {"max_retries": 4}),
    ("delay-reseeded", "777:delay=0.02@0.4", {}),
    # transient network partitions (§20): sends blackhole for the window
    # but the socket stays open — the run must ride through on the
    # session machinery without burning retries on live connections
    ("partition", "1234:partition=1@0.05",
     {"heartbeat_s": 0.2, "reconnect_grace_s": 5.0}),
    ("partition-long", "4321:partition=2@0.03",
     {"heartbeat_s": 0.2, "reconnect_grace_s": 5.0, "max_retries": 4}),
    # wire corruption with CRC32 trailers armed: every flipped bit must
    # surface as a retryable transfer error — results stay bitwise right
    ("bitflip-checksum", "1234:bitflip@0.25", {"max_retries": 6}),
]


@pytest.mark.chaos
@pytest.mark.parametrize("spec,opts", [m[1:] for m in MATRIX],
                         ids=[m[0] for m in MATRIX])
def test_chaos_matrix_bitwise_and_ledgers(spec, opts, monkeypatch):
    """The acceptance matrix: under each fault class the pipeline
    completes with bitwise-identical results, and the runtime's ledgers
    come out healthy enough to serve a fresh round of tasks."""
    monkeypatch.setenv("RJAX_CHAOS", spec)
    if "bitflip" in spec:
        # checksums must be armed on BOTH ends: the scheduler via the
        # module global, the agents via the inherited environment
        monkeypatch.setenv("RJAX_WIRE_CHECKSUM", "1")
        protocol.refresh_checksum()
    if "freeze" in spec:
        # frozen serve connections must time out fast enough for the
        # lost-input retry path to finish inside the test budget —
        # scheduler-side via the module global, agents via the env
        monkeypatch.setenv("RJAX_PEER_FETCH_TIMEOUT", "2")
        monkeypatch.setattr(peer, "PEER_FETCH_TIMEOUT", 2.0)
    chaos.refresh()
    expect = reference_result()
    with api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2,
                           **opts) as rt:
        gen_t = api.task(gen_frag, name="gen")
        xform_t = api.task(xform, name="xform")
        merge_t = api.task(merge, name="merge")
        frags = gen_t.map([(i,) for i in range(FRAGS)])
        root = tree_reduce([xform_t(f) for f in frags], merge_t)
        got = api.wait_on(root, timeout=180)
        np.testing.assert_array_equal(got, expect)
        # ledgers rebuilt/consistent: a post-fault round on the same
        # runtime still resolves residency and returns correct bits
        chk = api.wait_on(merge_t(frags[0], frags[1]), timeout=60)
        np.testing.assert_array_equal(chk, gen_frag(0) + gen_frag(1))
        counters = rt.graph.counters()
        assert counters.get("failed", 0) == 0
