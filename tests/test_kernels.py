"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.knn_topk import knn_topk
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

# minutes of JAX compile+run on CPU: opt-in via `-m slow` (see pytest.ini)
pytestmark = pytest.mark.slow



def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


@pytest.mark.parametrize("B,H,K,Sq,Skv,d", [
    (2, 4, 2, 64, 64, 32),
    (1, 4, 1, 100, 100, 16),   # MQA + ragged
    (2, 8, 8, 128, 128, 64),   # MHA
    (1, 4, 2, 80, 200, 32),    # cross-length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, K, Sq, Skv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, K, Skv, d), dtype)
    v = jax.random.normal(ks[2], (B, K, Skv, d), dtype)
    o = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("window", [1, 7, 64])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 65, 16))
    k = jax.random.normal(ks[1], (1, 1, 65, 16))
    v = jax.random.normal(ks[2], (1, 1, 65, 16))
    o = flash_attention(q, k, v, window=window, block_q=32, block_k=32,
                        interpret=True)
    r = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 32, 3, 8, 16, 8),
    (1, 50, 2, 16, 8, 16),    # ragged
    (2, 64, 4, 4, 4, 64),     # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H))
                         ).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)))
    Bm = jax.random.normal(jax.random.PRNGKey(3), (B, S, N), dtype)
    Cm = jax.random.normal(jax.random.PRNGKey(4), (B, S, N), dtype)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, _ = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=0.05 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("B,S,R,blk", [(2, 16, 64, 32), (1, 33, 100, 64),
                                       (3, 8, 16, 16)])
def test_rglru_scan_sweep(B, S, R, blk):
    la = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(0), (B, S, R)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, R))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, R))
    y, hT = rglru_scan(la, b, h0, block_r=blk, interpret=True)
    yr, hTr = ref.rglru_scan_ref(la, b, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-5)


@pytest.mark.parametrize("m,n,d,k,bm,bn", [
    (50, 200, 10, 5, 32, 64),
    (128, 512, 20, 3, 128, 128),
    (7, 30, 4, 7, 8, 16),      # k > block remainder, ragged everywhere
])
def test_knn_topk_sweep(m, n, d, k, bm, bn):
    tx = jax.random.normal(jax.random.PRNGKey(3), (m, d))
    trx = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    ty = jax.random.randint(jax.random.PRNGKey(5), (n,), 0, 4)
    dd, ll = knn_topk(tx, trx, ty, k=k, block_m=bm, block_n=bn, interpret=True)
    dr, lr = ref.knn_topk_ref(tx, trx, ty, k)
    np.testing.assert_allclose(np.asarray(dd), np.asarray(dr), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ll), np.asarray(lr))


@pytest.mark.parametrize("n,d,k,bm", [(300, 8, 5, 64), (1025, 16, 7, 256),
                                      (64, 4, 2, 64)])
def test_kmeans_assign_sweep(n, d, k, bm):
    x = jax.random.normal(jax.random.PRNGKey(6), (n, d))
    c = jax.random.normal(jax.random.PRNGKey(7), (k, d))
    s1, c1, e1 = kmeans_assign(x, c, block_m=bm, interpret=True)
    s2, c2, e2 = ref.kmeans_assign_ref(x, c)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    assert float(e1) == pytest.approx(float(e2), rel=1e-4, abs=1e-2)
    assert int(jnp.sum(c1)) == n


@pytest.mark.parametrize("shape", [(8, 32), (3, 7, 64), (2, 2, 2, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(8), shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(9), (shape[-1],), dtype)
    o = rmsnorm(x, s, block_rows=4, interpret=True)
    r = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=_tol(dtype))


def test_flash_custom_vjp_grads_match_reference():
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (1, 4, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    v = jax.random.normal(ks[2], (1, 2, 32, 16))
    for argnum in range(3):
        g1 = jax.grad(lambda *a: jnp.sum(ops.flash_attention_op(*a)),
                      argnums=argnum)(q, k, v)
        g2 = jax.grad(lambda *a: jnp.sum(ref.flash_attention_ref(*a)),
                      argnums=argnum)(q, k, v)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_kernel_matches_model_layer_attention():
    """The kernel and the model's chunked-jnp twin agree (same math)."""
    from repro.layers.attention import _chunked_attn
    B, H, K, S, d = 1, 4, 2, 48, 16
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, H, S, d))
    k = jax.random.normal(ks[1], (B, K, S, d))
    v = jax.random.normal(ks[2], (B, K, S, d))
    o_kernel = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    # model layout: (B,S,K,G,hd) / (B,S,K,hd)
    G = H // K
    qg = q.reshape(B, K, G, S, d).transpose(0, 3, 1, 2, 4)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    pos = jnp.arange(S, dtype=jnp.int32)
    o_model = _chunked_attn(qg, kk, vv, pos, pos, None, chunk=16)
    o_model = o_model.transpose(0, 2, 3, 1, 4).reshape(B, H, S, d)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               atol=3e-5)
