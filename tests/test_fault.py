"""Fault-tolerance policy units (DESIGN.md §19): retry backoff math, the
``FailureDetector`` state machine against a fake clock, and the runtime
honoring ``backoff_seconds`` end to end (the regression for the knob that
previously existed but was never applied)."""
import time

import pytest

from repro.core import api
from repro.core.fault import (
    ALIVE,
    DEAD,
    SUSPECT,
    FailureDetector,
    LivenessConfig,
    RetryPolicy,
)


# ------------------------------------------------------------ RetryPolicy
def test_delay_for_exponential_floor_and_jitter_ceiling():
    """Attempt N waits at least ``backoff * factor**(N-1)`` and at most
    that times ``1 + jitter`` — pinned with the rng at both extremes."""
    p = RetryPolicy(backoff_seconds=0.5, backoff_factor=2.0,
                    backoff_max=30.0, jitter=0.25)
    for n in (1, 2, 3, 4):
        floor = 0.5 * 2.0 ** (n - 1)
        assert p.delay_for(n, rng=lambda: 0.0) == pytest.approx(floor)
        assert p.delay_for(n, rng=lambda: 1.0) == pytest.approx(floor * 1.25)
        mid = p.delay_for(n, rng=lambda: 0.5)
        assert floor <= mid <= floor * 1.25


def test_delay_for_caps_at_backoff_max():
    p = RetryPolicy(backoff_seconds=1.0, backoff_factor=10.0, backoff_max=5.0,
                    jitter=0.0)
    assert p.delay_for(1) == 1.0
    assert p.delay_for(2) == 5.0   # 10.0 capped
    assert p.delay_for(9) == 5.0


def test_delay_for_zero_backoff_is_immediate():
    p = RetryPolicy()   # backoff_seconds=0.0: the historical behavior
    assert p.delay_for(1) == 0.0
    assert p.delay_for(7) == 0.0


def test_delay_for_lost_input_pacing():
    """Lost-input failures are paced even with no backoff configured
    (§15: retries must not race the lineage rebuild), and the pacing
    floor combines with — never weakens — the exponential term."""
    p = RetryPolicy(jitter=0.0)
    assert p.delay_for(1, lost_input=True, lost_input_pace=0.25) == 0.25
    assert p.delay_for(3, lost_input=True, lost_input_pace=0.25) == 0.75
    assert p.delay_for(9, lost_input=True, lost_input_pace=0.25) == 1.0  # capped
    strong = RetryPolicy(backoff_seconds=2.0, jitter=0.0)
    assert strong.delay_for(1, lost_input=True) == 2.0   # backoff dominates


def test_runtime_waits_backoff_between_attempts():
    """End-to-end regression: with ``retry_backoff_s`` set, the gap
    between attempt 1 and attempt 2 is at least the configured base (the
    knob used to be silently ignored)."""
    stamps = []

    with api.runtime_start(n_workers=2, backend="thread", max_retries=1,
                           retry_backoff_s=0.3):
        def flaky():
            stamps.append(time.monotonic())
            if len(stamps) == 1:
                raise ValueError("first attempt fails")
            return "ok"

        t = api.task(flaky, name="flaky")
        assert api.wait_on(t(), timeout=30) == "ok"

    assert len(stamps) == 2
    gap = stamps[1] - stamps[0]
    assert gap >= 0.3, f"retry fired after {gap:.3f}s, expected >= 0.3s"
    # and with jitter bounded: never more than base * (1 + 0.25) + slack
    assert gap < 0.3 * 1.25 + 2.0


# -------------------------------------------------------- FailureDetector
class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def make_detector(suspicion_s=1.0, heartbeat_s=0.1, enabled=True,
                  dead_factor=2.0, min_grace_beats=3.0):
    clock = FakeClock()
    det = FailureDetector(
        LivenessConfig(enabled=enabled, suspicion_s=suspicion_s,
                       dead_factor=dead_factor,
                       min_grace_beats=min_grace_beats),
        heartbeat_s, clock=clock)
    return det, clock


def test_detector_alive_suspect_dead_progression():
    det, clock = make_detector(suspicion_s=1.0, heartbeat_s=0.1)
    det.note_install(0)
    assert det.assess(0) == ALIVE
    clock.t += 0.9
    assert det.assess(0) == ALIVE
    clock.t += 0.2            # age 1.1 > suspicion 1.0
    assert det.assess(0) == SUSPECT
    clock.t += 1.0            # age 2.1 > dead 2.0
    assert det.assess(0) == DEAD
    assert det.snapshot()[0]["state"] == DEAD


def test_detector_beat_resets_age():
    det, clock = make_detector(suspicion_s=1.0, heartbeat_s=0.1)
    det.note_install(0)
    clock.t += 1.5
    assert det.assess(0) == SUSPECT
    det.note_beat(0)
    assert det.assess(0) == ALIVE
    assert det.snapshot()[0]["beats"] == 1


def test_detector_install_counts_as_synthetic_beat():
    """A node wedged at birth (never beat once) still ages out."""
    det, clock = make_detector(suspicion_s=0.5, heartbeat_s=0.1)
    det.note_install(2)
    clock.t += 5.0
    assert det.assess(2) == DEAD


def test_detector_grace_beats_floor():
    """A suspicion window tighter than the beat cadence never fires
    before ``min_grace_beats`` beat periods — no false kills when the
    operator sets suspicion_s < heartbeat_s."""
    det, clock = make_detector(suspicion_s=0.1, heartbeat_s=1.0,
                               min_grace_beats=3.0)
    det.note_install(0)
    clock.t += 2.5             # > suspicion, < 3 beat periods
    assert det.assess(0) == ALIVE
    clock.t += 1.0             # 3.5 > 3 beat periods
    assert det.assess(0) != ALIVE


def test_detector_inactive_without_heartbeats():
    """heartbeat_s=0 (heartbeats off) means beat age carries no
    information: never suspect on it."""
    det, clock = make_detector(heartbeat_s=0.0)
    det.note_install(0)
    clock.t += 1e6
    assert det.assess(0) == ALIVE
    det2, clock2 = make_detector(enabled=False)
    assert not det2.active


def test_detector_deadline_overrides_beats():
    """An in-flight request past its deadline marks the node dead even
    while it beats — the SIGSTOP-adjacent 'beating but wedged' case."""
    det, clock = make_detector(suspicion_s=10.0, heartbeat_s=0.1)
    det.note_install(0)
    det.note_deadline(0, clock.t + 1.0)
    det.note_beat(0)
    assert det.assess(0) == ALIVE
    clock.t += 1.5
    det.note_beat(0)           # still beating...
    assert det.assess(0) == DEAD
    det.note_deadline(0, None)   # request completed after all
    assert det.assess(0) == ALIVE


def test_detector_removed_node_is_dead_until_reinstalled():
    det, clock = make_detector()
    det.note_install(0)
    det.note_removed(0)
    assert det.assess(0) == DEAD
    assert 0 not in det.snapshot()
    det.note_install(0)
    assert det.assess(0) == ALIVE
